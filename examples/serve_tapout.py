"""End-to-end serving driver (the paper's deployment shape): a batched
request stream served with speculative decoding + TapOut, bandit shared
online across requests.  Compares against Static-6 on the same workload.

    PYTHONPATH=src python examples/serve_tapout.py [--requests 12]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import get_corpus, trained_pair
from repro.core import EngineSpec, StaticGamma, make_controller
from repro.serving.engine import SpecServer


def serve(controller, draft, target, prompts, max_new):
    srv = SpecServer(draft, target, controller,
                     spec=EngineSpec(batch_size=4, max_len=1024))
    for ids in prompts:
        srv.submit(ids, max_new)
    srv.run_until_drained()
    return srv.throughput_stats()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=64)
    args = ap.parse_args()

    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()
    # a shifting workload: code first, then prose (tests online adaptation)
    prompts = [ids[:48] for _, ids in
               corpus.prompts("humaneval", args.requests // 2, seed=3)]
    prompts += [ids[:48] for _, ids in
                corpus.prompts("mt_bench", args.requests - len(prompts), seed=4)]

    tap = make_controller("tapout_seq_ucb1", gamma_max=16)
    s_tap = serve(tap, draft, target, prompts, args.max_new)
    s_sta = serve(StaticGamma(gamma=6), draft, target, prompts, args.max_new)

    print(f"{'':24s}{'TapOut Seq-UCB1':>18s}{'Static-6':>12s}")
    for k in ("total_new_tokens", "accept_rate", "modeled_cost_per_token",
              "wall_s_per_token", "mean_latency_s"):
        print(f"{k:24s}{s_tap[k]:>18.4g}{s_sta[k]:>12.4g}")
    speedup = s_sta["modeled_cost_per_token"] / s_tap["modeled_cost_per_token"]
    print(f"\nmodeled speedup over Static-6: {speedup:.2f}x")
    print("final arm values:", dict(zip([a.name for a in tap.arms],
                                        [round(float(v), 3) for v in tap.arm_values])))


if __name__ == "__main__":
    main()
