"""Quickstart: train a tiny draft/target pair on the synthetic corpus and
generate with TapOut sequence-level UCB1.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import get_corpus, trained_pair
from repro.core import EngineSpec, make_controller, make_engine
from repro.data.tokenizer import ByteTokenizer


def main():
    print("== loading (or training) the llama-1b-8b analog pair ...")
    draft, target = trained_pair("llama-1b-8b")
    tok = ByteTokenizer()
    corpus = get_corpus()
    controller = make_controller("tapout_seq_ucb1", gamma_max=16)
    engine = make_engine(draft, target, controller,
                         EngineSpec(backend="single", max_len=1024))

    for kind, ids in corpus.prompts("humaneval", 2, seed=5):
        res = engine.generate(ids[:64], 96)
        text = tok.decode(res.tokens[res.prompt_len:])
        print(f"\n== prompt ({kind}) -> {res.new_tokens} tokens, "
              f"m={res.mean_accepted:.2f}, accept={res.accept_rate:.0%}, "
              f"{len(res.sessions)} sessions")
        print(text[:200].replace("\n", "\\n"))

    print("\n== learned arm values (interpretable bandit state):")
    for arm, v in zip(controller.arms, controller.arm_values):
        print(f"   {arm.name:16s} {v:.3f}   (pulls: "
              f"{controller.bandit.counts[list(controller.arms).index(arm)]})")


if __name__ == "__main__":
    main()
