"""Speculative decoding with TapOut on ANY assigned architecture family:
instantiates the reduced same-family target + an even smaller draft and runs
dynamic speculation — including the attention-free (SSM / RG-LRU) families
via the snapshot-recompute rollback path.

    PYTHONPATH=src python examples/arch_spec_decode.py --arch mamba2-1.3b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import ARCH_IDS, smoke_config
from repro.core import EngineSpec, ModelBundle, make_controller, make_engine
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-1.3b")
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    tcfg = smoke_config(args.arch).replace(vocab_size=259)
    dcfg = tcfg.replace(name=tcfg.name + "-draft", d_model=max(64, tcfg.d_model // 2),
                        num_heads=max(1, tcfg.num_heads // 2),
                        num_kv_heads=1 if tcfg.num_kv_heads == 1 else
                        max(1, tcfg.num_kv_heads // 2),
                        d_ff=max(64, tcfg.d_ff // 2) if tcfg.d_ff else 0)
    # (random weights — this demonstrates the mechanics, not quality)
    target = ModelBundle(T.init_params(tcfg, jax.random.PRNGKey(0)), tcfg)
    draft = ModelBundle(T.init_params(dcfg, jax.random.PRNGKey(1)), dcfg)
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=8)
    eng = make_engine(draft, target, ctrl,
                      EngineSpec(backend="single", max_len=256))
    print(f"arch family: {tcfg.arch_type}; pointer-rollback caches: "
          f"draft={eng.draft_cheap} target={eng.target_cheap}")
    kw = {}
    res = eng.generate([1, 5, 9, 13, 17, 21], args.max_new)
    print(f"generated {res.new_tokens} tokens in {len(res.sessions)} sessions; "
          f"m={res.mean_accepted:.2f} accept={res.accept_rate:.0%}")
    print("arm values:", [round(float(v), 3) for v in ctrl.arm_values])


if __name__ == "__main__":
    main()
