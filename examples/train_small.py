"""Train a small LM (any assigned --arch family, reduced) for a few hundred
steps on the synthetic corpus — exercises the full training substrate
(optimizer, chunked CE, remat, checkpointing).

    PYTHONPATH=src python examples/train_small.py --arch gemma-2b --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import ARCH_IDS, smoke_config
from repro.data.synthetic import SyntheticCorpus
from repro.models import transformer as T
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import OptConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(vocab_size=259)
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")
    corpus = SyntheticCorpus(seed=0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    out = train(cfg, params,
                corpus.training_batches(seq_len=args.seq_len,
                                        batch_size=args.batch, seed=1),
                OptConfig(lr=3e-3, warmup_steps=30, total_steps=args.steps),
                steps=args.steps, log_every=20,
                callback=lambda m: print(
                    f"step {m['step']:4d}  loss {m['loss']:.3f}  "
                    f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}"))
    if args.out:
        save_checkpoint(args.out, out["params"],
                        {"arch": args.arch, "steps": args.steps})
        print("saved", args.out)


if __name__ == "__main__":
    main()
