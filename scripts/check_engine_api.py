#!/usr/bin/env python
"""Lint: new code must build engines through the factory API.

Two rules, enforced over ``src/repro``, ``benchmarks``, ``scripts`` and
``examples`` (NOT ``tests/`` — the suite deliberately exercises both the
concrete classes and the deprecated kwarg shim):

1. No direct construction of the concrete engine classes (``SpecEngine``,
   ``BatchedSpecEngine``, ``PagedSpecEngine``, ``TreeSpecEngine``,
   ``TreeSlotEngine``) outside ``core/engine.py`` — that file owns them
   and ``make_engine`` is the one public way in.  Mentioning the names
   (imports, isinstance, type hints) is fine; CALLING them is not.
2. No ``SpecServer(...)`` call without ``spec=`` — the keyword surface
   (``max_concurrency=``, ``paged=``, ``tree=``, ...) is deprecated and
   only kept alive for out-of-repo callers (docs/serving.md has the
   migration table).

Exit 1 with file:line diagnostics on any violation; wired into the CI
lint lane so a regression to the old construction paths fails the build.
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SCAN_DIRS = ("src/repro", "benchmarks", "scripts", "examples")
ENGINE_OWNER = os.path.join("src", "repro", "core", "engine.py")
SERVER_OWNER = os.path.join("src", "repro", "serving", "engine.py")
ENGINE_CLASSES = ("SpecEngine", "BatchedSpecEngine", "PagedSpecEngine",
                  "TreeSpecEngine", "TreeSlotEngine")
CALL_RE = re.compile(r"\b(" + "|".join(ENGINE_CLASSES) + r")\s*\(")
SERVER_RE = re.compile(r"\bSpecServer\s*\(")


def _py_files():
    for d in SCAN_DIRS:
        base = os.path.join(ROOT, d)
        for dirpath, _, names in os.walk(base):
            for n in sorted(names):
                if n.endswith(".py"):
                    yield os.path.join(dirpath, n)


def _call_span(text: str, open_paren: int) -> str:
    """The argument text of the call whose ``(`` is at ``open_paren``."""
    depth = 0
    for i in range(open_paren, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return text[open_paren:i + 1]
    return text[open_paren:]


def check_file(path: str) -> list:
    rel = os.path.relpath(path, ROOT)
    src = open(path).read()
    problems = []
    if not rel.endswith(ENGINE_OWNER):
        for m in CALL_RE.finditer(src):
            # a class STATEMENT (``class SpecEngine(...)``) is a definition,
            # not a construction; everything else that calls the name is
            line_start = src.rfind("\n", 0, m.start()) + 1
            prefix = src[line_start:m.start()]
            if prefix.lstrip().startswith("class "):
                continue
            line = src.count("\n", 0, m.start()) + 1
            problems.append(
                f"{rel}:{line}: direct {m.group(1)}(...) construction — "
                f"use make_engine(draft, target, controller, EngineSpec(...))")
    # the server module itself only mentions the legacy call shape inside
    # its own DeprecationWarning message — skip the owner
    for m in (() if rel.endswith(SERVER_OWNER) else SERVER_RE.finditer(src)):
        span = _call_span(src, m.end() - 1)
        if "spec=" not in span and "spec =" not in span:
            line = src.count("\n", 0, m.start()) + 1
            problems.append(
                f"{rel}:{line}: SpecServer(...) without spec= — the legacy "
                f"kwarg surface is deprecated; pass spec=EngineSpec(...)")
    return problems


def main() -> int:
    problems = []
    for path in _py_files():
        if os.path.abspath(path) == os.path.abspath(__file__):
            continue
        problems.extend(check_file(path))
    for p in problems:
        print(p)
    if problems:
        print(f"\n{len(problems)} engine-API violation(s).", file=sys.stderr)
        return 1
    print("engine-API lint: OK "
          f"({sum(1 for _ in _py_files())} files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
