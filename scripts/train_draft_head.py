"""Train an EAGLE-style draft head and prove the train->checkpoint->serve
loop end to end.

    PYTHONPATH=src python scripts/train_draft_head.py --smoke
    PYTHONPATH=src python scripts/train_draft_head.py \
        --steps 200 --seq-len 96 --batch 8 --out artifacts/models/eagle_head

The head (one transformer block + final norm, ``core/drafters.py``) is
trained against the frozen target's hidden states on synthetic corpus
batches, checkpointed via ``training/checkpoint.py``, reloaded against a
fresh template (asserting a bit-exact logits roundtrip), assembled into a
``ModelBundle`` and served through ``make_engine`` for a few greedy tokens.
``--smoke`` shrinks everything to CI scale and writes the loss-curve/claims
artifact ``artifacts/bench/eagle_head_smoke.json``.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def tiny_target():
    """A tiny random-init dense target (the CI smoke target)."""
    import jax
    from repro.core import ModelBundle
    from repro.models import ModelConfig
    from repro.models import transformer as T
    cfg = ModelConfig(name="smoke-tgt", arch_type="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                      vocab_size=259)  # ByteTokenizer vocab
    return ModelBundle(T.init_params(cfg, jax.random.PRNGKey(0)), cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=96)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="",
                    help="checkpoint path (default artifacts/models/...)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run; writes artifacts/bench/"
                         "eagle_head_smoke.json")
    args = ap.parse_args()
    if args.smoke:
        args.steps = min(args.steps, 20)
        args.seq_len = min(args.seq_len, 48)
        args.batch = min(args.batch, 4)

    import jax.numpy as jnp
    import numpy as np
    from repro.core import (EngineSpec, StaticGamma, eagle_bundle,
                            eagle_head_logits, eagle_logit_params,
                            load_eagle_head, make_engine, save_eagle_head,
                            train_eagle_head)
    from repro.data.synthetic import SyntheticCorpus
    from repro.training.optimizer import OptConfig

    target = tiny_target()
    corpus = SyntheticCorpus(seed=args.seed)

    print(f"[train] EAGLE head on {target.cfg.name}: steps={args.steps} "
          f"seq_len={args.seq_len} batch={args.batch}")
    out = train_eagle_head(
        target,
        corpus.training_batches(seq_len=args.seq_len,
                                batch_size=args.batch, seed=args.seed),
        steps=args.steps,
        opt_cfg=OptConfig(lr=3e-3, warmup_steps=min(5, args.steps),
                          total_steps=args.steps))
    head, head_cfg, hist = out["head"], out["head_cfg"], out["history"]
    print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")

    # checkpoint + bit-exact reload
    path = args.out or os.path.join(ROOT, "artifacts", "models",
                                    f"{head_cfg.name}")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    save_eagle_head(path, head, head_cfg, hist)
    _, head2 = load_eagle_head(path, target.cfg)
    probe = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, 8, head_cfg.d_model)).astype(np.float32))
    lg1 = eagle_head_logits(head, head_cfg, eagle_logit_params(target.params),
                            probe)
    lg2 = eagle_head_logits(head2, head_cfg, eagle_logit_params(target.params),
                            probe)
    roundtrip_ok = bool(np.array_equal(np.asarray(lg1), np.asarray(lg2)))
    print(f"[ckpt] {path} roundtrip bit-identical: {roundtrip_ok}")

    # serve the trained head as a drafter through the standard engine path
    draft = eagle_bundle(target, head, head_cfg)
    eng = make_engine(draft, target, StaticGamma(gamma=4),
                      EngineSpec(backend="single", max_len=192))
    _, ids = next(iter(corpus.prompts("alpaca", 1, seed=7)))
    r = eng.generate(ids[:24], 16)
    print(f"[serve] drafted={r.total_drafted} new_tokens={r.new_tokens}")

    summary = {
        "bench": "train_draft_head",
        "steps": args.steps,
        "loss_first": hist[0]["loss"],
        "loss_last": hist[-1]["loss"],
        "loss_curve": [h["loss"] for h in hist],
        "checkpoint": os.path.relpath(path, ROOT),
        "claim_loss_decreased": bool(hist[-1]["loss"] < hist[0]["loss"]),
        "claim_ckpt_roundtrip_bitexact": roundtrip_ok,
        "claim_served_tokens": bool(len(r.tokens) >= len(ids[:24]) + 16),
    }
    if args.smoke:
        os.makedirs(os.path.join(ROOT, "artifacts", "bench"), exist_ok=True)
        p = os.path.join(ROOT, "artifacts", "bench", "eagle_head_smoke.json")
        with open(p, "w") as f:
            json.dump(summary, f, indent=2, default=float)
        print(f"[smoke] wrote {p}")
    ok = all(v for k, v in summary.items() if k.startswith("claim_"))
    print(f"[done] claims: "
          f"{ {k: v for k, v in summary.items() if k.startswith('claim_')} }")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
