#!/usr/bin/env python
"""Docs lane checks (stdlib only, run by CI):

1. every intra-repo markdown link in README.md and docs/**/*.md resolves
   to an existing file (anchors stripped; http(s)/mailto skipped);
2. every page under docs/ is reachable from docs/index.md by following
   markdown links (no orphan documentation).

Exits non-zero with one line per violation.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files():
    files = [os.path.join(REPO, "README.md")]
    for root, _, names in os.walk(os.path.join(REPO, "docs")):
        files += [os.path.join(root, n) for n in sorted(names)
                  if n.endswith(".md")]
    return [f for f in files if os.path.exists(f)]


def links_of(path: str):
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # drop fenced code blocks — ascii diagrams are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return LINK_RE.findall(text)


def resolve(src: str, target: str):
    target = target.split("#", 1)[0]
    if not target:
        return None
    return os.path.normpath(os.path.join(os.path.dirname(src), target))


def main() -> int:
    errors = []
    files = md_files()

    # ---- 1. intra-repo links resolve
    graph = {f: set() for f in files}
    for f in files:
        for raw in links_of(f):
            if raw.startswith(SKIP_PREFIXES):
                continue
            dest = resolve(f, raw)
            if dest is None:
                continue
            if not os.path.exists(dest):
                errors.append(f"{os.path.relpath(f, REPO)}: broken link "
                              f"-> {raw}")
            elif dest.endswith(".md"):
                graph[f].add(dest)

    # ---- 2. every docs/*.md reachable from docs/index.md
    index = os.path.join(REPO, "docs", "index.md")
    if not os.path.exists(index):
        errors.append("docs/index.md is missing")
    else:
        seen, queue = {index}, [index]
        while queue:
            cur = queue.pop()
            for dest in graph.get(cur, ()):
                if dest not in seen:
                    seen.add(dest)
                    queue.append(dest)
        for f in files:
            if os.sep + "docs" + os.sep in f and f not in seen:
                errors.append(f"{os.path.relpath(f, REPO)}: not reachable "
                              f"from docs/index.md")

    for e in errors:
        print(f"::error::{e}")
    if not errors:
        print(f"docs ok: {len(files)} pages, all links resolve, all docs "
              f"pages reachable from docs/index.md")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
