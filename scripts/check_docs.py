#!/usr/bin/env python
"""Docs lane checks (stdlib only, run by CI):

1. every intra-repo markdown link in README.md and docs/**/*.md resolves
   to an existing file (anchors stripped; http(s)/mailto skipped);
2. every page under docs/ is reachable from docs/index.md by following
   markdown links (no orphan documentation);
3. every module under src/repro/ is mentioned by at least one docs page
   or the README (orphan-module report): a module ``pkg/mod.py`` counts
   as mentioned if any page contains ``pkg/mod.py`` or the dotted path
   ``repro.pkg.mod``; a package ``pkg/__init__.py`` is covered by any
   ``repro.pkg`` mention.  The per-module map lives in docs/index.md —
   adding a module without documenting it fails CI.

Exits non-zero with one line per violation.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files():
    files = [os.path.join(REPO, "README.md")]
    for root, _, names in os.walk(os.path.join(REPO, "docs")):
        files += [os.path.join(root, n) for n in sorted(names)
                  if n.endswith(".md")]
    return [f for f in files if os.path.exists(f)]


def links_of(path: str):
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # drop fenced code blocks — ascii diagrams are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return LINK_RE.findall(text)


def resolve(src: str, target: str):
    target = target.split("#", 1)[0]
    if not target:
        return None
    return os.path.normpath(os.path.join(os.path.dirname(src), target))


def repro_modules():
    """Module files under src/repro, as paths relative to src/repro."""
    root = os.path.join(REPO, "src", "repro")
    out = []
    for dirpath, dirnames, names in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for n in sorted(names):
            if n.endswith(".py"):
                out.append(os.path.relpath(os.path.join(dirpath, n), root))
    return out


def orphan_modules(files):
    corpus = ""
    for f in files:
        with open(f, encoding="utf-8") as fh:
            corpus += fh.read() + "\n"
    orphans = []
    for rel in repro_modules():
        rel = rel.replace(os.sep, "/")
        if rel.endswith("/__init__.py"):
            pkg = rel[:-len("/__init__.py")].replace("/", ".")
            mentions = (rel, f"repro.{pkg}")
        else:
            dotted = rel[:-3].replace("/", ".")
            mentions = (rel, f"repro.{dotted}")
        if not any(m in corpus for m in mentions):
            orphans.append((rel, mentions))
    return orphans


def main() -> int:
    errors = []
    files = md_files()

    # ---- 1. intra-repo links resolve
    graph = {f: set() for f in files}
    for f in files:
        for raw in links_of(f):
            if raw.startswith(SKIP_PREFIXES):
                continue
            dest = resolve(f, raw)
            if dest is None:
                continue
            if not os.path.exists(dest):
                errors.append(f"{os.path.relpath(f, REPO)}: broken link "
                              f"-> {raw}")
            elif dest.endswith(".md"):
                graph[f].add(dest)

    # ---- 2. every docs/*.md reachable from docs/index.md
    index = os.path.join(REPO, "docs", "index.md")
    if not os.path.exists(index):
        errors.append("docs/index.md is missing")
    else:
        seen, queue = {index}, [index]
        while queue:
            cur = queue.pop()
            for dest in graph.get(cur, ()):
                if dest not in seen:
                    seen.add(dest)
                    queue.append(dest)
        for f in files:
            if os.sep + "docs" + os.sep in f and f not in seen:
                errors.append(f"{os.path.relpath(f, REPO)}: not reachable "
                              f"from docs/index.md")

    # ---- 3. orphan-module report: every src/repro module is documented
    n_modules = len(repro_modules())
    for rel, mentions in orphan_modules(files):
        errors.append(f"src/repro/{rel}: not mentioned by any docs page "
                      f"(add '{mentions[0]}' or '{mentions[1]}' to the "
                      f"docs/index.md module map)")

    for e in errors:
        print(f"::error::{e}")
    if not errors:
        print(f"docs ok: {len(files)} pages, all links resolve, all docs "
              f"pages reachable from docs/index.md, all {n_modules} "
              f"src/repro modules mentioned")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
