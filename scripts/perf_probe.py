"""Hillclimb probe: lower one (arch x shape) with config overrides and print
the roofline terms + memory — the measurement half of each §Perf iteration.

    PYTHONPATH=src python scripts/perf_probe.py --arch gemma-2b \
        --shape decode_32k --set long_context_window=4096 [--unroll] [--multi-pod]
"""
import argparse
import ast
import json
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override k=v (python literal)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--donate-cache", action="store_true")
    ap.add_argument("--cache-int8", action="store_true")
    ap.add_argument("--argmax-out", action="store_true")
    ap.add_argument("--serve-resident", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    from repro.launch.dryrun import lower_pair
    r = lower_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                   unroll=args.unroll, cfg_overrides=overrides,
                   train_microbatches=args.microbatches,
                   donate_cache=args.donate_cache,
                   cache_int8=args.cache_int8, argmax_out=args.argmax_out,
                   serve_resident=args.serve_resident, verbose=False)
    rl = r.get("roofline", {})
    mem = r.get("memory", {})
    print(json.dumps({
        "overrides": overrides,
        "status": r["status"],
        "t_compute_ms": rl.get("t_compute_s", 0) * 1e3,
        "t_memory_ms": rl.get("t_memory_s", 0) * 1e3,
        "t_collective_ms": rl.get("t_collective_s", 0) * 1e3,
        "dominant": rl.get("dominant"),
        "collective_per_chip": rl.get("collective_per_chip_bytes"),
        "temp_gb": mem.get("temp_size_in_bytes", 0) / 1e9,
        "args_gb": mem.get("argument_size_in_bytes", 0) / 1e9,
        "compile_s": r.get("compile_s"),
    }, indent=2))
    return 0 if r["status"] == "compiled" else 1


if __name__ == "__main__":
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    sys.exit(main())
