#!/usr/bin/env python
"""Lint-lane schema check for the committed BENCH_serving.json (stdlib only).

The serving-path benches append one row per run via
``benchmarks.common.record_serving_bench``; the file's git history IS the
perf trajectory across PRs, so a malformed row silently poisons every
later comparison.  This script validates each row:

1. the document is ``{"runs": [...]}`` and each row has exactly the keys
   ``bench`` (non-empty str), ``recorded_at`` (UTC ``...T...Z`` timestamp)
   and ``summary`` (non-empty dict);
2. every ``claim_*`` key anywhere in a summary holds a real bool — a
   claim recorded as a string/int/None means the bench's gate logic broke;
3. each summary carries at least one ``claim_*`` key (a serving bench
   with no gated claim is recording noise, not evidence);
4. rows from benches that ship an engine ``describe()`` blob
   (``ENGINE_BLOB_BENCHES``) actually attach one — a dict under an
   ``engine`` key (possibly nested per-config) with at least a ``backend``
   field, so the trajectory stays attributable to an engine config.
   Pre-existing benches that predate the convention are exempt;
5. rows from drafter-pool benches (``DRAFTER_BLOB_BENCHES``) stamp
   drafter identity: every engine blob carries a ``drafter`` dict with
   ``name`` and ``kind``, and the summary carries a pool-level
   ``drafters`` blob with the candidate ``names`` — a drafter bench row
   that cannot say WHICH drafters competed is not evidence;
6. rows from the MoE/encoder workload benches (``MOE_ENCODER_BENCHES``)
   stamp BOTH axes: a ``moe`` dict with numeric routed-expert stats
   (``routed_frac``, ``mean_routing_density``) and an ``encoder`` dict
   with numeric shared-segment stats (``unique_bytes``, ``logical_bytes``,
   ``streams``) — a routed-cost or segment-sharing claim without the
   numbers behind it is not evidence.

Exits non-zero with one ``::error::`` line per violation.
"""
from __future__ import annotations

import json
import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
PATH = os.path.join(REPO, "BENCH_serving.json")
ROW_KEYS = {"bench", "recorded_at", "summary"}
TS_RE = re.compile(r"^\d{4}-\d{2}-\d{2}T\d{2}:\d{2}:\d{2}Z$")
# benches (by name prefix, _smoke included) required to attach describe()
ENGINE_BLOB_BENCHES = ("prefix_sharing", "slo_serving", "drafters",
                       "moe_encoder")
# benches required to stamp drafter identity (engine blob "drafter" dict
# + summary-level "drafters" pool blob)
DRAFTER_BLOB_BENCHES = ("drafters",)
# benches required to stamp routed-expert stats ("moe" dict) and shared
# encoder-segment stats ("encoder" dict) on the summary
MOE_ENCODER_BENCHES = ("moe_encoder",)


def _num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def claim_keys(obj, path=""):
    """Yield (dotted_path, value) for every claim_* key, at any depth."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{path}.{k}" if path else str(k)
            if isinstance(k, str) and k.startswith("claim_"):
                yield p, v
            yield from claim_keys(v, p)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from claim_keys(v, f"{path}[{i}]")


def engine_blobs(summary):
    """Engine describe() blobs: ``engine`` may be one blob or a dict of
    per-config blobs (e.g. {"fifo": {...}, "slo": {...}})."""
    eng = summary.get("engine")
    if not isinstance(eng, dict):
        return []
    if "backend" in eng:
        return [eng]
    return [v for v in eng.values() if isinstance(v, dict)]


def check_row(i, row):
    errs = []
    where = f"runs[{i}]"
    if not isinstance(row, dict):
        return [f"{where}: row is {type(row).__name__}, not an object"]
    if set(row) != ROW_KEYS:
        errs.append(f"{where}: keys {sorted(row)} != {sorted(ROW_KEYS)}")
        return errs
    bench, ts, summary = row["bench"], row["recorded_at"], row["summary"]
    if not (isinstance(bench, str) and bench):
        errs.append(f"{where}: 'bench' must be a non-empty string")
        bench = "?"
    where = f"runs[{i}] ({bench})"
    if not (isinstance(ts, str) and TS_RE.match(ts)):
        errs.append(f"{where}: 'recorded_at' {ts!r} is not a UTC "
                    f"YYYY-MM-DDTHH:MM:SSZ timestamp")
    if not (isinstance(summary, dict) and summary):
        errs.append(f"{where}: 'summary' must be a non-empty object")
        return errs
    claims = list(claim_keys(summary))
    if not claims:
        errs.append(f"{where}: summary has no claim_* key — serving "
                    f"benches must record their gated claims")
    for path, v in claims:
        if not isinstance(v, bool):
            errs.append(f"{where}: summary.{path} = {v!r} "
                        f"({type(v).__name__}) — claims must be bool")
    if bench.startswith(ENGINE_BLOB_BENCHES):
        blobs = engine_blobs(summary)
        if not blobs:
            errs.append(f"{where}: missing engine describe() blob "
                        f"(summary['engine'] dict with a 'backend' field)")
        for b in blobs:
            if "backend" not in b:
                errs.append(f"{where}: engine blob lacks 'backend': "
                            f"{sorted(b)[:6]}")
    if bench.startswith(DRAFTER_BLOB_BENCHES):
        for b in engine_blobs(summary):
            d = b.get("drafter")
            if not (isinstance(d, dict) and isinstance(d.get("name"), str)
                    and isinstance(d.get("kind"), str)):
                errs.append(f"{where}: engine blob lacks a 'drafter' dict "
                            f"with 'name'/'kind' — drafter identity must "
                            f"be stamped on every run")
        pool = summary.get("drafters")
        if not (isinstance(pool, dict) and isinstance(pool.get("names"),
                                                      list)
                and pool["names"]):
            errs.append(f"{where}: summary lacks a 'drafters' pool blob "
                        f"with non-empty 'names'")
    if bench.startswith(MOE_ENCODER_BENCHES):
        moe = summary.get("moe")
        if not (isinstance(moe, dict) and _num(moe.get("routed_frac"))
                and _num(moe.get("mean_routing_density"))):
            errs.append(f"{where}: summary lacks a 'moe' dict with numeric "
                        f"'routed_frac'/'mean_routing_density' — MoE rows "
                        f"must stamp routed-expert stats")
        enc = summary.get("encoder")
        if not (isinstance(enc, dict) and _num(enc.get("unique_bytes"))
                and _num(enc.get("logical_bytes"))
                and _num(enc.get("streams"))):
            errs.append(f"{where}: summary lacks an 'encoder' dict with "
                        f"numeric 'unique_bytes'/'logical_bytes'/'streams' "
                        f"— encoder rows must stamp shared-segment stats")
    return errs


def main() -> int:
    if not os.path.exists(PATH):
        print("::error::BENCH_serving.json is missing from the repo root")
        return 1
    try:
        with open(PATH) as f:
            doc = json.load(f)
    except ValueError as e:
        print(f"::error::BENCH_serving.json is not valid JSON: {e}")
        return 1
    runs = doc.get("runs") if isinstance(doc, dict) else None
    if not isinstance(runs, list):
        print("::error::BENCH_serving.json must be {\"runs\": [...]}")
        return 1
    errors = []
    for i, row in enumerate(runs):
        errors += check_row(i, row)
    for e in errors:
        print(f"::error::{e}")
    if not errors:
        n_claims = sum(len(list(claim_keys(r["summary"]))) for r in runs)
        print(f"bench schema ok: {len(runs)} runs, {n_claims} claim "
              f"values, all rows well-formed")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
