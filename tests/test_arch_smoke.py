"""Per assigned architecture: instantiate the REDUCED same-family variant and
run one forward + one train step + one decode step on CPU; assert output
shapes and no NaNs.  (Full configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.launch.specs import SHAPES
from repro.models import transformer as T
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import make_train_step


def _batch_for(cfg, B=2, S=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    extras = {}
    if cfg.vision is not None:
        extras["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.vision.num_patches, cfg.vision.vit_dim))
    if cfg.is_encdec:
        extras["frame_embeds"] = jax.random.normal(
            rng, (B, cfg.encdec.frontend_len, cfg.encdec.frontend_dim))
    batch.update(extras)
    return batch, extras


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_shapes(arch):
    cfg = smoke_config(arch)
    assert cfg.num_layers <= max(2, len(cfg.block_pattern))
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch, extras = _batch_for(cfg)
    h, aux = T.forward_hidden(params, cfg, batch["tokens"], remat=False,
                              **extras)
    logits = T.logits_fn(params, cfg, h)
    B, S = batch["tokens"].shape
    extra_seq = cfg.vision.num_patches if cfg.vision is not None else 0
    assert logits.shape == (B, S + extra_seq, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch, _ = _batch_for(cfg)
    step = make_train_step(cfg, OptConfig(lr=1e-3, total_steps=10),
                           remat=False, donate=False)
    params2, opt2, m = jax.jit(step)(params, init_opt_state(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) > 0
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch, extras = _batch_for(cfg, B=1, S=8)
    cache, spec = T.init_cache(cfg, 1, 64, jnp.float32)
    lg, cache = T.step(params, cfg, batch["tokens"], cache, spec, **extras)
    for _ in range(3):
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        lg, cache = T.step(params, cfg, tok, cache, spec)
    assert lg.shape == (1, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(lg, np.float32)).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_paged_step(arch):
    """Every registry arch — dense, MoE, vision- and encoder-conditioned —
    builds a tiny variant and advances the PAGED decode path: conditioned
    prefill (patch prepend / shared cross segment) plus decode steps."""
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch, extras = _batch_for(cfg, B=1, S=8)
    cache, spec = T.init_paged_cache(cfg, 1, 64, block_size=8,
                                     dtype=jnp.float32)
    # hand the lane a real block-table row (block 0 is the trash block)
    cache = {**cache, "tables": jnp.arange(1, spec.max_blocks + 1,
                                           dtype=jnp.int32)[None]}
    kw = {}
    if "patch_embeds" in extras:
        kw["patch_embeds"] = extras["patch_embeds"]
    if "frame_embeds" in extras:
        lane = T.encode_cross_segment(params, cfg, extras["frame_embeds"])
        cache = T.write_cross_segment(cache, lane, 1)
        cache = {**cache, "cross_seg": cache["cross_seg"].at[0].set(1)}
    lg, cache = T.paged_step(params, cfg, batch["tokens"], cache, spec, **kw)
    for _ in range(3):
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        lg, cache = T.paged_step(params, cfg, tok, cache, spec)
    assert lg.shape == (1, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(lg, np.float32)).any()
    if cfg.moe is not None:
        # the routing-density channel rode along with the decode step
        assert float(np.asarray(cache["moe_stats"])[0]) >= 1.0


def test_full_configs_match_assignment():
    spec = {
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 102400),
        "gemma-2b": (18, 2048, 8, 1, 256000),
        "qwen3-4b": (36, 2560, 32, 8, 151936),
        "recurrentgemma-2b": (26, 2560, 10, 1, 256000),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "mamba2-1.3b": (48, 2048, 1, 1, 50280),
        "qwen2.5-3b": (36, 2048, 16, 2, 151936),
        "internvl2-26b": (48, 6144, 48, 8, 92553),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 256206),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 200064),
    }
    for arch, (L, d, H, kv, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.vocab_size) == (L, d, H, kv, V), arch
        assert cfg.source


def test_extra_config_details():
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.mla.kv_lora_rank == 512 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    q3 = get_config("qwen3-moe-235b-a22b")
    assert q3.moe.num_experts == 128 and q3.moe.top_k == 8
    assert get_config("qwen2.5-3b").qkv_bias
    assert get_config("qwen3-4b").qk_norm
    rg = get_config("recurrentgemma-2b")
    assert rg.block_pattern == ("rglru", "rglru", "local") and rg.window == 2048
    mb = get_config("mamba2-1.3b")
    assert mb.ssm.d_state == 128 and mb.is_attention_free
    assert get_config("gemma-2b").resolved_head_dim == 256
    assert get_config("seamless-m4t-large-v2").is_encdec
    assert get_config("internvl2-26b").vision is not None


def test_shapes_table():
    assert SHAPES["train_4k"] == dict(seq_len=4096, batch=256, kind="train")
    assert SHAPES["prefill_32k"] == dict(seq_len=32768, batch=32, kind="prefill")
    assert SHAPES["decode_32k"] == dict(seq_len=32768, batch=128, kind="decode")
    assert SHAPES["long_500k"] == dict(seq_len=524288, batch=1, kind="decode")
