"""Paged KV-cache subsystem: block allocator invariants, token-for-token
equivalence of the paged engine with the dense path, masked-slot/block-reuse
isolation, O(1) length-truncation rollback, and block-aware serving
admission with backpressure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import drain_streams as _drain
from conftest import make_tiny_pair
from repro.core import ModelBundle, SpecEngine, make_controller
from repro.core.engine import BatchedSpecEngine, PagedSpecEngine
from repro.models import ModelConfig, RGLRUConfig
from repro.models import transformer as T
from repro.models.cache import BlockAllocator, PoolExhausted
from repro.serving.engine import SpecServer

PROMPTS = [[1, 5, 9, 13],
           [2, 6, 10, 14, 18, 22, 26],
           [3, 7, 11, 15, 19, 23, 27, 31, 35, 39, 43],
           [4, 8, 12, 16, 20]]


# --------------------------------------------------------------- allocator

def test_allocator_invariants():
    a = BlockAllocator(num_blocks=9, max_blocks=6, batch=3)
    assert a.blocks_in_use == 0
    row = a.allocate(0, 3)
    assert a.blocks_in_use == 3 and a.peak_in_use == 3
    assert 0 not in row[:3], "trash block must never be handed out"
    assert (row[3:] == 0).all(), "unallocated table entries point at trash"
    a.allocate(1, 4)
    assert a.blocks_in_use == 7
    # no block belongs to two slots
    assert not set(a.owned[0]) & set(a.owned[1])
    with pytest.raises(PoolExhausted):
        a.allocate(2, 2)                      # only 1 of 8 usable blocks left
    assert a.blocks_in_use == 7, "failed allocation must not leak"
    a.release(1)
    assert a.blocks_in_use == 3
    assert (a.tables[1] == 0).all()
    a.allocate(2, 5)                          # released blocks are reusable
    assert a.blocks_in_use == 8 and a.peak_in_use == 8


def test_allocator_truncate_frees_tail_blocks():
    a = BlockAllocator(num_blocks=9, max_blocks=8, batch=1)
    a.allocate(0, 6)
    released = a.truncate(0, keep_tokens=33, block_size=16)  # keep 3 blocks
    assert released == 3
    assert len(a.owned[0]) == 3 and a.blocks_in_use == 3
    assert (a.tables[0][3:] == 0).all() and (a.tables[0][:3] != 0).all()


def test_paged_rollback_is_length_truncation_only():
    """Rollback must not touch pool contents — only the lengths vector."""
    from repro.models.cache import paged_rollback
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=17)
    cache, spec = T.init_paged_cache(cfg, 2, 64, block_size=8,
                                     dtype=jnp.float32)
    rolled = paged_rollback(cache, np.array([3, 7]))
    assert rolled["layers"] is cache["layers"]      # same pytree, no copy
    assert rolled["tables"] is cache["tables"]
    np.testing.assert_array_equal(np.asarray(rolled["lengths"]), [3, 7])


# --------------------------------------------------------------- equivalence

def test_paged_matches_single_stream_and_dense_batched(tiny_dense_pair):
    """B=4 paged generation == B=4 dense batched == 4 single-stream runs,
    token for token (the ISSUE's headline acceptance criterion)."""
    draft, target = tiny_dense_pair
    max_new = 20
    refs = []
    for p in PROMPTS:
        ctrl = make_controller("fixed_svip", gamma_max=4, seed=0)
        refs.append(SpecEngine(draft, target, ctrl,
                               max_len=256).generate(p, max_new).tokens)
    dense = BatchedSpecEngine(draft, target,
                              make_controller("fixed_svip", gamma_max=4, seed=0),
                              batch_size=4, max_len=256)
    dense_states = _drain(dense, PROMPTS, max_new)
    paged = PagedSpecEngine(draft, target,
                            make_controller("fixed_svip", gamma_max=4, seed=0),
                            batch_size=4, max_len=256, block_size=16)
    paged_states = _drain(paged, PROMPTS, max_new)
    for pst, dst, ref in zip(paged_states, dense_states, refs):
        n = min(len(ref), len(pst["seq"]))
        assert pst["seq"][:n] == ref[:n]
        nd = min(len(dst["seq"]), len(pst["seq"]))
        assert pst["seq"][:nd] == dst["seq"][:nd]
        assert pst["res"].new_tokens >= max_new
    # every stream's blocks were returned on close
    assert paged.dalloc.blocks_in_use == 0
    assert paged.talloc.blocks_in_use == 0


def test_paged_matches_single_recurrent_family():
    """Snapshot-recompute (recurrent draft) over the paged target pool."""
    draft, target = make_tiny_pair("recurrent")
    prompts = PROMPTS[:2]
    max_new = 12
    refs = []
    for p in prompts:
        eng1 = SpecEngine(draft, target,
                          make_controller("fixed_svip", gamma_max=4, seed=0),
                          max_len=128)
        refs.append(eng1.generate(p, max_new).tokens)
    eng = PagedSpecEngine(draft, target,
                          make_controller("fixed_svip", gamma_max=4, seed=0),
                          batch_size=2, max_len=128, block_size=16)
    assert not eng.draft_cheap and eng.target_cheap
    states = _drain(eng, prompts, max_new)
    for st, ref in zip(states, refs):
        n = min(len(ref), len(st["seq"]))
        assert st["seq"][:n] == ref[:n]


def test_paged_matches_single_stream_mla():
    """MLA latent pools (ckv/krope block tables, absorbed attention) —
    the ISSUE's acceptance criterion names attention/MLA-only configs."""
    draft, target = make_tiny_pair("mla")
    prompts = PROMPTS[:2]
    max_new = 12
    refs = []
    for p in prompts:
        refs.append(SpecEngine(draft, target,
                               make_controller("fixed_svip", gamma_max=4,
                                               seed=0),
                               max_len=128).generate(p, max_new).tokens)
    eng = PagedSpecEngine(draft, target,
                          make_controller("fixed_svip", gamma_max=4, seed=0),
                          batch_size=2, max_len=128, block_size=16)
    assert eng.draft_cheap and eng.target_cheap
    states = _drain(eng, prompts, max_new)
    for st, ref in zip(states, refs):
        n = min(len(ref), len(st["seq"]))
        assert st["seq"][:n] == ref[:n]


def test_paged_masked_slot_and_block_reuse_isolation(tiny_dense_pair):
    """A neighbor slot that finishes, releases its BLOCKS back to the pool,
    and is replaced by a new stream (which re-allocates those same physical
    blocks) must never perturb slot 0's tokens."""
    draft, target = tiny_dense_pair
    max_new = 24
    ref = SpecEngine(draft, target,
                     make_controller("fixed_svip", gamma_max=4, seed=0),
                     max_len=256).generate(PROMPTS[0], max_new).tokens
    ctrl = make_controller("fixed_svip", gamma_max=4, seed=0)
    eng = PagedSpecEngine(draft, target, ctrl, batch_size=2, max_len=256,
                          block_size=16)
    eng.open_stream(0, PROMPTS[0])
    eng.open_stream(1, PROMPTS[1])
    sessions = 0
    for tick in range(200):
        st0 = eng.slots[0]
        if st0["res"].new_tokens >= max_new:
            break
        if tick == 2 and eng.slots[1] is not None:
            eng.close_stream(1)               # blocks go back to the pool
        if tick == 5 and eng.slots[1] is None:
            eng.open_stream(1, PROMPTS[2])    # new stream reuses them
        sessions += len(eng.session_step_batch())
    n = min(len(ref), len(st0["seq"]))
    assert st0["seq"][:n] == ref[:n]
    assert sum(h["batch"] for h in ctrl.history) == sessions


def test_paged_outputs_masked_for_inactive(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = make_controller("fixed_svip", gamma_max=4, seed=0)
    eng = PagedSpecEngine(draft, target, ctrl, batch_size=3, max_len=256,
                          block_size=16)
    eng.open_stream(1, PROMPTS[0])
    assert eng.active_mask().tolist() == [False, True, False]
    eng.session_step_batch()
    assert eng.slots[1]["res"].sessions[0].n_drafted >= 1
    assert eng.slots[0] is None and eng.slots[2] is None
    assert eng._tlen[0] == 0 and eng._tlen[2] == 0
    # empty lanes own no blocks and their table rows point at trash
    assert not eng.talloc.owned[0] and not eng.talloc.owned[2]
    assert (np.asarray(eng.tcache["tables"])[[0, 2]] == 0).all()


def test_paged_slot_reuse_resets_recurrent_state():
    """A reused slot must prefill from ZERO recurrent state, not the
    previous stream's final hidden state (regression: pool rows are masked
    by length, but conv/ssm/rec state is integrated and needs an explicit
    reset on admission).  Asserted at the state level — after re-admission
    the lane's recurrent leaves must be bit-identical to a fresh engine's —
    and at the token level."""
    V = 61
    tcfg = ModelConfig(name="t", arch_type="hybrid", num_layers=2, d_model=64,
                       num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=V,
                       block_pattern=("rglru", "attn"),
                       rglru=RGLRUConfig(lru_width=64))
    dcfg = ModelConfig(name="d", arch_type="dense", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=V)
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    draft, target = ModelBundle(dp, dcfg), ModelBundle(tp, tcfg)
    max_new = 10

    from repro.models.cache import POOL_LEAF_KEYS

    def recurrent_leaves(eng):
        out = []
        def f(path, a):
            if getattr(path[-1], "key", None) not in POOL_LEAF_KEYS:
                out.append(np.asarray(a))
            return a
        jax.tree_util.tree_map_with_path(f, eng.tcache["layers"])
        return out

    def mk():
        return PagedSpecEngine(draft, target,
                               make_controller("fixed_svip", gamma_max=3,
                                               seed=0),
                               batch_size=1, max_len=128, block_size=16)

    fresh = mk()
    assert not fresh.target_cheap
    fresh.open_stream(0, PROMPTS[1])
    want = recurrent_leaves(fresh)

    reused = mk()
    _drain(reused, [PROMPTS[0]], max_new)      # pollute slot 0's state
    reused.open_stream(0, PROMPTS[1])          # re-admit into slot 0
    got = recurrent_leaves(reused)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)

    ref = SpecEngine(draft, target,
                     make_controller("fixed_svip", gamma_max=3, seed=0),
                     max_len=128).generate(PROMPTS[1], max_new).tokens
    for _ in range(200):
        s = reused.slots[0]
        if s["done"] or s["res"].new_tokens >= max_new:
            break
        reused.session_step_batch()
    seq = reused.slots[0]["seq"]
    n = min(len(ref), len(seq))
    assert seq[:n] == ref[:n]


# --------------------------------------------------------------- serving

def test_paged_server_backpressures_and_drains(tiny_dense_pair):
    """With a pool too small for the full batch width, admission must
    re-queue instead of admitting — and still drain every request."""
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=4, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=4,
                     paged=True, block_size=16, pool_tokens=96)
    prompts = [[1 + i, 5, 9, 13] for i in range(6)]
    ids = [srv.submit(p, 10) for p in prompts]
    responses = srv.run_until_drained(max_ticks=500)
    assert len(responses) == 6
    assert {r.request_id for r in responses} == set(ids)
    for r in responses:
        assert r.result.new_tokens >= 10
    stats = srv.throughput_stats()
    assert stats["backpressure_events"] > 0
    assert stats["peak_concurrency"] < 4        # the pool, not B, was binding
    assert stats["blocks_in_use"] == 0          # all blocks returned
    assert stats["peak_blocks_in_use"] > 0


def test_allocator_blocks_for_raises_beyond_table_width():
    """Regression: ``blocks_for`` used to clamp to ``max_blocks``, so an
    over-long request under-reserved and wrote through trash block 0."""
    a = BlockAllocator(num_blocks=64, max_blocks=4, batch=1)
    assert a.blocks_for(64, 16) == 4
    with pytest.raises(ValueError, match="max_blocks"):
        a.blocks_for(65, 16)
    assert a.blocks_in_use == 0, "the failed probe must not allocate"


def test_admission_backpressure_under_fragmentation(tiny_dense_pair):
    """Interleave admit/preempt(truncate)/release until ``PoolExhausted``:
    the engine must keep backpressuring (can_admit False) while full, then
    drain and re-admit with zero leaked blocks."""
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    eng = PagedSpecEngine(draft, target, ctrl, batch_size=4, max_len=256,
                          block_size=16, pool_tokens=160)   # 10 usable blocks

    def conserved(a):
        return len(a.free) + a.blocks_in_use == a.num_blocks - 1

    prompts = [[1 + i, 5, 9, 13, 17, 21, 25] for i in range(8)]
    live, i, exhausted = [], 0, False
    while i < len(prompts):
        reserve = len(prompts[i]) + 40                      # 3 blocks each
        if not eng.can_admit(reserve):
            exhausted = True
            free_slot = next(s for s in range(4) if s not in live)
            with pytest.raises(PoolExhausted):
                eng.open_stream(free_slot, prompts[i], reserve_tokens=reserve)
            # fragment: preempt the OLDEST stream's tail, then release it
            victim = live.pop(0)
            eng.dalloc.truncate(victim, 16, eng.block_size)
            eng.talloc.truncate(victim, 16, eng.block_size)
            eng.close_stream(victim)
        else:
            slot = next(s for s in range(4) if s not in live)
            eng.open_stream(slot, prompts[i], reserve_tokens=reserve)
            live.append(slot)
            i += 1
        assert conserved(eng.dalloc) and conserved(eng.talloc)
    assert exhausted, "the pool was never actually binding"
    for _ in range(3):
        eng.session_step_batch()
    for slot in live:
        eng.close_stream(slot)
    assert eng.dalloc.blocks_in_use == 0 and eng.talloc.blocks_in_use == 0
    assert conserved(eng.dalloc) and conserved(eng.talloc)
    # the drained pool admits a full-size request again
    assert eng.can_admit(len(prompts[0]) + 40)
    eng.open_stream(0, prompts[0], reserve_tokens=len(prompts[0]) + 40)
    eng.session_step_batch()
    eng.close_stream(0)
    assert eng.dalloc.blocks_in_use == 0


def test_paged_server_matches_dense_server(tiny_dense_pair):
    """Same workload through the dense and the paged server: identical
    tokens per request (greedy), so the refactor is behavior-preserving."""
    draft, target = tiny_dense_pair
    prompts = [[1, 5, 9, 13], [2, 6, 10, 14], [3, 7, 11, 15]]

    def run(paged):
        ctrl = make_controller("fixed_svip", gamma_max=4, seed=0)
        srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2,
                         paged=paged, block_size=16)
        for p in prompts:
            srv.submit(p, 12)
        srv.run_until_drained(max_ticks=500)
        return {r.request_id: r.result.tokens for r in srv.responses}

    dense, paged = run(False), run(True)
    assert dense.keys() == paged.keys()
    for rid in dense:
        n = min(len(dense[rid]), len(paged[rid]))
        assert dense[rid][:n] == paged[rid][:n]
