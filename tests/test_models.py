"""Model substrate: prefill/decode == full forward for every family; ring
caches; rollback masking; long-context window path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (EncDecConfig, MLAConfig, MoEConfig, ModelConfig,
                          RGLRUConfig, SSMConfig, VisionStubConfig)
from repro.models import transformer as T
from repro.models.cache import rollback


def _equiv(cfg, extra=None, S=24, B=2, tol=3e-4, max_len=64):
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    kw = dict(extra or {})
    h, aux = T.forward_hidden(params, cfg, toks, remat=False, **kw)
    full = T.logits_fn(params, cfg, h)
    cache, spec = T.init_cache(cfg, B, max_len, jnp.float32)
    lg1, cache = T.step(params, cfg, toks[:, :S // 2], cache, spec,
                        all_logits=True, **kw)
    lg2, cache = T.step(params, cfg, toks[:, S // 2:], cache, spec,
                        all_logits=True)
    np.testing.assert_allclose(np.asarray(lg1[:, -1]),
                               np.asarray(full[:, full.shape[1] - S + S // 2 - 1]),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(lg2[:, -1]), np.asarray(full[:, -1]),
                               rtol=tol, atol=tol)
    assert not np.isnan(np.asarray(full)).any()
    # VLM patches occupy cache positions too
    assert int(cache["pos"]) == full.shape[1]
    return params, full


def test_dense_gqa():
    _equiv(ModelConfig(name="d", arch_type="dense", num_layers=4, d_model=128,
                       num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=97,
                       qk_norm=True, qkv_bias=True))


def test_mqa_geglu():
    _equiv(ModelConfig(name="m", arch_type="dense", num_layers=3, d_model=96,
                       num_heads=4, num_kv_heads=1, head_dim=32, d_ff=192,
                       vocab_size=97, activation="geglu"))


def test_moe_mla():
    _equiv(ModelConfig(
        name="mm", arch_type="moe", num_layers=3, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=97, block_pattern=("mla",),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      num_shared_experts=1, d_shared=64, capacity_factor=4.0,
                      dense_layers=(0,)),
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32)))


def test_mamba2():
    _equiv(ModelConfig(name="mb", arch_type="ssm", num_layers=4, d_model=128,
                       num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=97,
                       block_pattern=("mamba2",),
                       ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=8)),
           tol=1e-3)


def test_hybrid_rglru_local():
    _equiv(ModelConfig(name="hy", arch_type="hybrid", num_layers=5,
                       d_model=128, num_heads=4, num_kv_heads=1, d_ff=256,
                       vocab_size=97, block_pattern=("rglru", "rglru", "local"),
                       window=8, rglru=RGLRUConfig(lru_width=128)), tol=1e-3)


def test_encdec_audio():
    cfg = ModelConfig(name="ed", arch_type="audio", num_layers=3, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=97,
                      encdec=EncDecConfig(num_encoder_layers=2,
                                          frontend_dim=48, frontend_len=12))
    frames = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 48))
    _equiv(cfg, extra={"frame_embeds": frames})


def test_vlm():
    cfg = ModelConfig(name="vl", arch_type="vlm", num_layers=3, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=97,
                      vision=VisionStubConfig(vit_dim=32, num_patches=6,
                                              projector_hidden=64))
    patches = jax.random.normal(jax.random.PRNGKey(4), (2, 6, 32))
    _equiv(cfg, extra={"patch_embeds": patches})


def test_ring_cache_long_context():
    """Sliding-window ring cache must equal full cache within the window."""
    cfg = ModelConfig(name="lc", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=61,
                      long_context_window=16, max_full_cache_len=32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 48), 0, 61)
    # ring path (max_len beyond max_full_cache_len -> window 16 + slack)
    cache, spec = T.init_cache(cfg, 1, 64, jnp.float32)
    assert spec.layers[0].ring and spec.layers[0].window == 16
    lg_ring, cache = T.step(params, cfg, toks, cache, spec, all_logits=True)
    # reference: windowed attention, full cache
    cfg_w = cfg.replace(block_pattern=("local",), window=16)
    params_w = params
    cache2, spec2 = T.init_cache(cfg_w, 1, 64, jnp.float32)
    lg_win, _ = T.step(params_w, cfg_w, toks, cache2, spec2, all_logits=True)
    np.testing.assert_allclose(np.asarray(lg_ring[:, -8:]),
                               np.asarray(lg_win[:, -8:]), atol=3e-4, rtol=3e-4)


def test_rollback_pointer_masks_stale_entries():
    cfg = ModelConfig(name="rb", arch_type="dense", num_layers=2, d_model=64,
                      num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=61)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = list(range(1, 13))
    cache, spec = T.init_cache(cfg, 1, 64, jnp.float32)
    lg, cache = T.step(params, cfg, jnp.asarray([toks[:8]], jnp.int32), cache, spec)
    # advance 4 garbage tokens then roll back
    _, cache_g = T.step(params, cfg, jnp.asarray([[7, 7, 7, 7]], jnp.int32),
                        cache, spec)
    cache_rb = rollback(cache_g, 8)
    lg_a, _ = T.step(params, cfg, jnp.asarray([toks[8:10]], jnp.int32),
                     cache_rb, spec, all_logits=True)
    lg_b, _ = T.step(params, cfg, jnp.asarray([toks[8:10]], jnp.int32),
                     cache, spec, all_logits=True)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               atol=2e-5, rtol=2e-5)


def test_scan_vs_unrolled_layers_identical():
    kw = dict(name="sc", arch_type="dense", num_layers=6, d_model=64,
              num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=61)
    cfg_s = ModelConfig(**kw, scan_layers=True)
    cfg_u = ModelConfig(**kw, scan_layers=False)
    params = T.init_params(cfg_s, jax.random.PRNGKey(0))
    # re-layout stacked params into the unrolled structure
    from repro.models.transformer import layer_grouping
    g = layer_grouping(cfg_s)
    assert g.n_cycles == 6
    unrolled_layers = {"prefix": [
        jax.tree.map(lambda a: a[i], params["layers"]["stack"])["0"]
        for i in range(6)], "tail": [], "stack": None}
    params_u = {**params, "layers": unrolled_layers}
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 61)
    h_s, _ = T.forward_hidden(params, cfg_s, toks, remat=False)
    h_u, _ = T.forward_hidden(params_u, cfg_u, toks, remat=False)
    np.testing.assert_allclose(np.asarray(h_s), np.asarray(h_u),
                               atol=2e-5, rtol=2e-5)
