"""End-to-end behaviour: trained draft/target pair + TapOut beats naive
configurations on the synthetic corpus, with exact output equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ar_greedy_decode

# trains two models + compiles full engines: excluded from the fast CI lane
pytestmark = pytest.mark.slow
from repro.configs.registry import paper_pair
from repro.core import ModelBundle, SpecEngine, StaticGamma, make_controller
from repro.data.synthetic import DATASET_MIX, SyntheticCorpus
from repro.models import transformer as T
from repro.training.optimizer import OptConfig
from repro.training.train_loop import train


@pytest.fixture(scope="module")
def trained_pair():
    """Draft (1L) + target (3L) trained briefly on code-heavy data."""
    corpus = SyntheticCorpus(seed=0)
    dcfg, tcfg = paper_pair("llama-1b-8b")
    dcfg = dcfg.replace(num_layers=1, d_model=96, num_heads=2, num_kv_heads=1,
                        d_ff=192)
    tcfg = tcfg.replace(num_layers=3, d_model=160, num_heads=4, num_kv_heads=2,
                        d_ff=320)
    mix = {"code": 0.7, "prose": 0.3}
    opt = OptConfig(lr=3e-3, warmup_steps=20, total_steps=120)
    dp = train(dcfg, T.init_params(dcfg, jax.random.PRNGKey(0)),
               corpus.training_batches(seq_len=96, batch_size=8, mix=mix, seed=1),
               opt, steps=120, log_every=60)["params"]
    tp = train(tcfg, T.init_params(tcfg, jax.random.PRNGKey(1)),
               corpus.training_batches(seq_len=96, batch_size=8, mix=mix, seed=2),
               opt, steps=120, log_every=60)["params"]
    return ModelBundle(dp, dcfg), ModelBundle(tp, tcfg), corpus


def test_trained_pair_has_useful_acceptance(trained_pair):
    draft, target, corpus = trained_pair
    prompts = corpus.prompts("humaneval", 4, seed=42)
    eng = SpecEngine(draft, target, StaticGamma(gamma=6), max_len=512)
    rates = []
    for _, ids in prompts:
        r = eng.generate(ids[:48], 64)
        rates.append(r.accept_rate)
    # a trained same-domain draft must do far better than chance
    assert np.mean(rates) > 0.3, rates


def test_tapout_exact_and_competitive(trained_pair):
    draft, target, corpus = trained_pair
    prompts = corpus.prompts("humaneval", 3, seed=43)
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=12, seed=0)
    eng = SpecEngine(draft, target, ctrl, max_len=512)
    static = SpecEngine(draft, target, StaticGamma(gamma=6), max_len=512)
    cost_tap, cost_sta, toks = 0.0, 0.0, 0
    for _, ids in prompts:
        ref = ar_greedy_decode(target.params, target.cfg, ids[:48], 48)
        r = eng.generate(ids[:48], 48)
        assert r.tokens[:len(ref)] == ref[:len(r.tokens)]   # exactness
        s = static.generate(ids[:48], 48)
        cost_tap += r.modeled_cost / max(r.new_tokens, 1)
        cost_sta += s.modeled_cost / max(s.new_tokens, 1)
        toks += r.new_tokens
    assert toks > 0
    # TapOut should be within 1.5x of static cost even on tiny runs, and the
    # bandit must have visited all arms at least the init round
    assert cost_tap < 1.5 * cost_sta
    assert (ctrl.bandit.counts > 0).all()


def test_arm_values_in_unit_interval(trained_pair):
    draft, target, corpus = trained_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=8, seed=1)
    eng = SpecEngine(draft, target, ctrl, max_len=512)
    for _, ids in corpus.prompts("mt_bench", 2, seed=44):
        eng.generate(ids[:48], 40)
    v = ctrl.arm_values
    assert v.shape == (5,)
    assert (v >= 0).all() and (v <= 1).all()
