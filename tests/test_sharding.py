"""Sharding rules + a REAL multi-device integration test (subprocess with 8
forced host devices running an actual sharded train step numerically)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.shardings import cache_spec, param_spec
from repro.models.sharding import resolve_spec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_spec_rules():
    assert param_spec("embed", (1000, 64)) == ("model", ("pod", "data"))
    assert param_spec("layers/prefix/0/mixer/wq", (64, 128)) == \
        (("pod", "data"), "model")
    assert param_spec("layers/stack/0/ffn/experts/w_in", (4, 8, 64, 128))[0] is None
    assert param_spec("layers/prefix/0/norm1", (64,)) == (None,)
    assert param_spec("layers/tail/1/ffn/w_out", (256, 64)) == \
        ("model", ("pod", "data"))


def test_cache_spec_rules():
    # GQA with 16-divisible heads: shard heads
    assert cache_spec("layers/prefix/0/k", (8, 1024, 16, 128))[2] == "model"
    # MQA: shard sequence instead
    assert cache_spec("layers/prefix/0/k", (8, 1024, 1, 128))[1] == "model"
    assert cache_spec("layers/prefix/0/pos", (1024,)) == (None,)
    assert cache_spec("layers/prefix/0/ssm", (8, 16, 32, 64))[1] == "model"


def test_resolve_spec_drops_indivisible():
    import jax
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    spec = resolve_spec(FakeMesh(), [("pod", "data"), "model"], (8, 6))
    # pod missing -> dropped; data divides 8; model=2 divides 6
    assert spec[0] == "data" and spec[1] == "model"
    spec2 = resolve_spec(FakeMesh(), ["data", "model"], (6, 5))
    assert spec2[0] is None and spec2[1] is None  # 6%4, 5%2


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import smoke_config
    from repro.launch.shardings import batch_shardings, params_shardings
    from repro.models import transformer as T
    from repro.models.sharding import use_mesh
    from repro.training.optimizer import OptConfig, init_opt_state
    from repro.training.train_loop import make_train_step

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = smoke_config("qwen3-moe-235b-a22b").replace(vocab_size=512)
    with use_mesh(mesh):
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 512)}
        batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
        psh = params_shardings(mesh, params)
        params = jax.device_put(params, psh)
        step = make_train_step(cfg, OptConfig(lr=1e-3, total_steps=5),
                               remat=True, donate=False)
        fn = jax.jit(step)
        losses = []
        for _ in range(3):
            params, opt, m = fn(params, opt, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0], losses
        # params really are distributed
        leaf = jax.tree.leaves(params)[3]
        assert len(leaf.sharding.device_set) >= 1
        print("MULTIDEV_OK", losses)
""")


@pytest.mark.slow
def test_multidevice_sharded_train_step():
    """8 forced host devices, (2,4) mesh, sharded MoE train steps converge."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", _MULTIDEV], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + "\n" + r.stderr
