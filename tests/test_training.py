"""Training substrate: loss goes down, chunked CE correctness, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SyntheticCorpus
from repro.models import ModelConfig
from repro.models import transformer as T
from repro.models.common import cross_entropy_with_logits
from repro.training.checkpoint import (checkpoint_exists, load_checkpoint,
                                       save_checkpoint)
from repro.training.losses import chunked_ce_loss
from repro.training.optimizer import OptConfig, init_opt_state, lr_at
from repro.training.train_loop import make_train_step, train

CFG = ModelConfig(name="tt", arch_type="dense", num_layers=2, d_model=64,
                  num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=259)


def test_loss_decreases():
    corpus = SyntheticCorpus(seed=0)
    batches = corpus.training_batches(seq_len=64, batch_size=8, seed=1)
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    out = train(CFG, params, batches,
                OptConfig(lr=3e-3, warmup_steps=10, total_steps=60),
                steps=60, log_every=10)
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8
    assert np.isfinite(hist[-1]["grad_norm"])


def test_chunked_ce_matches_full():
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 259)
    labels = jnp.roll(toks, -1, 1)
    h, _ = T.forward_hidden(params, CFG, toks, remat=False)
    full = cross_entropy_with_logits(T.logits_fn(params, CFG, h), labels)
    chunked = chunked_ce_loss(params, CFG, h, labels, chunk=7)  # ragged chunk
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)


def test_chunked_ce_respects_mask():
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 259)
    labels = jnp.roll(toks, -1, 1)
    h, _ = T.forward_hidden(params, CFG, toks, remat=False)
    mask = jnp.arange(16)[None, :] < 8
    m1 = chunked_ce_loss(params, CFG, h, labels, mask=jnp.broadcast_to(mask, (2, 16)), chunk=4)
    full = cross_entropy_with_logits(T.logits_fn(params, CFG, h[:, :8]),
                                     labels[:, :8])
    np.testing.assert_allclose(float(m1), float(full), rtol=1e-5)


def test_lr_schedule():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr_at(cfg, jnp.int32(100))) <= 1.01e-4 + 1e-9


def test_checkpoint_roundtrip(tmp_path):
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, {"step": 3})
    assert checkpoint_exists(path)
    restored = load_checkpoint(path, jax.tree.map(jnp.zeros_like, params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_eagle_head_trains_and_roundtrips(tmp_path):
    """~20 steps of EAGLE-head training on the frozen target's hidden
    states strictly reduces the loss, and a checkpoint save/load reproduces
    bit-identical head logits (docs/drafters.md)."""
    from repro.core import (ModelBundle, eagle_head_logits,
                            eagle_logit_params, load_eagle_head,
                            save_eagle_head, train_eagle_head)
    target = ModelBundle(T.init_params(CFG, jax.random.PRNGKey(0)), CFG)
    corpus = SyntheticCorpus(seed=0)
    out = train_eagle_head(
        target, corpus.training_batches(seq_len=48, batch_size=4, seed=2),
        steps=20, opt_cfg=OptConfig(lr=3e-3, warmup_steps=5, total_steps=20))
    hist = out["history"]
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert hist[-1]["loss"] < hist[0]["loss"]

    path = os.path.join(tmp_path, "eagle_head")
    save_eagle_head(path, out["head"], out["head_cfg"], hist)
    head_cfg, head2 = load_eagle_head(path, CFG)
    probe = jax.random.normal(jax.random.PRNGKey(3), (1, 8, CFG.d_model))
    lp = eagle_logit_params(target.params)
    lg1 = eagle_head_logits(out["head"], head_cfg, lp, probe)
    lg2 = eagle_head_logits(head2, head_cfg, lp, probe)
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))


def test_mixed_precision_step_finite():
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    step = make_train_step(CFG, OptConfig(lr=1e-3, total_steps=10),
                           remat=False, compute_dtype=jnp.bfloat16,
                           donate=False)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 259)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    p2, o2, m = jax.jit(step)(params, init_opt_state(params), batch)
    assert np.isfinite(float(m["loss"]))
    # master params stay f32
    assert jax.tree.leaves(p2)[0].dtype == jnp.float32
