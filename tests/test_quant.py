"""Quantized inference subsystem: weight/KV round-trip error bounds,
structure of quantized param pytrees, int8-KV paged==dense parity, the
int8 SpecServer vs the fp target's argmax decode, and precision as a
bandit cost axis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import ar_greedy_decode
from conftest import drain_streams as _drain

from repro.core import (SpecEngine, TapOutTreeSequence, TreeSpecEngine,
                        chain_shape, default_pool, make_controller,
                        quantized_bundle, quantized_shape)
from repro.core.engine import BatchedSpecEngine, PagedSpecEngine
from repro.core.rewards import (modeled_session_cost, precision_cost_factor,
                                r_cost_adjusted)
from repro.models import ModelConfig, MoEConfig
from repro.models import transformer as T
from repro.models.quant import (dequantize_rows, dequantize_weight,
                                is_quantized, qmatmul, quantize_params,
                                quantize_rows, quantize_weight)
from repro.serving.engine import SpecServer

PROMPTS = [[1, 5, 9, 13],
           [2, 6, 10, 14, 18, 22, 26],
           [3, 7, 11, 15, 19, 23, 27, 31]]


# ------------------------------------------------------------- numerics

def test_weight_quant_roundtrip_error_bound():
    """|dequant(quant(w)) - w| <= scale/2 elementwise (symmetric rounding;
    scale is per OUTPUT channel)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (96, 64)) * 3.0
    qw = quantize_weight(w)
    assert qw["qw"].dtype == jnp.int8 and qw["scale"].shape == (64,)
    err = np.abs(np.asarray(dequantize_weight(qw) - w))
    bound = np.asarray(qw["scale"])[None, :] / 2 + 1e-6
    assert (err <= bound).all()


def test_qmatmul_equals_dequant_matmul():
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    w = jax.random.normal(ks[0], (32, 48))
    x = jax.random.normal(ks[1], (4, 32))
    qw = quantize_weight(w)
    np.testing.assert_allclose(np.asarray(qmatmul(x, qw)),
                               np.asarray(x @ dequantize_weight(qw)),
                               atol=1e-5, rtol=1e-5)
    # raw weights pass through untouched
    np.testing.assert_array_equal(np.asarray(qmatmul(x, w)),
                                  np.asarray(x @ w))


def test_kv_row_roundtrip_error_bound():
    """Int8 KV round trip: per-row-per-head scales bound the error by
    amax/254 per element."""
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 17, 3, 16)) * 5.0
    q, scale = quantize_rows(x)
    assert q.dtype == jnp.int8 and scale.shape == (2, 17, 3)
    err = np.abs(np.asarray(dequantize_rows(q, scale) - x))
    bound = np.asarray(scale)[..., None] / 2 + 1e-6
    assert (err <= bound).all()


def test_quantize_params_structure():
    """Linear weights become {qw, scale}; embeddings, norms and MoE expert
    banks stay raw arrays."""
    cfg = ModelConfig(name="q", arch_type="moe", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=17,
                      moe=MoEConfig(num_experts=2, top_k=1, d_expert=32))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(params)
    assert not is_quantized(qp["embed"]) and qp["embed"].dtype != jnp.int8
    blk = (qp["layers"]["prefix"] or [None])[0] or \
        jax.tree.map(lambda a: a[0], qp["layers"]["stack"])["0"]
    assert is_quantized(blk["mixer"]["wq"])
    assert blk["norm1"].dtype != jnp.int8
    # MoE layer: router + expert banks untouched (gathered by index)
    moe_blk = None
    for part in ("prefix", "tail"):
        for b in qp["layers"][part]:
            if "ffn" in b and "experts" in b["ffn"]:
                moe_blk = b
    if moe_blk is None and qp["layers"]["stack"] is not None:
        cyc = jax.tree.map(lambda a: a[0], qp["layers"]["stack"])
        for j in cyc.values():
            if "ffn" in j and "experts" in j["ffn"]:
                moe_blk = j
    assert moe_blk is not None
    assert not is_quantized(moe_blk["ffn"]["experts"]["w_in"])
    assert not is_quantized(moe_blk["ffn"]["router"])


def test_cost_model_precision_axis():
    assert precision_cost_factor("int8") < precision_cost_factor("bf16")
    c_fp = modeled_session_cost(5, 10.0, 100.0)
    c_q = modeled_session_cost(5, 10.0, 100.0, precision="int8")
    assert c_q < c_fp
    # cost-adjusted reward favors the cheaper arm at equal acceptance
    # (rel_cost is >= 1, relative to the pool's cheapest arm) and never
    # needs clipping
    assert r_cost_adjusted(3, 4, 8, rel_cost=1.0) > r_cost_adjusted(
        3, 4, 8, rel_cost=1.0 / 0.55)
    assert r_cost_adjusted(8, 8, 8, rel_cost=1.0) <= 1.0


def test_quantized_bundle_scales_cost(tiny_dense_pair):
    draft, _ = tiny_dense_pair
    qb = quantized_bundle(draft)
    assert qb.cost_per_token == pytest.approx(
        draft.cost_per_token * precision_cost_factor("int8"))
    layers = qb.params["layers"]
    blk = (layers["prefix"][0] if layers["prefix"] else
           jax.tree.map(lambda a: a[0], layers["stack"],
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))["0"])
    assert is_quantized(blk["mixer"]["wq"])


# ------------------------------------------------------- int8 KV parity

def test_int8_kv_paged_matches_dense_batched(tiny_dense_pair):
    """Dense batched and paged engines quantize identical rows identically,
    so under kv_dtype="int8" they stay token-for-token equal — the paged==
    dense invariant survives quantization."""
    draft, target = tiny_dense_pair
    max_new = 16
    dense = BatchedSpecEngine(
        draft, target, make_controller("fixed_svip", gamma_max=4, seed=0),
        batch_size=3, max_len=256, kv_dtype="int8")
    paged = PagedSpecEngine(
        draft, target, make_controller("fixed_svip", gamma_max=4, seed=0),
        batch_size=3, max_len=256, block_size=16, kv_dtype="int8")
    dstates = _drain(dense, PROMPTS, max_new)
    pstates = _drain(paged, PROMPTS, max_new)
    for dst, pst in zip(dstates, pstates):
        n = min(len(dst["seq"]), len(pst["seq"]))
        assert dst["seq"][:n] == pst["seq"][:n]


def test_int8_kv_single_stream_matches_fp_argmax(tiny_dense_pair):
    """Greedy speculative decoding under int8 KV must still produce the
    (fp) target's argmax decode — per-row scales keep the logit
    perturbation below the argmax margins of a trained/structured model."""
    draft, target = tiny_dense_pair
    eng = SpecEngine(draft, target,
                     make_controller("fixed_svip", gamma_max=4, seed=0),
                     max_len=256, kv_dtype="int8")
    for p in PROMPTS[:2]:
        ref = ar_greedy_decode(target.params, target.cfg, p, 20)
        out = eng.generate(p, 20).tokens
        n = min(len(ref), len(out))
        assert out[:n] == ref[:n]


def test_server_int8_quant_draft_matches_fp_argmax(tiny_dense_pair):
    """ISSUE acceptance: SpecServer(kv_dtype="int8", quant_draft=True)
    drains a multi-stream workload on the paged path with greedy outputs
    matching the bf16/fp target's argmax decode."""
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=4, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2,
                     paged=True, block_size=16, kv_dtype="int8",
                     quant_draft=True)
    ids = [srv.submit(p, 16) for p in PROMPTS]
    responses = srv.run_until_drained(max_ticks=500)
    assert {r.request_id for r in responses} == set(ids)
    for r in responses:
        req = srv.requests[r.request_id]
        ref = ar_greedy_decode(target.params, target.cfg, req.prompt, 16)
        n = min(len(ref), len(r.result.tokens))
        assert r.result.tokens[:n] == ref[:n]


# ------------------------------------------------------- precision arms

def test_tree_engine_precision_arm(tiny_dense_pair):
    """An int8-draft chain arm runs inside the shape bandit and exposes a
    cheaper modeled cost than its bf16 twin at the same session shape."""
    draft, target = tiny_dense_pair
    stop = default_pool()[1]
    shapes = [chain_shape(stop), quantized_shape(chain_shape(stop))]
    assert shapes[1].precision == "int8"
    ctrl = TapOutTreeSequence(4, "ucb1", "cost", shapes, seed=0)
    eng = TreeSpecEngine(draft, target, ctrl, max_len=256)
    assert "int8" in eng._draft_variants
    assert (eng._draft_variants["int8"].cost_per_token
            < draft.cost_per_token)
    r = eng.generate(PROMPTS[0], 12)
    assert r.new_tokens >= 12
    assert ctrl.shape_pulls.sum() == len(r.sessions)
    # both arms were explored and the int8 arm's sessions were cheaper per
    # drafted token by construction of the cost model
    assert (ctrl.shape_pulls > 0).all()
