"""Hypothesis property tests over the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bandits import UCB1, ThompsonBeta, UCBTuned
from repro.core.rewards import r_blend, r_simple
from repro.core.arms import update_adaedl_lambda
from repro.data.tokenizer import ByteTokenizer


# ------------------------------------------------------------- bandits

@given(st.lists(st.tuples(st.integers(0, 4), st.floats(0, 1)), min_size=1,
                max_size=200))
@settings(max_examples=50, deadline=None)
def test_bandit_state_invariants(updates):
    b = UCB1(5)
    for arm, r in updates:
        b.update(arm, r)
    assert b.t == len(updates)
    assert b.counts.sum() == len(updates)
    assert np.all(b.means >= -1e-9) and np.all(b.means <= 1 + 1e-9)
    for a in range(5):
        assert 0 <= b.variance(a) <= 0.25 + 1e-6 or b.counts[a] < 2


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 1)), min_size=3,
                max_size=300))
@settings(max_examples=50, deadline=None)
def test_beta_ts_posterior_consistent(updates):
    b = ThompsonBeta(3)
    for arm, r in updates:
        b.update(arm, float(r))
    # posterior mean = (1 + successes) / (2 + pulls)
    for a in range(3):
        succ = sum(r for arm, r in updates if arm == a)
        n = sum(1 for arm, _ in updates if arm == a)
        assert abs(b.arm_values[a] - (1 + succ) / (2 + n)) < 1e-9


@given(st.integers(1, 64), st.integers(0, 64), st.integers(1, 128))
@settings(max_examples=100, deadline=None)
def test_reward_bounds_and_monotonicity(n_drafted_raw, m_raw, gamma):
    # engine invariant: m <= n_drafted <= gamma_max
    n_drafted = min(n_drafted_raw, gamma)
    m = min(m_raw, n_drafted)
    for fn in (r_simple, r_blend):
        r = fn(m, n_drafted, gamma)
        assert -1e-9 <= r <= 1 + 1e-9
    # blend is monotone in accepted count
    if m + 1 <= n_drafted:
        assert r_blend(m + 1, n_drafted, gamma) >= r_blend(m, n_drafted, gamma)
    # r_simple ignores n_drafted entirely (incomplete proxy, paper 4.1.2)
    assert r_simple(m, n_drafted, gamma) == r_simple(m, n_drafted * 2, gamma)


@given(st.floats(0, 1), st.floats(0, 1), st.integers(0, 32), st.integers(1, 32))
@settings(max_examples=100, deadline=None)
def test_adaedl_lambda_stays_bounded(lam, ema, n_acc_raw, n_drafted):
    n_acc = min(n_acc_raw, n_drafted)
    lam2, ema2 = update_adaedl_lambda(lam, ema, n_acc, n_drafted)
    assert 0.0 <= lam2 <= 1.0
    assert 0.0 <= ema2 <= 1.0


# ------------------------------------------------- drafter-as-arm bandit

@given(st.lists(st.tuples(st.integers(0, 14),
                          st.lists(st.tuples(st.integers(0, 6),
                                             st.integers(0, 6)),
                                   min_size=1, max_size=4)),
                min_size=1, max_size=30),
       st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_drafter_shape_batch_updates_order_independent(ticks, seed):
    """Batched bandit updates over the (drafter x stop-rule) arm pool are
    order-independent WITHIN a tick: permuting the lanes of every
    ``update_shape_batch`` call leaves the meta-bandit's counts and
    AdaEDL's pooled lambda bit-identical, and the merged means equal to
    float tolerance (Chan's merge reorders float sums)."""
    from repro.core.arms import default_drafter_pool
    from repro.core.controller import TapOutTreeSequence

    def run(permute):
        rng = np.random.default_rng(seed)
        c = TapOutTreeSequence(6, "ucb1", "simple",
                               shapes=default_drafter_pool(6), seed=0)
        for shape_idx, lanes in ticks:
            nd = np.array([max(d, 1) for d, _ in lanes], np.int64)
            na = np.minimum(np.array([a for _, a in lanes], np.int64), nd)
            if permute:
                p = rng.permutation(nd.size)
                nd, na = nd[p], na[p]
            c.update_shape_batch(shape_idx, nd, na)
        return c

    a, b = run(False), run(True)
    sa, sb = a.bandit.state_dict(), b.bandit.state_dict()
    assert sa["t"] == sb["t"]
    np.testing.assert_array_equal(sa["counts"], sb["counts"])
    np.testing.assert_allclose(sa["means"], sb["means"])
    np.testing.assert_allclose(sa["m2"], sb["m2"], atol=1e-12)
    assert a.lam == b.lam and a._accept_ema == b._accept_ema


@given(st.integers(0, 10_000), st.sampled_from(["kv", "eagle", "ssd"]))
@settings(max_examples=10, deadline=None)
def test_pull_share_converges_to_forced_best_drafter(seed, best):
    """Under synthetic rewards where ONE drafter's arms accept far more,
    the meta-bandit's empirical pull share converges to that drafter."""
    from repro.core.arms import default_drafter_pool
    from repro.core.controller import TapOutTreeSequence
    c = TapOutTreeSequence(6, "ucb1", "simple",
                           shapes=default_drafter_pool(6), seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(400):
        i = c.begin_shape()
        p = 0.8 if c.shapes[i].drafter == best else 0.2
        c.update_shape(i, 6, int(rng.binomial(6, p)))
    pulls = c.drafter_pulls
    assert pulls[best] / sum(pulls.values()) > 0.5, pulls


# ------------------------------------------------------------- tokenizer

@given(st.text(max_size=200))
@settings(max_examples=100, deadline=None)
def test_tokenizer_roundtrip(s):
    tok = ByteTokenizer()
    ids = tok.encode(s, bos=True, eos=True)
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert tok.decode(ids) == s.encode("utf-8", errors="replace").decode(
        "utf-8", errors="replace")
    assert all(0 <= i < tok.vocab_size for i in ids)


# ------------------------------------------------------------- MoE routing

@given(st.integers(1, 4), st.integers(2, 16), st.data())
@settings(max_examples=20, deadline=None)
def test_moe_dispatch_positions_unique(G, S, data):
    """No two kept (token,k) assignments share an (expert, slot)."""
    import jax, jax.numpy as jnp
    from repro.models import ModelConfig, MoEConfig
    from repro.models.moe import init_moe, moe_ffn
    E = data.draw(st.sampled_from([2, 4]))
    cfg = ModelConfig(name="p", arch_type="moe", num_layers=1, d_model=16,
                      num_heads=1, num_kv_heads=1, d_ff=32, vocab_size=11,
                      moe=MoEConfig(num_experts=E, top_k=min(2, E),
                                    d_expert=16, capacity_factor=1.0))
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(data.draw(st.integers(0, 100))),
                          (G, S, 16))
    y, aux = moe_ffn(params, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0


def _moe_cfg(E=4, K=2, d=16, decode_gather=False):
    from repro.models import ModelConfig, MoEConfig
    return ModelConfig(name="p", arch_type="moe", num_layers=1, d_model=d,
                       num_heads=1, num_kv_heads=1, d_ff=32, vocab_size=11,
                       moe=MoEConfig(num_experts=E, top_k=K, d_expert=16,
                                     decode_gather=decode_gather))


@given(st.integers(1, 3), st.integers(2, 6), st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_combine_conserves_gate_weights(G, S, seed):
    """With ample capacity (zero drops) the batched scatter/dispatch path
    applies EXACTLY the normalized top-k gate weights: its output matches
    the per-token decode-gather path (which multiplies gates directly,
    with no capacity concept) to float tolerance — gate mass is conserved
    through buffer scatter, expert einsum, and gather/combine."""
    import jax
    from repro.models.moe import init_moe, moe_ffn
    cfg = _moe_cfg(decode_gather=True)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, S, 16))
    # G*S*K >= E -> dispatch path; capacity_factor=E -> C == S*K, no drops
    y, aux = moe_ffn(params, cfg, x, capacity_factor=float(cfg.moe.num_experts))
    assert float(aux["moe_drop_frac"]) == 0.0
    for g in range(G):
        for s in range(S):
            yt, _ = moe_ffn(params, cfg, x[g:g + 1, s:s + 1])  # gather path
            np.testing.assert_allclose(np.asarray(y[g, s]),
                                       np.asarray(yt[0, 0]),
                                       atol=1e-5, rtol=1e-5)


@given(st.integers(2, 12), st.floats(0.25, 2.0), st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_moe_capacity_drop_frac_matches_analytic(S, cf, seed):
    """S identical tokens route identically, so each chosen expert keeps
    exactly min(S, C) of its S assignments and the reported drop fraction
    equals the analytic 1 - min(S, C)/S; distinct-experts-hit is exactly
    top_k."""
    import jax
    import jax.numpy as jnp
    from repro.models.moe import init_moe, moe_ffn
    cfg = _moe_cfg()
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    params = init_moe(jax.random.PRNGKey(0), cfg)
    row = jax.random.normal(jax.random.PRNGKey(seed), (1, 1, 16))
    x = jnp.broadcast_to(row, (1, S, 16))
    _, aux = moe_ffn(params, cfg, x, capacity_factor=cf)
    C = max(1, min(int(S * K * cf / E + 0.999), S * K))
    expect = 1.0 - min(S, C) / S
    assert abs(float(aux["moe_drop_frac"]) - expect) < 1e-6
    assert float(aux["moe_experts_hit"][0]) == K


@given(st.integers(2, 4), st.integers(2, 6), st.integers(0, 50), st.data())
@settings(max_examples=20, deadline=None)
def test_moe_routing_group_permutation_equivariant(G, S, seed, data):
    """Routing is independent per group: permuting the group axis permutes
    the outputs and the per-group experts-hit channel, and leaves every
    scalar aux (losses, drop fraction) invariant."""
    import jax
    import jax.numpy as jnp
    from repro.models.moe import init_moe, moe_ffn
    cfg = _moe_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, S, 16))
    perm = np.asarray(data.draw(st.permutations(range(G))))
    y, aux = moe_ffn(params, cfg, x)
    yp, auxp = moe_ffn(params, cfg, jnp.asarray(np.asarray(x)[perm]))
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y)[perm],
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(auxp["moe_experts_hit"]),
                                  np.asarray(aux["moe_experts_hit"])[perm])
    for k in ("moe_aux_loss", "moe_z_loss", "moe_drop_frac"):
        np.testing.assert_allclose(float(auxp[k]), float(aux[k]), atol=1e-6)


# ------------------------------------------------------------- paged cache

@given(st.data())
@settings(max_examples=15, deadline=None)
def test_paged_kernel_matches_dense_reference(data):
    """Paged flash-decode == dense reference for arbitrary ragged lengths,
    shuffled block tables, sliding windows, and post-rollback states
    (lengths truncated below the rows actually written)."""
    import jax, jax.numpy as jnp
    from repro.kernels import ops, ref
    ops.FORCE_INTERPRET = True
    B = data.draw(st.integers(1, 3), label="B")
    G = data.draw(st.sampled_from([1, 2]), label="G")
    H = G * data.draw(st.sampled_from([1, 2]), label="rep")
    bs = data.draw(st.sampled_from([4, 8]), label="bs")
    MB = data.draw(st.integers(2, 4), label="MB")
    D = 16
    window = data.draw(st.sampled_from([0, 0, 5]), label="window")
    N = B * MB + 1
    ks = jax.random.split(jax.random.PRNGKey(data.draw(
        st.integers(0, 1000), label="seed")), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kpool = jax.random.normal(ks[1], (N, bs, G, D))
    vpool = jax.random.normal(ks[2], (N, bs, G, D))
    # shuffled, non-overlapping tables; ragged lengths simulate rollback:
    # every allocated row exists in the pool, lengths may sit mid-block
    perm = np.random.default_rng(
        data.draw(st.integers(0, 1000), label="perm")).permutation(
            np.arange(1, N))
    tables = np.zeros((B, MB), np.int32)
    lengths = np.zeros((B,), np.int32)
    pi = 0
    for b in range(B):
        lengths[b] = data.draw(st.integers(1, MB * bs), label=f"len{b}")
        nb = -(-int(lengths[b]) // bs)
        tables[b, :nb] = perm[pi:pi + nb]
        pi += nb
    out = ops.paged_decode_attention(q, kpool, vpool, jnp.asarray(tables),
                                     jnp.asarray(lengths), window=window)
    exp = ref.paged_decode_attention_ref(q, kpool, vpool, jnp.asarray(tables),
                                         jnp.asarray(lengths), window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


@given(st.lists(st.tuples(st.integers(1, 48), st.integers(0, 2)),
                min_size=1, max_size=16),
       st.integers(2, 12), st.integers(4, 16))
@settings(max_examples=50, deadline=None)
def test_allocator_conservation_under_churn(events, num_blocks_x, bs):
    """Arbitrary admit/truncate/release churn conserves blocks
    (``free + in_use == num_blocks - 1`` after EVERY mutation), never
    double-books a physical block, never hands out the trash block, and
    keeps ``peak_in_use`` an exact running max.  Requests too large for
    the table width raise ``ValueError`` instead of silently clamping."""
    from repro.models.cache import BlockAllocator, PoolExhausted
    num_blocks = num_blocks_x
    a = BlockAllocator(num_blocks=num_blocks, max_blocks=8, batch=4)
    live = set()
    running_peak = 0

    def check():
        owned = [b for s in range(4) for b in a.owned[s]]
        assert 0 not in owned
        assert len(owned) == len(set(owned))          # no double-booking
        assert len(a.free) + a.blocks_in_use == num_blocks - 1
        assert len(owned) == a.blocks_in_use          # no sharing here
        assert a.peak_in_use == running_peak

    for tokens, action in events:
        slot = tokens % 4
        if slot in live and action == 1:
            a.release(slot)
            live.discard(slot)
        elif slot in live and action == 2:
            a.truncate(slot, tokens, bs)
            if not a.owned[slot]:
                live.discard(slot)
        elif slot not in live:
            try:
                a.allocate(slot, a.blocks_for(tokens, bs))
                live.add(slot)
            except ValueError:
                assert -(-tokens // bs) > a.max_blocks
            except PoolExhausted:
                pass
        running_peak = max(running_peak, a.blocks_in_use)
        check()


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(2, 40),
                          st.integers(0, 2)),
                min_size=1, max_size=20),
       st.integers(0, 1000))
@settings(max_examples=50, deadline=None)
def test_cow_safety_under_admit_draft_rollback_evict(events, seed):
    """COW safety: over random admit/draft-write/rollback/release/evict
    sequences with prefix sharing, a slot's write frontier (positions the
    draft/target may speculatively write, then roll back) NEVER overlaps a
    block with refcount > 1 or an immutable cached block — so rollback,
    which only rewinds lengths, cannot be observed by any other stream.
    Conservation holds throughout, cache references included."""
    from repro.models.cache import (BlockAllocator, PoolExhausted,
                                    PrefixCache)
    bs, B = 4, 4
    rng = np.random.default_rng(seed)
    dalloc = BlockAllocator(num_blocks=24, max_blocks=12, batch=B)
    talloc = BlockAllocator(num_blocks=24, max_blocks=12, batch=B)
    pc = PrefixCache(bs, (dalloc, talloc))
    # a small prompt pool so admissions actually collide on prefixes
    prompts = [rng.integers(1, 9, size=n).tolist()
               for n in rng.integers(6, 20, size=3)]
    live = {}                                     # slot -> prompt length

    def check():
        for a in (dalloc, talloc):
            assert len(a.free) + a.blocks_in_use == a.num_blocks - 1
        for slot, P in live.items():
            for a, first in ((dalloc, P - 2), (talloc, P - 1)):
                for idx in range(first // bs, len(a.owned[slot])):
                    blk = a.owned[slot][idx]
                    assert a.refcount[blk] == 1 and not a.immutable[blk], \
                        f"slot {slot} frontier block {blk} is shared"

    for slot, x, action in events:
        if slot in live and action == 1:          # release
            dalloc.release(slot)
            talloc.release(slot)
            del live[slot]
        elif action == 2:                         # evict pressure
            pc.evict(x % 4)
        elif slot not in live:                    # admit with sharing + COW
            prompt = prompts[x % len(prompts)]
            P = len(prompt)
            need = dalloc.blocks_for(P + 8, bs)
            n, runs = pc.match(prompt, limit_tokens=P - 1)
            n_cow = 1 if n and (P - 2) // bs < n else 0
            try:
                if n:
                    dalloc.share(slot, runs[0][:n])
                    talloc.share(slot, runs[1][:n])
                    dalloc.extend(slot, need - n)
                    talloc.extend(slot, need - n)
                    for a, first in ((dalloc, P - 2), (talloc, P - 1)):
                        for idx in range(first // bs, len(a.owned[slot])):
                            if not a.writable(slot, idx):
                                a.cow(slot, idx)
                else:
                    dalloc.allocate(slot, need)
                    talloc.allocate(slot, need)
            except PoolExhausted:
                dalloc.release(slot)
                talloc.release(slot)
            else:
                n_reg = (P - 2) // bs
                if n_reg > 0:
                    pc.insert(prompt, n_reg,
                              (dalloc.owned[slot], talloc.owned[slot]))
                live[slot] = P
        check()
    # drain: every stream releases, the cache evicts everything — all
    # blocks return to the free lists
    for slot in list(live):
        dalloc.release(slot)
        talloc.release(slot)
    pc.evict(10 ** 6)
    assert dalloc.blocks_in_use == 0 and talloc.blocks_in_use == 0


# ------------------------------------------------------------- quantization

@given(st.integers(0, 1000),
       st.lists(st.integers(-3, 3), min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_weight_quant_per_channel_scale_invariance(seed, exps):
    """Scaling output channel c by a power of two scales that channel's
    quantization scale EXACTLY and leaves the int8 codes unchanged —
    per-channel symmetric quantization is scale-equivariant (the reason
    one outlier column cannot clip its neighbors)."""
    import jax
    from repro.models.quant import dequantize_weight, quantize_weight
    d_out = len(exps)
    w = jax.random.normal(jax.random.PRNGKey(seed), (16, d_out))
    c = np.float32(2.0) ** np.asarray(exps, np.float32)       # exact in fp
    qw = quantize_weight(w)
    qw_scaled = quantize_weight(w * c[None, :])
    np.testing.assert_array_equal(np.asarray(qw_scaled["qw"]),
                                  np.asarray(qw["qw"]))
    np.testing.assert_allclose(np.asarray(qw_scaled["scale"]),
                               np.asarray(qw["scale"]) * c, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dequantize_weight(qw_scaled)),
                               np.asarray(dequantize_weight(qw)) * c[None, :],
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 1000), st.integers(1, 6), st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_kv_row_quant_roundtrip_bound(seed, L, G):
    """Int8 KV round trip is bounded by half an lsb of each row's scale,
    for every row independently (the per-row layout's invariant)."""
    import jax
    from repro.models.quant import dequantize_rows, quantize_rows
    x = jax.random.normal(jax.random.PRNGKey(seed), (L, G, 8)) * 4.0
    q, scale = quantize_rows(x)
    err = np.abs(np.asarray(dequantize_rows(q, scale)) - np.asarray(x))
    assert (err <= np.asarray(scale)[..., None] / 2 + 1e-6).all()


# ------------------------------------------------------------- masking rule

@given(st.integers(0, 100), st.lists(st.integers(-1, 120), min_size=1,
                                     max_size=64), st.integers(0, 16))
@settings(max_examples=100, deadline=None)
def test_attention_mask_rule(qpos, kpos_list, window):
    """Position-based mask: valid, causal, windowed — matches the spec."""
    import jax.numpy as jnp
    from repro.models.attention import _mask
    qp = jnp.asarray([qpos], jnp.int32)
    kp = jnp.asarray(kpos_list, jnp.int32)
    m = np.asarray(_mask(qp, kp, window, causal=True))[0]
    for i, k in enumerate(kpos_list):
        expect = (k >= 0) and (k <= qpos) and (window == 0 or qpos - k < window)
        assert m[i] == expect


# ------------------------------------------------------------- tree masks

@st.composite
def _parent_arrays(draw):
    """Random level-ordered parent arrays (the TreeSpec invariant)."""
    n_levels = draw(st.integers(1, 4))
    widths = [draw(st.integers(1, 4)) for _ in range(n_levels)]
    parents, prev = [], [-1]
    for w in widths:
        start = len(parents)
        for _ in range(w):
            if parents and prev != [-1]:
                parents.append(draw(st.sampled_from(prev)))
            else:
                parents.append(-1)
        prev = list(range(start, len(parents)))
    return tuple(parents)


@given(_parent_arrays())
@settings(max_examples=100, deadline=None)
def test_tree_ancestor_mask_matches_transitive_closure(parents):
    """The incrementally-built ancestor mask equals the transitive-closure
    oracle (boolean matrix powers of the child->parent edge relation)."""
    from repro.core.tree import TreeSpec, ancestor_mask_oracle
    spec = TreeSpec(parents)
    np.testing.assert_array_equal(spec.ancestor_mask,
                                  ancestor_mask_oracle(parents))
    # structural invariants: diagonal, strict lower-triangularity, and
    # each node's ancestor count == its depth
    m = spec.ancestor_mask
    assert m.diagonal().all()
    assert not np.triu(m, 1).any()
    np.testing.assert_array_equal(m.sum(1) - 1, spec.depths)


@given(_parent_arrays())
@settings(max_examples=50, deadline=None)
def test_tree_verify_mask_extension(parents):
    """Prepending the committed token preserves the ancestor relation and
    makes node 0 a universal ancestor."""
    from repro.core.tree import TreeSpec
    spec = TreeSpec(parents)
    vm = spec.verify_mask
    assert vm[:, 0].all() and not vm[0, 1:].any()
    np.testing.assert_array_equal(vm[1:, 1:], spec.ancestor_mask)
