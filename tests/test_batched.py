"""Batched multi-stream speculative decoding: exact per-stream equivalence
with single-stream runs, masked-slot isolation, and order-independent
batched bandit updates."""
import numpy as np
import pytest

from conftest import drain_streams as _drain_batched
from conftest import make_tiny_pair
from repro.core import SpecEngine, make_controller
from repro.core.bandits import EXP3, UCB1, ThompsonBeta, make_bandit
from repro.core.engine import BatchedSpecEngine

# three streams at DIFFERENT sequence positions (unequal prompt lengths)
PROMPTS = [[1, 5, 9, 13],
           [2, 6, 10, 14, 18, 22, 26],
           [3, 7, 11, 15, 19, 23, 27, 31, 35, 39, 43]]


def test_batched_matches_three_single_stream_runs(tiny_dense_pair):
    """B=3 streams at different positions == three B=1 greedy runs."""
    draft, target = tiny_dense_pair
    max_new = 24
    refs = []
    for p in PROMPTS:
        ctrl = make_controller("fixed_svip", gamma_max=6, seed=0)
        eng1 = SpecEngine(draft, target, ctrl, max_len=256)
        refs.append(eng1.generate(p, max_new).tokens)
    ctrl = make_controller("fixed_svip", gamma_max=6, seed=0)
    engB = BatchedSpecEngine(draft, target, ctrl, batch_size=3, max_len=256)
    states = _drain_batched(engB, PROMPTS, max_new)
    for st, ref in zip(states, refs):
        n = min(len(ref), len(st["seq"]))
        assert st["seq"][:n] == ref[:n]
        assert st["res"].new_tokens >= max_new


def test_batched_matches_single_recurrent_family():
    """Snapshot-rollback (recurrent draft) batched == single-stream."""
    draft, target = make_tiny_pair("recurrent")
    prompts = PROMPTS[:2]
    max_new = 12
    refs = []
    for p in prompts:
        eng1 = SpecEngine(draft, target,
                          make_controller("fixed_svip", gamma_max=4, seed=0),
                          max_len=128)
        refs.append(eng1.generate(p, max_new).tokens)
    engB = BatchedSpecEngine(draft, target,
                             make_controller("fixed_svip", gamma_max=4, seed=0),
                             batch_size=2, max_len=128)
    assert not engB.draft_cheap and engB.target_cheap
    states = _drain_batched(engB, prompts, max_new)
    for st, ref in zip(states, refs):
        n = min(len(ref), len(st["seq"]))
        assert st["seq"][:n] == ref[:n]


def test_masked_slot_never_perturbs_neighbors(tiny_dense_pair):
    """A slot that finishes (and later one that joins) must not change a
    neighbor's tokens or inject bandit observations."""
    draft, target = tiny_dense_pair
    max_new = 30
    ref_ctrl = make_controller("fixed_svip", gamma_max=6, seed=0)
    ref = SpecEngine(draft, target, ref_ctrl, max_len=256).generate(
        PROMPTS[0], max_new).tokens

    ctrl = make_controller("fixed_svip", gamma_max=6, seed=0)
    eng = BatchedSpecEngine(draft, target, ctrl, batch_size=2, max_len=256)
    eng.open_stream(0, PROMPTS[0])
    eng.open_stream(1, PROMPTS[1])
    sessions = 0
    for tick in range(200):
        st0 = eng.slots[0]
        if st0["res"].new_tokens >= max_new:
            break
        # kill the neighbor after 2 ticks -> slot 1 is masked from then on
        if tick == 2 and eng.slots[1] is not None:
            eng.close_stream(1)
        # re-admit a different stream mid-flight -> slot reuse next to slot 0
        if tick == 5 and eng.slots[1] is None:
            eng.open_stream(1, PROMPTS[2])
        sessions += len(eng.session_step_batch())
    n = min(len(ref), len(st0["seq"]))
    assert st0["seq"][:n] == ref[:n]
    # masked slots contributed no sessions: history counts only active slots
    assert sum(h["batch"] for h in ctrl.history) == sessions


def test_batched_outputs_masked_for_inactive(tiny_dense_pair):
    """Inactive lanes leave the device with zeroed outputs."""
    draft, target = tiny_dense_pair
    ctrl = make_controller("fixed_svip", gamma_max=6, seed=0)
    eng = BatchedSpecEngine(draft, target, ctrl, batch_size=3, max_len=256)
    eng.open_stream(1, PROMPTS[0])          # only the middle slot is live
    active = eng.active_mask()
    assert active.tolist() == [False, True, False]
    eng.session_step_batch()
    st = eng.slots[1]
    assert st["res"].sessions[0].n_drafted >= 1
    # neighbors untouched on host: no state, positions still zero
    assert eng.slots[0] is None and eng.slots[2] is None
    assert eng._tpos[0] == 0 and eng._tpos[2] == 0


# ------------------------------------------------------- batched bandits

def test_bandit_update_batch_order_independent():
    arms = np.array([0, 2, 1, 2, 0, 1, 1])
    rewards = np.array([0.1, 0.9, 0.4, 0.8, 0.3, 0.5, 0.6])
    perm = np.random.default_rng(0).permutation(arms.size)
    for kind in ("ucb1", "ucb_tuned", "ts_beta", "ts_gaussian", "exp3"):
        a = make_bandit(kind, 3, seed=0)
        b = make_bandit(kind, 3, seed=0)
        a.update_batch(arms, rewards)
        b.update_batch(arms[perm], rewards[perm])
        np.testing.assert_allclose(a.means, b.means)
        np.testing.assert_allclose(a.m2, b.m2)
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_allclose(a.arm_values, b.arm_values)


def test_bandit_update_batch_matches_sequential_stats():
    arms = [0, 1, 1, 2, 0]
    rewards = [0.2, 0.9, 0.7, 0.1, 0.4]
    a, b = UCB1(3), UCB1(3)
    a.update_batch(arms, rewards)
    for arm, r in zip(arms, rewards):
        b.update(arm, r)
    np.testing.assert_allclose(a.means, b.means)
    np.testing.assert_allclose(a.m2, b.m2, atol=1e-12)
    assert a.t == b.t


def test_ucb1_select_batch_diversifies():
    b = UCB1(3)
    picks = b.select_batch(3)
    assert set(picks.tolist()) == {0, 1, 2}   # unplayed arms covered first
    for arm in (0, 1, 2):                     # symmetric state: plain select()
        b.update(arm, 0.5)                    # would hand every stream arm 0
    picks = b.select_batch(3)
    assert set(picks.tolist()) == {0, 1, 2}   # fantasy pulls spread the batch


def test_thompson_beta_batch_posterior():
    b = ThompsonBeta(2, seed=0)
    b.update_batch([0, 0, 1], [1.0, 1.0, 0.0])
    assert b.alpha[0] == 3.0 and b.beta[0] == 1.0
    assert b.alpha[1] == 1.0 and b.beta[1] == 2.0


def test_exp3_converges_to_best_arm():
    b = EXP3(3, seed=0, gamma=0.2)
    rng = np.random.default_rng(1)
    means = [0.2, 0.8, 0.4]
    for _ in range(300):
        picks = b.select_batch(4)
        b.update_batch(picks, (rng.random(4) < np.take(means, picks)))
    assert int(np.argmax(b.arm_values)) == 1


def test_controller_update_batch_equals_merged_observations(tiny_dense_pair):
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=6, seed=0)
    arm_mat = ctrl.begin_batch(3)
    assert arm_mat.shape == (3, 6)
    ctrl.update_batch(arm_mat, np.array([4, 2, 6]), np.array([3, 1, 6]))
    assert ctrl.bandit.t == 3
    assert ctrl.history[-1]["batch"] == 3
    tok = make_controller("tapout_token_ucb1", gamma_max=5, seed=0)
    mat = tok.begin_batch(4)
    assert mat.shape == (4, 5)
    tok.update_batch(mat, np.array([5, 3, 0, 2]), np.array([5, 1, 0, 0]))
    # position-0 bandit saw one observation per stream that drafted >= 1
    assert tok.bank.bandits[0].t == 3
