"""SpecDec++ classifier baseline: training, calibration, controller use."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FixedArm, ModelBundle, SpecEngine
from repro.core.controller import Controller
from repro.core.specdecpp import (STOP_THRESHOLD, classifier_logit,
                                  collect_from_traces, init_classifier,
                                  make_specdecpp_arm, train_classifier)


def test_classifier_learns_separable_rule():
    rng = np.random.default_rng(0)
    n = 2000
    X = rng.normal(size=(n, 6)).astype(np.float32)
    y = (X[:, 1] > 0.3).astype(np.float32)       # "high sqrt-entropy -> reject"
    params, losses = train_classifier(X, y, steps=400)
    pred = np.asarray(jax.nn.sigmoid(classifier_logit(params, jnp.asarray(X)))) > 0.5
    acc = (pred == y).mean()
    assert acc > 0.9, acc
    assert losses[-1] < losses[0]


def test_collect_from_traces():
    traces = [
        {"signals": np.ones((4, 6), np.float32), "n_drafted": 4, "n_accepted": 2},
        {"signals": np.zeros((3, 6), np.float32), "n_drafted": 2, "n_accepted": 2},
    ]
    X, y = collect_from_traces(traces)
    assert X.shape == (6, 6)
    np.testing.assert_array_equal(y, [0, 0, 1, 1, 0, 0])


def test_specdecpp_arm_in_engine(tiny_dense_pair):
    draft, target = tiny_dense_pair
    params = init_classifier(jax.random.PRNGKey(0))
    arm = make_specdecpp_arm(params)

    class SpecDecPPController(Controller):
        name = "specdecpp"

        def __init__(self, gamma_max):
            super().__init__([arm], gamma_max)

        def begin(self):
            return np.zeros((self.gamma_max,), np.int32)

    eng = SpecEngine(draft, target, SpecDecPPController(6), max_len=128)
    r = eng.generate([1, 5, 9, 13], 12)
    assert r.new_tokens >= 12
    for s in r.sessions:
        assert 1 <= s.n_drafted <= 6
