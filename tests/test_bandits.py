"""Bandit algorithm unit tests: convergence, posterior updates, bank."""
import numpy as np
import pytest

from repro.core.bandits import (BanditBank, EpsilonGreedy, ThompsonBeta,
                                ThompsonGaussian, UCB1, UCBTuned, make_bandit)


def _run(bandit, means, steps=2000, seed=0):
    rng = np.random.default_rng(seed)
    pulls = np.zeros(len(means), int)
    for _ in range(steps):
        a = bandit.select()
        r = float(rng.random() < means[a])
        bandit.update(a, r)
        pulls[a] += 1
    return pulls


@pytest.mark.parametrize("cls", [UCB1, UCBTuned, ThompsonBeta, EpsilonGreedy])
def test_identifies_best_arm(cls):
    means = [0.2, 0.5, 0.8]
    b = cls(3, seed=1)
    pulls = _run(b, means)
    assert pulls[2] > 0.6 * pulls.sum()
    assert np.argmax(b.arm_values) == 2


def test_ucb1_plays_all_arms_first():
    b = UCB1(4)
    seen = set()
    for _ in range(4):
        a = b.select()
        seen.add(a)
        b.update(a, 0.5)
    assert seen == {0, 1, 2, 3}


def test_ucb1_exploration_bonus_decreases():
    b = UCB1(2)
    for _ in range(100):
        b.update(0, 0.5)
    b.update(1, 0.4)
    # arm 1 has a huge bonus (1 pull) -> selected despite lower mean
    assert b.select() == 1


def test_gaussian_ts_posterior_concentrates():
    b = ThompsonGaussian(2, seed=0, noise_var=0.05)
    for _ in range(200):
        b.update(0, 0.9)
        b.update(1, 0.1)
    sel = [b.select() for _ in range(50)]
    assert np.mean(np.array(sel) == 0) > 0.95
    assert abs(b.arm_values[0] - 0.9) < 0.05


def test_beta_ts_updates():
    b = ThompsonBeta(2, seed=0)
    b.update(0, 1.0)
    b.update(0, 1.0)
    b.update(1, 0.0)
    assert b.alpha[0] == 3.0 and b.beta[0] == 1.0
    assert b.alpha[1] == 1.0 and b.beta[1] == 2.0


def test_variance_tracking():
    b = UCBTuned(1)
    data = [0.1, 0.9, 0.5, 0.3, 0.7]
    for r in data:
        b.update(0, r)
    assert abs(b.variance(0) - np.var(data)) < 1e-9
    assert abs(b.means[0] - np.mean(data)) < 1e-12


def test_bandit_bank_positions_independent():
    bank = BanditBank(4, lambda s: UCB1(3, s))
    for _ in range(60):
        arms = bank.select_all()
        assert arms.shape == (4,)
        # position 0 always rewarded on arm 1, position 3 on arm 2
        bank.update(0, int(arms[0]), 1.0 if arms[0] == 1 else 0.0)
        bank.update(3, int(arms[3]), 1.0 if arms[3] == 2 else 0.0)
    assert np.argmax(bank.arm_values[0]) == 1
    assert np.argmax(bank.arm_values[3]) == 2


def test_make_bandit_registry():
    for k in ["ucb1", "ucb_tuned", "ts_beta", "ts_gaussian", "eps_greedy"]:
        assert make_bandit(k, 3).n_arms == 3
    with pytest.raises(KeyError):
        make_bandit("nope", 3)
