"""Prefix-sharing copy-on-write KV pool: allocator refcount/share/COW
units, PrefixCache trie semantics + LRU eviction, exact token/bandit
parity between shared-prefix and fully private admission (fp and int8 KV),
and serving with the prefix cache enabled."""
import jax
import numpy as np
import pytest

from repro.core import ModelBundle, make_controller
from repro.core.engine import EngineSpec, make_engine
from repro.models import ModelConfig
from repro.models import transformer as T
from repro.models.cache import BlockAllocator, PoolExhausted, PrefixCache
from repro.serving.engine import SpecServer


def _conserved(a: BlockAllocator) -> bool:
    return len(a.free) + a.blocks_in_use == a.num_blocks - 1


# --------------------------------------------------------------- allocator

def test_blocks_for_raises_instead_of_clamping():
    """Regression: a request needing more logical blocks than the table
    width used to be silently clamped, under-reserving and routing the
    overflow through trash block 0."""
    a = BlockAllocator(num_blocks=32, max_blocks=4, batch=1)
    assert a.blocks_for(4 * 16, 16) == 4
    with pytest.raises(ValueError, match="max_blocks"):
        a.blocks_for(4 * 16 + 1, 16)
    with pytest.raises(ValueError):
        a.allocate(0, 5)                   # extend enforces the same bound
    assert a.blocks_in_use == 0 and _conserved(a)


def test_share_refcounts_outlive_the_first_owner():
    a = BlockAllocator(num_blocks=16, max_blocks=8, batch=3)
    a.allocate(0, 4)
    blocks = list(a.owned[0])
    a.share(1, blocks[:2])
    assert a.blocks_in_use == 4, "sharing consumes no new blocks"
    assert [int(a.refcount[b]) for b in blocks] == [2, 2, 1, 1]
    assert (a.tables[1][:2] == blocks[:2]).all()
    a.release(0)
    assert a.blocks_in_use == 2, "shared blocks survive the donor's release"
    assert [int(a.refcount[b]) for b in blocks[:2]] == [1, 1]
    a.release(1)
    assert a.blocks_in_use == 0 and _conserved(a)


def test_cow_privatizes_a_shared_block():
    a = BlockAllocator(num_blocks=16, max_blocks=8, batch=2)
    a.allocate(0, 2)
    a.share(1, list(a.owned[0]))
    assert not a.writable(1, 1) and not a.writable(0, 1)
    src, dst = a.cow(1, 1)
    assert src != dst and a.owned[1][1] == dst == a.tables[1][1]
    assert a.writable(1, 1), "slot 1 now solely owns its copy"
    assert a.writable(0, 1), "slot 0 got its sole ownership back"
    assert int(a.refcount[src]) == 1 and int(a.refcount[dst]) == 1
    assert _conserved(a)


def test_immutable_blocks_are_never_writable():
    a = BlockAllocator(num_blocks=16, max_blocks=8, batch=1)
    a.allocate(0, 2)
    blk = a.owned[0][0]
    a.addref(blk)
    a.immutable[blk] = True
    assert not a.writable(0, 0)
    a.decref(blk)
    assert not a.writable(0, 0), "immutable even as sole owner"
    a.release(0)
    assert not a.immutable[blk], "last decref sheds the immutable mark"
    assert a.blocks_in_use == 0 and _conserved(a)


def test_extend_appends_after_shared_run():
    a = BlockAllocator(num_blocks=16, max_blocks=8, batch=2)
    a.allocate(0, 3)
    a.share(1, list(a.owned[0])[:2])
    a.extend(1, 2)
    assert len(a.owned[1]) == 4
    assert a.owned[1][:2] == a.owned[0][:2]
    assert a.writable(1, 2) and a.writable(1, 3)
    assert (a.tables[1][:4] == a.owned[1]).all()
    assert _conserved(a)
    tight = BlockAllocator(num_blocks=4, max_blocks=8, batch=1)
    tight.allocate(0, 2)
    with pytest.raises(PoolExhausted):
        tight.extend(0, 2)                     # fits the table, not the pool
    assert tight.blocks_in_use == 2 and _conserved(tight)


# ------------------------------------------------------------ prefix cache

def _cache_with_donor(bs=4, n_blocks=6):
    a = BlockAllocator(num_blocks=32, max_blocks=8, batch=4)
    b = BlockAllocator(num_blocks=32, max_blocks=8, batch=4)
    pc = PrefixCache(bs, (a, b))
    a.allocate(0, n_blocks)
    b.allocate(0, n_blocks)
    return pc, a, b


def test_prefix_cache_match_insert_roundtrip():
    pc, a, b = _cache_with_donor()
    toks = list(range(100, 120))                        # 5 chunks of 4
    added = pc.insert(toks, 3, (a.owned[0], b.owned[0]))
    assert added == 3 and pc.n_chunks == 3
    n, runs = pc.match(toks)
    assert n == 3
    assert runs[0] == a.owned[0][:3] and runs[1] == b.owned[0][:3]
    # longest match stops at the first divergent chunk
    n2, _ = pc.match(toks[:8] + [7, 7, 7, 7] + toks[12:])
    assert n2 == 2
    # a shorter prompt matches only its own whole chunks
    n3, _ = pc.match(toks[:7])
    assert n3 == 1
    # re-registering is idempotent: existing copy wins, no double refs
    before = [int(a.refcount[blk]) for blk in a.owned[0][:3]]
    assert pc.insert(toks, 3, (a.owned[0], b.owned[0])) == 0
    assert [int(a.refcount[blk]) for blk in a.owned[0][:3]] == before


def test_prefix_cache_refs_pin_blocks_until_eviction():
    pc, a, b = _cache_with_donor()
    pc.insert(list(range(100, 116)), 4, (a.owned[0], b.owned[0]))
    donor = list(a.owned[0])
    a.release(0)
    b.release(0)
    assert a.blocks_in_use == 4, "cached chunks survive the donor"
    assert all(a.immutable[blk] for blk in donor[:4])
    assert pc.evictable_chunks() == 4
    assert pc.evict(10) == 4
    assert a.blocks_in_use == 0 and b.blocks_in_use == 0
    assert _conserved(a) and _conserved(b)


def test_prefix_cache_eviction_respects_live_stream_pins():
    pc, a, b = _cache_with_donor(n_blocks=2)
    old = list(range(100, 108))                          # 2 chunks
    new = list(range(200, 208))
    pc.insert(old, 2, (a.owned[0], b.owned[0]))
    a.allocate(1, 2)
    b.allocate(1, 2)
    pc.insert(new, 2, (a.owned[1], b.owned[1]))
    pin = list(a.owned[1])
    a.release(0), b.release(0), a.release(1), b.release(1)
    a.share(2, pin)               # a live stream still aliases new's blocks
    assert pc.evictable_chunks() == 2, "only the unpinned branch counts"
    assert pc.evict(10) == 2
    assert pc.match(new, touch=False)[0] == 2, "pinned branch survives"
    assert pc.match(old, touch=False)[0] == 0


def test_prefix_cache_lru_order():
    pc, a, b = _cache_with_donor(n_blocks=2)
    first = list(range(100, 108))
    second = list(range(200, 208))
    pc.insert(first, 1, (a.owned[0][:1], b.owned[0][:1]))
    pc.insert(second, 1, (a.owned[0][1:], b.owned[0][1:]))
    a.release(0)
    b.release(0)
    pc.match(first)                                      # first becomes MRU
    pc.evict(1)
    assert pc.match(first, touch=False)[0] == 1
    assert pc.match(second, touch=False)[0] == 0, "LRU chunk went first"


# ------------------------------------------------- engine parity + stats

@pytest.fixture(scope="module")
def pair():
    V = 61
    tcfg = ModelConfig(name="tgt", arch_type="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=V)
    dcfg = ModelConfig(name="drf", arch_type="dense", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=1, d_ff=64,
                       vocab_size=V)
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    return ModelBundle(dp, dcfg), ModelBundle(tp, tcfg)


def _mk(pair, prefix_cache, kv_dtype=None, pool_tokens=512, mesh=None):
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    eng = make_engine(*pair, ctrl, EngineSpec(
        backend="paged", batch_size=4, max_len=256, block_size=8,
        pool_tokens=pool_tokens, prefix_cache=prefix_cache,
        kv_dtype=kv_dtype, mesh=mesh))
    return eng, ctrl


def _run(eng, prompt, slot, ticks=6):
    eng.open_stream(slot, list(prompt), reserve_tokens=len(prompt) + 30)
    for _ in range(ticks):
        eng.session_step_batch()
    st = eng.slots[slot]
    return (list(st["seq"]),
            [(s.n_drafted, s.n_accepted, s.arm) for s in st["res"].sessions])


SHARED = np.random.default_rng(0).integers(1, 60, size=17).tolist()
# donor registers (22-2)//8 = 2 chunks; the aligned adopter (len 17,
# 16 = 2*8 prefill tokens) adopts both and must COW the draft frontier
DONOR = SHARED + [11, 22, 33, 44, 55]
ALIGNED = list(SHARED)
UNALIGNED = SHARED + [17, 28, 39]


@pytest.mark.parametrize("kv_dtype", [None, "int8"])
@pytest.mark.parametrize("adopter,want_cow",
                         [(ALIGNED, 1), (UNALIGNED, 0)])
def test_shared_prefix_parity(pair, kv_dtype, adopter, want_cow):
    """A stream admitted onto shared prefix blocks is BIT-IDENTICAL to the
    same stream on fully private blocks: tokens, arm-selection trace, and
    bandit posterior all match, while prefill compute was actually skipped."""
    shared, ctrl_s = _mk(pair, True, kv_dtype)
    oA = _run(shared, DONOR, 0)
    oB = _run(shared, adopter, 1)
    ps = shared.pool_stats()
    assert ps["prefill_tokens_skipped"] == 16
    assert ps["cow_copies"] == want_cow
    assert ps["shared_blocks_in_use"] >= 2 * (2 - want_cow)
    assert ps["prefix_cache"]["hits"] == 1

    private, ctrl_p = _mk(pair, False, kv_dtype)
    assert _run(private, DONOR, 0) == oA
    assert _run(private, adopter, 1) == oB
    np.testing.assert_array_equal(ctrl_s.bandit.counts, ctrl_p.bandit.counts)
    np.testing.assert_array_equal(ctrl_s.bandit.means, ctrl_p.bandit.means)


def test_shared_prefix_parity_with_concurrent_donor(pair):
    """Donor keeps decoding WHILE the adopter runs on its blocks — the
    shared region must stay bit-stable under the donor's live writes."""
    shared, _ = _mk(pair, True)
    shared.open_stream(0, list(DONOR), reserve_tokens=len(DONOR) + 30)
    shared.session_step_batch()
    shared.open_stream(1, list(ALIGNED), reserve_tokens=len(ALIGNED) + 30)
    for _ in range(6):
        shared.session_step_batch()
    out0 = list(shared.slots[0]["seq"])
    out1 = list(shared.slots[1]["seq"])

    private, _ = _mk(pair, False)
    private.open_stream(0, list(DONOR), reserve_tokens=len(DONOR) + 30)
    private.session_step_batch()
    private.open_stream(1, list(ALIGNED), reserve_tokens=len(ALIGNED) + 30)
    for _ in range(6):
        private.session_step_batch()
    assert list(private.slots[0]["seq"]) == out0
    assert list(private.slots[1]["seq"]) == out1


def test_close_stream_keeps_cached_blocks_and_evict_reclaims(pair):
    eng, _ = _mk(pair, True)
    _run(eng, DONOR, 0)
    eng.close_stream(0)
    assert eng.dalloc.blocks_in_use == 2, "cache holds the registered run"
    assert eng.prefix_cache.evictable_chunks() == 2
    # a new admission of the same prompt re-adopts the cached blocks
    _run(eng, DONOR, 1, ticks=2)
    assert eng.pool_stats()["prefix_cache"]["hits"] == 1
    eng.close_stream(1)
    eng.prefix_cache.evict(99)
    assert eng.dalloc.blocks_in_use == 0 and eng.talloc.blocks_in_use == 0
    assert _conserved(eng.dalloc) and _conserved(eng.talloc)


def test_admission_evicts_cold_prefixes_under_pressure(pair):
    """With a pool sized so cached chunks must be reclaimed, admission
    evicts cold prefixes instead of backpressuring forever."""
    eng, _ = _mk(pair, True, pool_tokens=9 * 8)          # 9 usable blocks
    rng = np.random.default_rng(3)
    for slot in range(2):
        p = rng.integers(1, 60, size=18).tolist()        # reserve 48 -> 6 blk
        _run(eng, p, slot, ticks=2)
        eng.close_stream(slot)
    assert eng.prefix_cache.n_chunks == 4
    big = rng.integers(1, 60, size=20).tolist()          # reserve 50 -> 7 blk
    assert eng.can_admit(len(big) + 30, prompt=big)
    _run(eng, big, 0, ticks=2)                           # forces eviction
    assert eng.pool_stats()["prefix_cache"]["evictions"] > 0
    eng.close_stream(0)


def test_admission_pins_adopted_run_against_its_own_eviction(pair):
    """Regression: when the deficit can only be covered by evicting the
    very chunks being adopted (refcount==1 until ``share`` pins them),
    admission used to evict them first — ``share`` then addref'd a freed
    block (assert / silent KV aliasing).  It must backpressure cleanly
    instead, leaving the cache intact."""
    eng, _ = _mk(pair, True, pool_tokens=9 * 8)          # 9 usable blocks
    _run(eng, DONOR, 0, ticks=2)
    eng.close_stream(0)
    assert eng.prefix_cache.evictable_chunks() == 2      # the adopted run
    cached = [b for run in eng.prefix_cache.match(DONOR, touch=False)[1]
              for b in run]
    # need 9 blocks, 7 free, and the only evictable chunks ARE the run
    # being adopted: can_admit must not promise this capacity...
    assert not eng.can_admit(72, prompt=ALIGNED)
    # ...and open_stream must refuse without corrupting the cache
    with pytest.raises(PoolExhausted):
        eng.open_stream(1, list(ALIGNED), reserve_tokens=72)
    assert eng.slots[1] is None
    assert eng.prefix_cache.match(DONOR, touch=False)[0] == 2
    assert [int(eng.dalloc.refcount[b]) for b in cached[:2]] == [1, 1], \
        "admission pin was dropped on the failure path"
    assert eng.prefix_cache.evictable_chunks() == 2
    assert _conserved(eng.dalloc) and _conserved(eng.talloc)
    # the same admission with a feasible reservation still succeeds
    # (the refused attempt above already counted one cache hit)
    assert eng.can_admit(len(ALIGNED) + 20, prompt=ALIGNED)
    eng.open_stream(1, list(ALIGNED), reserve_tokens=len(ALIGNED) + 20)
    assert eng.pool_stats()["prefix_cache"]["hits"] == 2


def test_admission_evicts_cold_chunks_but_never_the_adopted_run(pair):
    """Deficit covered by COLD chunks while the adopted run rides through
    pinned: eviction reclaims the cold prefix, the hit survives."""
    eng, _ = _mk(pair, True, pool_tokens=9 * 8)
    cold = np.random.default_rng(7).integers(1, 60, size=18).tolist()
    _run(eng, cold, 0, ticks=2)
    eng.close_stream(0)
    _run(eng, DONOR, 0, ticks=2)
    eng.close_stream(0)
    assert eng.prefix_cache.n_chunks == 4                # 2 cold + 2 donor
    assert eng.prefix_cache.match(ALIGNED, touch=False)[0] == 2
    # need 7 blocks, 5 free -> deficit 1, covered by cold chunks only
    eng.open_stream(0, list(ALIGNED), reserve_tokens=56)
    assert eng.pool_stats()["prefix_cache"]["evictions"] >= 1
    assert eng.prefix_cache.match(DONOR, touch=False)[0] == 2, \
        "eviction reclaimed the adopted (pinned) run"
    assert eng.pool_stats()["prefix_cache"]["hits"] == 1
    assert _conserved(eng.dalloc) and _conserved(eng.talloc)


def test_server_requeues_request_when_admission_races_the_probe(pair):
    """``can_admit`` is a probe, not a reservation: if ``open_stream``
    still raises ``PoolExhausted``, the server must re-queue the request
    as backpressure (FIFO intact), not crash the serving loop."""
    draft, target = pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    srv = SpecServer(draft, target, ctrl, spec=EngineSpec(
        backend="paged", batch_size=2, max_len=256, block_size=8,
        pool_tokens=6 * 8, prefix_cache=True))
    prompt = np.random.default_rng(2).integers(1, 60, size=20).tolist()
    rid = srv.submit(prompt, 30)                         # needs 7 > 6 blocks
    srv.engine.can_admit = lambda *a, **k: True          # force the race
    srv.step()
    assert list(srv.queue) == [rid], "request re-queued at the head"
    assert srv.backpressure_events == 1
    assert all(s is None for s in srv.engine.slots)
    assert _conserved(srv.engine.dalloc) and _conserved(srv.engine.talloc)


def test_describe_and_stats_schema(pair):
    eng, _ = _mk(pair, True)
    d = eng.describe()
    for key in ("shared_blocks_in_use", "prefill_tokens_computed",
                "prefill_tokens_skipped", "cow_copies", "prefix_cache"):
        assert key in d["pool"]
    assert d["pool"]["prefix_cache"]["chunks"] == 0
    off, _ = _mk(pair, False)
    assert "prefix_cache" not in off.describe()["pool"]


def test_prefix_cache_rejects_recurrent_stacks():
    V = 61
    from repro.models import RGLRUConfig
    cfg = ModelConfig(name="r", arch_type="hybrid", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=V,
                      block_pattern=("rglru", "attn"), window=16,
                      rglru=RGLRUConfig(lru_width=32))
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    bundle = ModelBundle(p, cfg)
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    with pytest.raises(ValueError, match="prefix_cache"):
        make_engine(bundle, bundle, ctrl, EngineSpec(
            backend="paged", batch_size=2, max_len=128, block_size=8,
            pool_tokens=256, prefix_cache=True))


# ----------------------------------------------------------------- serving

def test_server_shared_prompt_workload_drains_and_shares(pair):
    draft, target = pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    srv = SpecServer(draft, target, ctrl, spec=EngineSpec(
        backend="paged", batch_size=4, max_len=256, block_size=8,
        pool_tokens=768, prefix_cache=True))
    rng = np.random.default_rng(1)
    system = rng.integers(1, 60, size=33).tolist()
    ids = [srv.submit(system + rng.integers(1, 60, size=4).tolist(), 8)
           for _ in range(6)]
    responses = srv.run_until_drained(max_ticks=500)
    assert {r.request_id for r in responses} == set(ids)
    stats = srv.throughput_stats()
    assert stats["prefill_tokens_skipped"] > 0
    assert stats["prefix_cache"]["hits"] >= 5
    # only cache-held blocks remain after the drain
    assert stats["blocks_in_use"] == (
        stats["prefix_cache"]["chunks"] * 2)
