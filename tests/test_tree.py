"""Tree speculation subsystem: topology invariants, chain-engine parity,
greedy equivalence, paged parity, MLA stacks, bandit shapes, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ar_greedy_decode, make_tiny_pair
from repro.core import (FixedShape, ModelBundle, SpecEngine, StaticGamma,
                        TapOutTreeSequence, TreeSpecEngine, tree_shape)
from repro.core import tree as trees

from repro.models import ModelConfig
from repro.models import transformer as T

PROMPT = [1, 5, 9, 13]


# ------------------------------------------------------------- topology

def test_templates_shapes():
    c = trees.chain(5)
    assert c.n_nodes == 5 and c.max_depth == 5
    assert c.parents == (-1, 0, 1, 2, 3)
    b = trees.binary(3)
    assert b.n_nodes == 2 + 4 + 8 and b.max_depth == 3
    w = trees.wide(4, 3)
    assert w.n_nodes == 12 and len(w.roots) == 4
    f = trees.from_branching((4, 2, 1))
    assert f.n_nodes == 4 + 8 + 8
    assert [len(l) for l in f.levels] == [4, 8, 8]


def test_chain_mask_is_lower_triangular():
    c = trees.chain(6)
    np.testing.assert_array_equal(c.ancestor_mask,
                                  np.tril(np.ones((6, 6), bool)))


def test_verify_extension():
    b = trees.binary(2)
    vm = b.verify_mask
    assert vm.shape == (7, 7)
    assert vm[:, 0].all()                 # last committed token sees all
    assert (b.verify_depths == np.concatenate([[0], b.depths + 1])).all()


def test_levels_are_contiguous_node_ranges():
    for spec in (trees.binary(3), trees.wide(3, 4),
                 trees.from_branching((3, 2, 2))):
        flat = [i for lvl in spec.levels for i in lvl]
        assert flat == list(range(spec.n_nodes))


def test_invalid_parents_rejected():
    with pytest.raises(AssertionError):
        trees.TreeSpec((0,))              # parent must be < index
    with pytest.raises(AssertionError):
        trees.TreeSpec((-1, 1))           # forward reference


# ------------------------------------------------------------- walk

def test_greedy_walk_longest_path_and_divergence():
    spec = trees.binary(2)                # roots (0,1); children (2..5)
    tokens = np.array([7, 3, 9, 4, 5, 6])
    V = 12
    p = np.zeros((7, V))
    p[0, 3] = 1.0                         # root target argmax = 3 -> node 1
    p[2, 5] = 1.0                         # at node 1: argmax 5 -> node 4
    p[5, 11] = 1.0                        # at node 4 (leaf): bonus 11
    q = np.full((6, V), 1.0 / V)
    path, repl = trees.verify_walk(spec, tokens, q, p, greedy=True)
    assert path == [1, 4] and repl == 11
    # divergence: no candidate matches -> replacement = argmax
    p[0] = 0
    p[0, 8] = 1.0
    path, repl = trees.verify_walk(spec, tokens, q, p, greedy=True)
    assert path == [] and repl == 8


def test_stochastic_walk_certain_accept():
    """p == q at the drafted token with ratio 1 accepts surely."""
    spec = trees.chain(2)
    tokens = np.array([4, 6])
    V = 8
    q = np.zeros((2, V))
    q[0, 4] = 1.0
    q[1, 6] = 1.0
    p = np.zeros((3, V))
    p[0, 4] = 1.0
    p[1, 6] = 1.0
    p[2, 2] = 1.0
    rng = np.random.default_rng(0)
    path, repl = trees.verify_walk(spec, tokens, q, p, greedy=False, rng=rng)
    assert path == [0, 1] and repl == 2


# ------------------------------------------------------------- engines

def test_chain_topology_matches_chain_engine(tiny_dense_pair):
    """Acceptance criterion: a chain-topology tree run is token-identical
    to the existing chain engine under the same seed (greedy)."""
    draft, target = tiny_dense_pair
    eng_t = TreeSpecEngine(draft, target,
                           FixedShape(6, tree_shape(trees.chain(6))),
                           max_len=256, seed=0)
    eng_c = SpecEngine(draft, target, StaticGamma(gamma=6), max_len=256,
                       seed=0)
    r_t = eng_t.generate(PROMPT, 40)
    r_c = eng_c.generate(PROMPT, 40)
    assert r_t.tokens == r_c.tokens
    assert [s.n_accepted for s in r_t.sessions] == \
        [s.n_accepted for s in r_c.sessions]


@pytest.mark.parametrize("spec", [trees.binary(3), trees.wide(4, 2),
                                  trees.from_branching((3, 2, 1))],
                         ids=lambda s: s.name)
def test_tree_greedy_equivalence(spec, tiny_dense_pair):
    """Greedy tree speculation must reproduce target-only greedy decoding
    exactly, whatever the topology."""
    draft, target = tiny_dense_pair
    ref = ar_greedy_decode(target.params, target.cfg, PROMPT, 32)
    eng = TreeSpecEngine(draft, target, FixedShape(8, tree_shape(spec)),
                         max_len=256)
    r = eng.generate(PROMPT, 32)
    assert r.tokens[:len(ref)] == ref[:len(r.tokens)]
    for s in r.sessions:
        assert 0 <= s.n_accepted <= spec.max_depth
        assert s.n_drafted == spec.n_nodes
    assert r.total_accepted + len(r.sessions) == r.new_tokens


def test_self_speculation_tree_accepts_full_depth(tiny_dense_pair):
    """draft == target: the greedy path matches to the deepest leaf every
    session, so accepted-per-verify == max_depth."""
    _, target = tiny_dense_pair
    spec = trees.binary(3)
    eng = TreeSpecEngine(target, target, FixedShape(6, tree_shape(spec)),
                         max_len=256)
    r = eng.generate(PROMPT, 24)
    assert r.mean_accepted == spec.max_depth


def test_paged_tree_engine_matches_dense(tiny_dense_pair):
    draft, target = tiny_dense_pair
    spec = trees.from_branching((3, 2, 1))
    r_d = TreeSpecEngine(draft, target, FixedShape(6, tree_shape(spec)),
                         max_len=256).generate(PROMPT, 28)
    r_p = TreeSpecEngine(draft, target, FixedShape(6, tree_shape(spec)),
                         max_len=256, paged=True,
                         block_size=16).generate(PROMPT, 28)
    assert r_d.tokens == r_p.tokens


def test_tree_engine_mla_stack():
    """MLA latent tree attention (absorbed formulation) + latent commit."""
    draft, target = make_tiny_pair("mla")
    ref = ar_greedy_decode(target.params, target.cfg, PROMPT, 20)
    eng = TreeSpecEngine(draft, target,
                         FixedShape(6, tree_shape(trees.binary(2))),
                         max_len=128)
    r = eng.generate(PROMPT, 20)
    assert r.tokens[:len(ref)] == ref[:len(r.tokens)]


def test_recurrent_stack_rejected():
    from repro.models import SSMConfig
    cfg = ModelConfig(name="s", arch_type="ssm", num_layers=2, d_model=64,
                      num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=61,
                      block_pattern=("mamba2",),
                      ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=8))
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    b = ModelBundle(p, cfg)
    with pytest.raises(AssertionError):
        TreeSpecEngine(b, b, FixedShape(4, tree_shape(trees.binary(2))),
                       max_len=128)


# ------------------------------------------------------------- bandit

def test_shape_pool_and_bandit_runs(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = TapOutTreeSequence(8, "ucb1", "simple", seed=0)
    names = [s.name for s in ctrl.shapes]
    assert any(n.startswith("chain_") for n in names)
    assert any(n.startswith("tree_") for n in names)
    ref = ar_greedy_decode(target.params, target.cfg, PROMPT, 40)
    eng = TreeSpecEngine(draft, target, ctrl, max_len=256)
    r = eng.generate(PROMPT, 40)
    assert r.tokens[:len(ref)] == ref[:len(r.tokens)]
    # every shape explored at least once (UCB1 round-robins first)
    assert (ctrl.shape_pulls >= 1).sum() >= min(len(ctrl.shapes),
                                                len(r.sessions))
    assert ctrl.arm_values.shape == (len(ctrl.shapes),)


def test_bandit_concentrates_on_degenerate_winner(tiny_dense_pair):
    """Self-speculation: the binary(3) tree accepts 3/session while a
    1-node chain accepts at most 1 — the meta-bandit must shift pulls
    toward the tree arm."""
    _, target = tiny_dense_pair
    shapes = [tree_shape(trees.chain(1)), tree_shape(trees.binary(3))]
    ctrl = TapOutTreeSequence(6, "ucb1", "simple", shapes=shapes, seed=0)
    eng = TreeSpecEngine(target, target, ctrl, max_len=512)
    eng.generate(PROMPT, 120)
    assert ctrl.shape_pulls[1] > ctrl.shape_pulls[0]
    assert ctrl.arm_values[1] > ctrl.arm_values[0]


def test_stochastic_tree_output_distribution(tiny_dense_pair):
    """Multi-candidate residual sampling: empirical next-token dist of the
    tree engine ~= the target dist (the SpecInfer guarantee)."""
    draft, target = tiny_dense_pair
    cache, spec = T.init_cache(target.cfg, 1, 64, jnp.float32)
    lg, _ = T.step(target.params, target.cfg,
                   jnp.asarray([PROMPT], jnp.int32), cache, spec)
    p_tgt = np.asarray(jax.nn.softmax(lg[0, -1]))
    N = 150
    eng = TreeSpecEngine(draft, target,
                         FixedShape(4, tree_shape(trees.binary(2))),
                         max_len=64, temperature=1.0, greedy=False, seed=0)
    counts = np.zeros(target.cfg.vocab_size)
    for _ in range(N):
        r = eng.generate(PROMPT, 1)
        counts[r.tokens[len(PROMPT)]] += 1
    tv = 0.5 * np.abs(counts / N - p_tgt).sum()
    assert tv < 0.3, tv


# ------------------------------------------------------------- serving

def test_tree_serving_drains_and_accounts(tiny_dense_pair):
    from repro.serving.engine import SpecServer
    draft, target = tiny_dense_pair
    ctrl = TapOutTreeSequence(6, "ucb1", "simple", seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=192, max_concurrency=3,
                     tree=True)
    rng = np.random.default_rng(0)
    n_req = 5
    for _ in range(n_req):
        srv.submit(rng.integers(1, 60, size=int(rng.integers(4, 16))).tolist(),
                   8)
    rs = srv.run_until_drained()
    assert len(rs) == n_req
    st = srv.throughput_stats()
    assert st["n_requests"] == n_req
    assert "accepted_per_verify" in st and st["accepted_per_verify"] >= 0
    assert len(st["shape_pulls"]) == len(ctrl.shapes)
    for r in rs:
        assert r.result.new_tokens >= 8
        for s in r.result.sessions:
            assert 0 <= s.n_accepted <= s.n_drafted
