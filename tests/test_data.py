"""Synthetic corpus: determinism, domain-entropy ordering, promptsets."""
import numpy as np

from repro.data.synthetic import (DATASET_MIX, SPECBENCH_MIX, SyntheticCorpus)
from repro.data.tokenizer import ByteTokenizer


def _char_entropy(text: str) -> float:
    _, counts = np.unique(list(text), return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log(p)).sum())


def test_deterministic():
    a = SyntheticCorpus(seed=3)
    b = SyntheticCorpus(seed=3)
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)
    assert a.document(rng_a, DATASET_MIX["mt_bench"]) == \
        b.document(rng_b, DATASET_MIX["mt_bench"])


def test_code_lower_entropy_than_prose():
    """Paper Fig. 2 precondition: coding text has lower entropy."""
    c = SyntheticCorpus(seed=0)
    rng = np.random.default_rng(0)
    code = "".join(c.gens.code(rng) for _ in range(20))
    prose = "".join(c.gens.prose(rng) for _ in range(20))
    # unigram char entropy is a weak proxy (the trained-model entropy gap is
    # much larger — bench_entropy reproduces Fig. 2); ordering must hold
    assert _char_entropy(code) < _char_entropy(prose) - 0.2


def test_specbench_categories_complete():
    cats = set(SPECBENCH_MIX)
    assert {"coding", "extraction", "humanities", "math", "math_reasoning",
            "qa", "rag", "reasoning", "roleplay", "stem", "summarization",
            "translation", "writing"} == cats


def test_prompts_shapes():
    c = SyntheticCorpus(seed=0)
    ps = c.prompts("specbench", 26)
    assert len(ps) == 26
    assert all(len(ids) > 10 for _, ids in ps)
    he = c.prompts("humaneval", 5)
    assert len(he) == 5 and all(cat == "humaneval" for cat, _ in he)


def test_training_batches_next_token():
    c = SyntheticCorpus(seed=0)
    it = c.training_batches(seq_len=32, batch_size=2, seed=0)
    x, y = next(it)
    assert x.shape == (2, 32) and y.shape == (2, 32)
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])
    tok = ByteTokenizer()
    assert x.max() < tok.vocab_size


def test_cipher_is_deterministic_mapping():
    c = SyntheticCorpus(seed=0)
    rng = np.random.default_rng(1)
    line = c.gens.cipher_pairs(rng)
    en, fr = line.strip().split(" | ")
    en_words = en.replace("EN: ", "").split()
    fr_words = fr.replace("FR: ", "").split()
    assert len(en_words) == len(fr_words)
    assert all(c.gens.cipher[w] == f for w, f in zip(en_words, fr_words))
