"""The engine matrix, locked down: every (target family, backend, kv_dtype)
cell runs a FULL speculative session and must reproduce the fp32 dense
greedy reference bit-exactly.

Families cover the workload axes the registry exposes: plain dense, MoE
(routed experts + routing-density accounting), vision-conditioned (prefix
patch embeddings, per-slot position offsets), and encoder-decoder (cross
attention via shared encoder segments).  Backends: single-stream dense and
paged.  kv_dtype: fp and int8 (per-row-scaled payloads).  Greedy argmax
acceptance makes every cell's output invariant to draft quality and cache
layout — any token diff is an engine bug, not noise."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ar_greedy_decode, drain_streams, make_tiny_pair
from repro.core import SpecEngine, make_controller
from repro.core.engine import PagedSpecEngine

FAMILIES = ("dense", "moe", "vlm", "encdec")
BACKENDS = ("single", "paged")
KV_DTYPES = (None, "int8")

PROMPT = [5, 9, 17, 3, 29, 41, 2, 11]
N_NEW = 12
MAX_LEN = 128


def conditioning(cfg):
    """Deterministic encoder inputs for a target config: (frame_embeds,
    patch_embeds), both None for text-only families."""
    rng = np.random.default_rng(0)
    if cfg.is_encdec:
        fe = rng.standard_normal((cfg.encdec.frontend_len,
                                  cfg.encdec.frontend_dim)).astype(np.float32)
        return fe, None
    if getattr(cfg, "vision", None) is not None:
        pe = rng.standard_normal((cfg.vision.num_patches,
                                  cfg.vision.vit_dim)).astype(np.float32)
        return None, pe
    return None, None


@pytest.fixture(scope="module")
def reference():
    """fp32 dense greedy decode per family — the row every cell must hit."""
    refs = {}
    for fam in FAMILIES:
        _, target = make_tiny_pair(fam)
        fe, pe = conditioning(target.cfg)
        refs[fam] = ar_greedy_decode(
            target.params, target.cfg, PROMPT, N_NEW, max_len=MAX_LEN,
            frame_embeds=None if fe is None else jnp.asarray(fe)[None],
            patch_embeds=None if pe is None else jnp.asarray(pe)[None])
    return refs


@pytest.mark.parametrize("kv_dtype", KV_DTYPES, ids=["fp", "int8"])
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("family", FAMILIES)
def test_matrix_cell_bit_exact(family, backend, kv_dtype, reference):
    draft, target = make_tiny_pair(family)
    ref = reference[family]
    fe, pe = conditioning(target.cfg)
    ctrl = make_controller("fixed_svip", gamma_max=4, seed=0)
    if backend == "single":
        eng = SpecEngine(draft, target, ctrl, max_len=MAX_LEN,
                         kv_dtype=kv_dtype)
        res = eng.generate(PROMPT, N_NEW, frame_embeds=fe, patch_embeds=pe)
        out = res.tokens
        assert res.new_tokens >= N_NEW
    else:
        eng = PagedSpecEngine(draft, target, ctrl, batch_size=2,
                              max_len=MAX_LEN, block_size=16,
                              kv_dtype=kv_dtype)
        kw = {}
        if fe is not None:
            kw["frame_embeds"] = fe
        if pe is not None:
            kw["patch_embeds"] = pe
        st = drain_streams(eng, [PROMPT], N_NEW, open_kwargs=[kw])[0]
        out = st["seq"]
    n = min(len(ref), len(out))
    assert n == len(ref), "cell under-produced"
    assert out[:n] == ref[:n], (family, backend, kv_dtype)

    # family-specific engine accounting rode along with the session
    if family == "moe":
        blob = eng.describe()["moe"]
        assert blob["routed_frac"] > 0 and blob["sessions"] > 0
        assert blob["mean_routing_density"] >= 1.0
    if family == "encdec" and backend == "paged":
        # the stream held (and on close released) a refcounted segment
        st_pool = eng.enc_pool.stats()
        assert st_pool["misses"] == 1 and st_pool["unique_segments"] == 0
