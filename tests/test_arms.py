"""Arm decision rules on crafted distributions (paper Table 1 semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.arms import (ADAEDL_DEFAULTS, arm_by_name, default_pool,
                             multi_threshold_pool, signal_vector,
                             signals_from_probs, update_adaedl_lambda)


def _sig(probs, prev_ent=0.0, lam=0.4, pos=1):
    p = jnp.asarray(probs)[None]          # batch of 1
    return signals_from_probs(p, jnp.asarray([prev_ent]), lam, pos)


def _stop(arm_name, sig, threshold=None):
    return bool(np.asarray(arm_by_name(arm_name, threshold).fn(sig))[0])


def test_max_confidence_stops_on_low_top1():
    assert _stop("max_confidence", _sig([0.5, 0.3, 0.2]))       # top1 .5 < .8
    assert not _stop("max_confidence", _sig([0.9, 0.05, 0.05]))


def test_svip_stops_on_high_entropy():
    flat = [1 / 8] * 8                     # H = ln 8 ~ 2.08, sqrt ~ 1.44 > .6
    assert _stop("svip", _sig(flat))
    peaked = [0.99] + [0.01 / 7] * 7
    assert not _stop("svip", _sig(peaked))


def test_logit_margin():
    assert _stop("logit_margin", _sig([0.45, 0.40, 0.15]))      # margin .05
    assert not _stop("logit_margin", _sig([0.8, 0.1, 0.1]))


def test_svip_difference_detects_spike():
    flat = [1 / 8] * 8
    s = _sig(flat, prev_ent=0.1)
    assert _stop("svip_difference", s)                           # 1.44-.1 > .2
    s2 = _sig(flat, prev_ent=1.40)
    assert not _stop("svip_difference", s2)


def test_adaedl_lambda_controls_stopping():
    flat = [1 / 8] * 8
    # 1 - sqrt(H) ~ 1-1.44 < 0: stops for lam=0.4, not for lam=-1 equivalent
    assert _stop("adaedl", _sig(flat, lam=0.4))
    peaked = [0.999] + [0.001 / 7] * 7
    assert not _stop("adaedl", _sig(peaked, lam=0.4))


def test_adaedl_update_direction():
    lam, ema = update_adaedl_lambda(0.4, 0.8, n_acc=0, n_drafted=8)
    assert lam > 0.4            # low accept rate -> raise threshold (stop earlier)
    lam2, _ = update_adaedl_lambda(0.4, 0.8, n_acc=8, n_drafted=8)
    assert lam2 < 0.4           # perfect acceptance -> relax


def test_default_pool_is_paper_table1():
    pool = default_pool()
    names = [a.name for a in pool]
    assert names == ["max_confidence", "svip", "adaedl", "svip_difference",
                     "logit_margin"]
    th = {a.name: a.threshold for a in pool}
    assert th["max_confidence"] == 0.8 and th["svip"] == 0.6
    assert th["svip_difference"] == 0.2 and th["logit_margin"] == 0.2


def test_multi_threshold_pool_bigger():
    assert len(multi_threshold_pool()) == 13


def test_arm_identity_cached_for_jit():
    assert default_pool()[0].fn is default_pool()[0].fn
    assert arm_by_name("svip") is arm_by_name("svip")


def test_signal_vector_shape():
    sig = _sig([0.5, 0.3, 0.2])
    v = signal_vector(sig)
    assert v.shape == (1, 6)
    assert np.isfinite(np.asarray(v)).all()
