"""EngineSpec/make_engine factory API: spec-vs-legacy parity, the
deprecation shim, canonical stats naming, and fused-vs-synchronous tick
bit-identity (the single-dispatch serving step must leave the bandit in
exactly the state the two-dispatch path produces)."""
import warnings

import numpy as np
import pytest

from repro.core import EngineSpec, make_controller, make_engine
from repro.core.engine import (BatchedSpecEngine, PagedSpecEngine,
                               SpecEngine, TreeSlotEngine)
from repro.serving.engine import SpecServer

PROMPTS = [[1, 5, 9, 13], [2, 6, 10, 14], [3, 7, 11, 15]]


def _controller(backend: str):
    kind = ("tapout_tree_ucb1" if backend.startswith("tree")
            else "tapout_seq_ucb1")
    return make_controller(kind, gamma_max=4, seed=0)


def _serve(pair, *, spec=None, legacy=None, max_new=10):
    draft, target = pair
    backend = spec.backend if spec is not None else (
        "tree_slot" if legacy.get("tree") else "batched")
    ctrl = _controller(backend)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        srv = (SpecServer(draft, target, ctrl, spec=spec)
               if spec is not None
               else SpecServer(draft, target, ctrl, **legacy))
    for p in PROMPTS:
        srv.submit(p, max_new)
    responses = srv.run_until_drained()
    tokens = {r.request_id: r.result.tokens for r in responses}
    return srv, ctrl, tokens


def _assert_state_equal(a, b):
    assert a["t"] == b["t"]
    np.testing.assert_array_equal(a["counts"], b["counts"])
    np.testing.assert_allclose(a["means"], b["means"], rtol=0, atol=0)
    np.testing.assert_allclose(a["m2"], b["m2"], rtol=0, atol=0)


# ------------------------------------------------------------- resolution

def test_spec_backend_resolution():
    assert EngineSpec().resolve_backend() == "batched"
    assert EngineSpec(batch_size=1).resolve_backend() == "single"
    assert EngineSpec(pool_tokens=4096).resolve_backend() == "paged"
    assert EngineSpec(backend="tree").resolve_backend() == "tree"
    with pytest.raises(ValueError):
        EngineSpec(backend="bogus")


def test_make_engine_dispatch(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = _controller("batched")
    eng = make_engine(draft, target, ctrl,
                      EngineSpec(batch_size=1, max_len=128))
    assert isinstance(eng, SpecEngine) and eng.backend_name == "single"
    eng = make_engine(draft, target, ctrl, backend="batched", batch_size=2,
                      max_len=128)
    assert isinstance(eng, BatchedSpecEngine)
    assert eng.fused                      # cheap-rollback stack -> fused
    d = eng.describe()
    assert d["backend"] == "batched" and d["batch_size"] == 2
    assert d["fused"] and d["devices"] == 1 and d["kv_dtype"] == "fp"
    eng = make_engine(draft, target, ctrl, backend="paged", batch_size=2,
                      max_len=128, pool_tokens=512)
    assert isinstance(eng, PagedSpecEngine)
    assert eng.describe()["pool"]["pool_tokens"] == 512
    eng = make_engine(draft, target, _controller("tree_slot"),
                      backend="tree_slot", batch_size=2, max_len=128)
    assert isinstance(eng, TreeSlotEngine)
    assert eng.describe()["backend"] == "tree_slot"


# ------------------------------------------------------------- deprecation

def test_legacy_kwargs_emit_deprecation_warning(tiny_dense_pair):
    draft, target = tiny_dense_pair
    with pytest.warns(DeprecationWarning, match="EngineSpec"):
        srv = SpecServer(draft, target, _controller("batched"),
                         max_len=256, max_concurrency=2)
    assert srv.backend == "batched" and srv.max_concurrency == 2


def test_spec_path_is_warning_free(tiny_dense_pair):
    draft, target = tiny_dense_pair
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        srv = SpecServer(draft, target, _controller("batched"),
                         spec=EngineSpec(batch_size=2, max_len=256))
    assert srv.backend == "batched"


def test_spec_plus_legacy_kwargs_raise(tiny_dense_pair):
    draft, target = tiny_dense_pair
    with pytest.raises(TypeError, match="not both"):
        SpecServer(draft, target, _controller("batched"),
                   spec=EngineSpec(), max_concurrency=2)
    with pytest.raises(TypeError, match="unknown"):
        SpecServer(draft, target, _controller("batched"), batch_sizes=2)


# ------------------------------------------------------------- parity

@pytest.mark.parametrize("backend", ["batched", "paged", "tree_slot"])
def test_factory_matches_legacy_kwargs(tiny_dense_pair, backend):
    """spec= and the deprecated kwarg surface must build engines that
    produce identical outputs AND identical bandit state."""
    legacy = dict(max_len=256, max_concurrency=2)
    if backend == "paged":
        legacy["paged"] = True
    if backend == "tree_slot":
        legacy["tree"] = True
    spec = EngineSpec(backend=backend, batch_size=2, max_len=256)
    _, ctrl_a, toks_a = _serve(tiny_dense_pair, legacy=legacy)
    _, ctrl_b, toks_b = _serve(tiny_dense_pair, spec=spec)
    assert toks_a == toks_b
    _assert_state_equal(ctrl_a.bandit.state_dict(),
                        ctrl_b.bandit.state_dict())


@pytest.mark.parametrize("backend", ["batched", "paged"])
def test_fused_tick_matches_synchronous(tiny_dense_pair, backend):
    """The single-dispatch fused tick and the two-dispatch synchronous
    tick must agree token-for-token and leave BIT-IDENTICAL bandit state
    (the fused program runs the sync primitives' exact traced bodies)."""
    results = {}
    for fused in (True, False):
        spec = EngineSpec(backend=backend, batch_size=2, max_len=256,
                          fused=fused)
        srv, ctrl, toks = _serve(tiny_dense_pair, spec=spec)
        assert srv.engine.fused is fused
        results[fused] = (ctrl, toks)
    assert results[True][1] == results[False][1]
    _assert_state_equal(results[True][0].bandit.state_dict(),
                        results[False][0].bandit.state_dict())


def test_fused_engine_direct_ticks_match(tiny_dense_pair):
    """Engine-level check without the server: back-to-back
    session_step_batch (launch+flush) on a fused engine equals the
    synchronous engine, stream for stream."""
    draft, target = tiny_dense_pair
    engines = {}
    for fused in (True, False):
        ctrl = _controller("batched")
        eng = make_engine(draft, target, ctrl, backend="batched",
                          batch_size=2, max_len=256, fused=fused)
        eng.open_stream(0, PROMPTS[0])
        eng.open_stream(1, PROMPTS[1])
        for _ in range(4):
            acted = eng.session_step_batch()
            assert acted == [0, 1]
        engines[fused] = (eng, ctrl)
    ef, es = engines[True][0], engines[False][0]
    assert ef.slots[0]["seq"] == es.slots[0]["seq"]
    assert ef.slots[1]["seq"] == es.slots[1]["seq"]
    np.testing.assert_array_equal(ef._dpos, es._dpos)
    np.testing.assert_array_equal(ef._tpos, es._tpos)
    _assert_state_equal(engines[True][1].bandit.state_dict(),
                        engines[False][1].bandit.state_dict())


def test_launch_flush_protocol(tiny_dense_pair):
    """Launch defers all host effects to flush: the bandit sees begin at
    launch and update only at flush; double-launch is rejected."""
    draft, target = tiny_dense_pair
    ctrl = _controller("batched")
    eng = make_engine(draft, target, ctrl, backend="batched", batch_size=2,
                      max_len=256)
    assert eng.session_step_flush() == []          # nothing pending
    eng.open_stream(0, PROMPTS[0])
    t0 = ctrl.bandit.t
    assert eng.session_step_launch() is True
    assert ctrl.bandit.t == t0                     # update deferred
    with pytest.raises(AssertionError):
        eng.session_step_launch()                  # pending not flushed
    assert eng.session_step_flush() == [0]
    assert ctrl.bandit.t > t0
    assert eng.session_step_flush() == []


# ------------------------------------------------------------- stats

def test_canonical_stats_schema(tiny_dense_pair):
    spec = EngineSpec(backend="batched", batch_size=2, max_len=256)
    srv, _, _ = _serve(tiny_dense_pair, spec=spec)
    stats = srv.throughput_stats()
    assert stats["accepted_per_verify"] > 0
    eng = stats["engine"]
    assert eng["backend"] == "batched" and eng["batch_size"] == 2
    assert eng["fused"] is True and eng["devices"] == 1
    for r in srv.responses:
        assert r.result.accepted_per_verify == r.result.mean_accepted
