import os
import sys

# Smoke tests and benches must see the single real CPU device — the 512-device
# XLA flag belongs ONLY to launch/dryrun.py (run as a separate process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models import transformer as T


@pytest.fixture(autouse=True, scope="module")
def _free_jit_executables_between_modules():
    """The CPU LLVM JIT arena is finite: 133 tests' compiled executables
    accumulate and eventually fail with 'Cannot allocate memory' /
    'Failed to materialize symbols'. Dropping jax's compilation caches
    between test modules keeps the arena bounded."""
    yield
    jax.clear_caches()


# ------------------------------------------------------ tiny model factory
#
# One construction site for every tiny (draft, target) family the suite
# exercises.  Targets always init from PRNGKey(0) and drafts from PRNGKey(1)
# — the seeds the pre-consolidation per-file constructions used — so the
# token sequences the existing tests assert on are unchanged.

_PAIRS = {}

_REGISTRY_ARCH = {"moe": "qwen3-moe-235b-a22b",
                  "encdec": "seamless-m4t-large-v2",
                  "vlm": "internvl2-26b"}


def make_tiny_pair(kind):
    """(draft_bundle, target_bundle) for a tiny model family (random init).

    Kinds: "dense" (attention target/draft), "recurrent" (dense target,
    hybrid rglru/local draft), "mla" (MLA latent stacks both sides), and
    the registry-backed conditioned/sparse targets "moe", "encdec", "vlm"
    (smoke-sized target from ``configs/registry.py`` plus a plain dense
    draft sharing its vocab — greedy verification makes the unconditioned
    draft exact for conditioned targets).  Pairs are built once per session
    and cached (params are tiny; ``jax.clear_caches`` does not drop them).
    """
    if kind in _PAIRS:
        return _PAIRS[kind]
    from repro.core import ModelBundle
    from repro.models import MLAConfig, RGLRUConfig
    V = 61
    if kind == "dense":
        tcfg = ModelConfig(name="tgt", arch_type="dense", num_layers=4,
                           d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                           vocab_size=V)
        dcfg = ModelConfig(name="drf", arch_type="dense", num_layers=2,
                           d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                           vocab_size=V)
    elif kind == "recurrent":
        tcfg = ModelConfig(name="t", arch_type="dense", num_layers=2,
                           d_model=96, num_heads=2, num_kv_heads=1, d_ff=192,
                           vocab_size=V)
        dcfg = ModelConfig(name="d", arch_type="hybrid", num_layers=2,
                           d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                           vocab_size=V, block_pattern=("rglru", "local"),
                           window=16, rglru=RGLRUConfig(lru_width=64))
    elif kind == "mla":
        mla = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                        qk_rope_head_dim=8, v_head_dim=16)
        tcfg = ModelConfig(name="t", arch_type="dense", num_layers=2,
                           d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                           vocab_size=V, block_pattern=("mla",), mla=mla)
        dcfg = ModelConfig(name="d", arch_type="dense", num_layers=1,
                           d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                           vocab_size=V, block_pattern=("mla",), mla=mla)
    elif kind in _REGISTRY_ARCH:
        from repro.configs.registry import smoke_config
        tcfg = smoke_config(_REGISTRY_ARCH[kind])
        dcfg = ModelConfig(name="drf", arch_type="dense", num_layers=2,
                           d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                           vocab_size=tcfg.vocab_size)
    else:
        raise ValueError(f"unknown tiny-pair kind {kind!r}")
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    pair = (ModelBundle(dp, dcfg), ModelBundle(tp, tcfg))
    _PAIRS[kind] = pair
    return pair


@pytest.fixture(scope="session")
def tiny_dense_pair():
    """(draft_bundle, target_bundle) of small dense models (random init)."""
    return make_tiny_pair("dense")


@pytest.fixture(scope="session")
def tiny_pair():
    """Factory fixture: ``tiny_pair(kind)`` -> (draft, target) bundles."""
    return make_tiny_pair


def ar_greedy_decode(params, cfg, prompt, n, max_len=256, frame_embeds=None,
                     patch_embeds=None):
    """Target-only greedy decoding reference (fp32 dense cache).  Encoder
    conditioning (``frame_embeds`` (1,F,D) / ``patch_embeds`` (1,P,D))
    applies to the prefill step only; decode steps run against the cache."""
    cache, spec = T.init_cache(cfg, 1, max_len, jnp.float32)
    seq = list(prompt)
    lg, cache = T.step(params, cfg, jnp.asarray([seq], jnp.int32), cache, spec,
                       frame_embeds=frame_embeds, patch_embeds=patch_embeds)
    for _ in range(n):
        t = int(jnp.argmax(lg[0, -1]))
        seq.append(t)
        lg, cache = T.step(params, cfg, jnp.asarray([[t]], jnp.int32), cache, spec)
    return seq


def drain_streams(eng, prompts, max_new, reserve=None, max_ticks=500,
                  open_kwargs=None):
    """Open one slot per prompt on a batched/paged engine and tick until
    every stream produced ``max_new`` tokens (or finished); returns the
    closed per-stream states.  ``reserve`` forwards ``reserve_tokens`` to
    paged admission; ``open_kwargs`` is an optional per-stream list of extra
    ``open_stream`` kwargs (e.g. encoder conditioning)."""
    final = [None] * len(prompts)
    for i, p in enumerate(prompts):
        kw = dict(open_kwargs[i]) if open_kwargs else {}
        if reserve is not None:
            kw["reserve_tokens"] = reserve
        eng.open_stream(i, list(p), **kw)
    for _ in range(max_ticks):
        for i in range(len(prompts)):
            st = eng.slots[i]
            if st is not None and (st["done"]
                                   or st["res"].new_tokens >= max_new):
                final[i] = eng.close_stream(i)
        if all(f is not None for f in final):
            return final
        eng.session_step_batch()
    raise AssertionError("streams did not drain")
