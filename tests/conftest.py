import os
import sys

# Smoke tests and benches must see the single real CPU device — the 512-device
# XLA flag belongs ONLY to launch/dryrun.py (run as a separate process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models import transformer as T


@pytest.fixture(autouse=True, scope="module")
def _free_jit_executables_between_modules():
    """The CPU LLVM JIT arena is finite: 133 tests' compiled executables
    accumulate and eventually fail with 'Cannot allocate memory' /
    'Failed to materialize symbols'. Dropping jax's compilation caches
    between test modules keeps the arena bounded."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def tiny_dense_pair():
    """(draft_bundle, target_bundle) of small dense models (random init)."""
    from repro.core import ModelBundle
    V = 61
    tcfg = ModelConfig(name="tgt", arch_type="dense", num_layers=4, d_model=128,
                       num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=V)
    dcfg = ModelConfig(name="drf", arch_type="dense", num_layers=2, d_model=64,
                       num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=V)
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    return ModelBundle(dp, dcfg), ModelBundle(tp, tcfg)


def ar_greedy_decode(params, cfg, prompt, n, max_len=256):
    """Target-only greedy decoding reference."""
    cache, spec = T.init_cache(cfg, 1, max_len, jnp.float32)
    seq = list(prompt)
    lg, cache = T.step(params, cfg, jnp.asarray([seq], jnp.int32), cache, spec)
    for _ in range(n):
        t = int(jnp.argmax(lg[0, -1]))
        seq.append(t)
        lg, cache = T.step(params, cfg, jnp.asarray([[t]], jnp.int32), cache, spec)
    return seq
