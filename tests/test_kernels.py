"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ops.FORCE_INTERPRET = True


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,H,G,Sq,Sk,D", [
    (1, 2, 1, 64, 64, 64),
    (2, 4, 2, 130, 130, 64),     # padding path
    (1, 8, 1, 96, 96, 128),      # MQA, MXU-aligned head dim
    (2, 4, 4, 33, 70, 32),       # MHA, ragged
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, G, Sq, Sk, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, G, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, G, Sk, D), dtype)
    qpos = jnp.arange(Sq, dtype=jnp.int32) + (Sk - Sq)
    kpos = jnp.arange(Sk, dtype=jnp.int32)
    out = ops.flash_attention(q, k, v, qpos, kpos, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, qpos, kpos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32))
    k = jax.random.normal(ks[1], (1, 1, 64, 32))
    v = jax.random.normal(ks[2], (1, 1, 64, 32))
    pos = jnp.arange(64, dtype=jnp.int32)
    out = ops.flash_attention(q, k, v, pos, pos, window=8, block_q=32, block_k=32)
    exp = ref.flash_attention_ref(q, k, v, pos, pos, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,H,G,L,D,valid", [
    (1, 2, 1, 256, 64, 256),
    (2, 4, 2, 300, 64, 200),     # ragged + invalid slots
    (1, 8, 8, 128, 128, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, G, L, D, valid, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, G, L, D), dtype)
    v = jax.random.normal(ks[2], (B, G, L, D), dtype)
    kpos = jnp.where(jnp.arange(L) < valid, jnp.arange(L), -1).astype(jnp.int32)
    out = ops.decode_attention(q, k, v, jnp.int32(valid - 1), kpos, block_l=128)
    exp = ref.decode_attention_ref(q, k, v, valid - 1, kpos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_decode_attention_ring_semantics():
    """Stale ring slots (future positions) must be masked out."""
    B, H, G, L, D = 1, 1, 1, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    kpos = jnp.arange(L, dtype=jnp.int32)
    # query at pos 40: slots 41.. are "stale future" entries
    out = ops.decode_attention(q, k, v, jnp.int32(40), kpos, block_l=32)
    exp = ref.decode_attention_ref(q, k[:, :, :41], v[:, :, :41], 40,
                                   kpos[:41])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


def _random_paged_layout(rng, B, N, bs, MB):
    """Non-overlapping random tables (block 0 = trash) + ragged lengths."""
    perm = rng.permutation(np.arange(1, N))
    tables = np.zeros((B, MB), np.int32)
    lengths = np.zeros((B,), np.int32)
    pi = 0
    for b in range(B):
        # bound by the blocks still unclaimed in the pool, not just MB
        max_tok = min(MB, len(perm) - pi) * bs
        L = int(rng.integers(1, max_tok)) if max_tok > 1 else 1
        nb = -(-L // bs)
        tables[b, :nb] = perm[pi:pi + nb]
        pi += nb
        lengths[b] = L
    return tables, lengths


@pytest.mark.parametrize("B,H,G,N,bs,MB,D,window", [
    (2, 4, 2, 9, 16, 4, 64, 0),
    (3, 2, 1, 17, 8, 6, 32, 0),      # MQA, small blocks
    (2, 8, 8, 9, 16, 4, 128, 0),     # MHA, MXU-aligned head dim
    (2, 4, 2, 9, 16, 4, 64, 12),     # sliding window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(B, H, G, N, bs, MB, D, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kpool = jax.random.normal(ks[1], (N, bs, G, D), dtype)
    vpool = jax.random.normal(ks[2], (N, bs, G, D), dtype)
    tables, lengths = _random_paged_layout(np.random.default_rng(0), B, N, bs, MB)
    out = ops.paged_decode_attention(q, kpool, vpool, jnp.asarray(tables),
                                     jnp.asarray(lengths), window=window)
    exp = ref.paged_decode_attention_ref(q, kpool, vpool, jnp.asarray(tables),
                                         jnp.asarray(lengths), window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_paged_decode_matches_dense_decode():
    """Paged kernel == dense decode kernel on the same logical cache."""
    B, H, G, bs, MB, D = 2, 4, 2, 16, 4, 64
    N = B * MB + 1
    L = MB * bs
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    lengths = np.array([37, 55], np.int32)
    # pack each stream's logical rows into disjoint pool blocks
    tables = np.zeros((B, MB), np.int32)
    kpool = np.zeros((N, bs, G, D), np.float32)
    vpool = np.zeros((N, bs, G, D), np.float32)
    nxt = 1
    for b in range(B):
        for mb in range(MB):
            tables[b, mb] = nxt
            kpool[nxt] = np.asarray(k[b, :, mb * bs:(mb + 1) * bs]).transpose(1, 0, 2)
            vpool[nxt] = np.asarray(v[b, :, mb * bs:(mb + 1) * bs]).transpose(1, 0, 2)
            nxt += 1
    out = ops.paged_decode_attention(q, jnp.asarray(kpool), jnp.asarray(vpool),
                                     jnp.asarray(tables), jnp.asarray(lengths))
    for b in range(B):
        kpos = jnp.where(jnp.arange(L) < lengths[b], jnp.arange(L), -1)
        exp = ops.decode_attention(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                   jnp.int32(lengths[b] - 1),
                                   kpos.astype(jnp.int32), block_l=32)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(exp[0]),
                                   atol=5e-5, rtol=5e-5)


def test_paged_decode_empty_lane_outputs_zero():
    """lengths == 0 (a masked/empty serving lane): every block is fully
    masked, so the kernel must emit zeros — not the mean of the trash rows
    (regression: exp(s - NEG_INF_max) == 1 poisoned the softmax sums)."""
    B, H, G, N, bs, MB, D = 2, 2, 1, 5, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kpool = jax.random.normal(ks[1], (N, bs, G, D))
    vpool = jax.random.normal(ks[2], (N, bs, G, D))
    tables = np.asarray([[0, 0], [1, 2]], np.int32)
    lengths = jnp.asarray([0, 9], jnp.int32)
    out = ops.paged_decode_attention(q, kpool, vpool, jnp.asarray(tables),
                                     lengths)
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    exp = ref.paged_decode_attention_ref(q, kpool, vpool, jnp.asarray(tables),
                                         lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


def test_paged_decode_post_rollback_state():
    """Rows past a truncated length are live in HBM but dead to attention:
    truncating lengths must equal never having written the tail."""
    B, H, G, N, bs, MB, D = 1, 2, 1, 7, 8, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kpool = jax.random.normal(ks[1], (N, bs, G, D))
    vpool = jax.random.normal(ks[2], (N, bs, G, D))
    tables = np.asarray([[3, 1, 4, 2]], np.int32)
    full = ops.paged_decode_attention(q, kpool, vpool, jnp.asarray(tables),
                                      jnp.asarray([20], jnp.int32))
    # corrupt the rows past position 20 -> must not change the output
    flat_k, flat_v = np.array(kpool), np.array(vpool)
    for p in range(20, MB * bs):
        blk, off = tables[0, p // bs], p % bs
        flat_k[blk, off] = 1e3
        flat_v[blk, off] = -1e3
    rolled = ops.paged_decode_attention(q, jnp.asarray(flat_k),
                                        jnp.asarray(flat_v),
                                        jnp.asarray(tables),
                                        jnp.asarray([20], jnp.int32))
    np.testing.assert_allclose(np.asarray(full), np.asarray(rolled),
                               atol=5e-5, rtol=5e-5)


# ------------------------------------------------------------- dense ragged

@pytest.mark.parametrize("B,H,G,L,D,window", [
    (2, 4, 2, 256, 64, 0),
    (3, 2, 1, 130, 32, 0),       # padding path, MQA
    (2, 8, 8, 128, 128, 0),      # MHA, MXU-aligned head dim
    (2, 4, 2, 256, 32, 24),      # sliding window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_decode_attention_sweep(B, H, G, L, D, window, dtype):
    """Per-lane lengths via scalar prefetch + pl.when early-exit vs the
    per-lane oracle."""
    ks = jax.random.split(jax.random.PRNGKey(30), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, G, L, D), dtype)
    v = jax.random.normal(ks[2], (B, G, L, D), dtype)
    rng = np.random.default_rng(30)
    lengths = jnp.asarray(rng.integers(1, L, size=B), jnp.int32)
    out = ops.ragged_decode_attention(q, k, v, lengths, window=window,
                                      block_l=64)
    exp = ref.ragged_decode_attention_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_ragged_decode_matches_per_lane_dense():
    """Ragged kernel == the non-ragged dense kernel called lane by lane."""
    B, H, G, L, D = 3, 4, 2, 192, 64
    ks = jax.random.split(jax.random.PRNGKey(31), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    lengths = np.array([17, 192, 65], np.int32)
    out = ops.ragged_decode_attention(q, k, v, jnp.asarray(lengths),
                                      block_l=64)
    for b in range(B):
        kpos = jnp.where(jnp.arange(L) < lengths[b], jnp.arange(L),
                         -1).astype(jnp.int32)
        exp = ops.decode_attention(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                   jnp.int32(lengths[b] - 1), kpos,
                                   block_l=64)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(exp[0]),
                                   atol=5e-5, rtol=5e-5)


def test_ragged_decode_empty_lane_outputs_zero():
    """lengths == 0: every block early-exits, the scratch stays at init,
    and the unguarded finalize must emit zeros."""
    B, H, G, L, D = 2, 2, 1, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(32), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    lengths = jnp.asarray([0, 70], jnp.int32)
    out = ops.ragged_decode_attention(q, k, v, lengths, block_l=32)
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    exp = ref.ragged_decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,H,G,L,D,window", [
    (2, 4, 2, 256, 64, 0),
    (3, 2, 1, 130, 32, 0),       # padding path, MQA
    (2, 4, 2, 256, 32, 24),      # sliding window
])
def test_ragged_decode_attention_quant_sweep(B, H, G, L, D, window):
    """Int8 ragged kernel vs the quantized ragged oracle, and within
    quantization error of the fp ragged kernel on the same cache."""
    from repro.models.quant import quantize_rows
    ks = jax.random.split(jax.random.PRNGKey(33), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    kq, kscale = quantize_rows(k)
    vq, vscale = quantize_rows(v)
    rng = np.random.default_rng(33)
    lengths = jnp.asarray(rng.integers(1, L, size=B), jnp.int32)
    out = ops.ragged_decode_attention_quant(q, kq, kscale, vq, vscale,
                                            lengths, window=window,
                                            block_l=64)
    exp = ref.ragged_decode_attention_quant_ref(q, kq, kscale, vq, vscale,
                                                lengths, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)
    fp = ref.ragged_decode_attention_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fp),
                               atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("B,H,G,L,D,window", [
    (2, 4, 2, 128, 64, 0),
    (3, 2, 1, 130, 32, 0),       # padding path, MQA
    (2, 4, 2, 128, 32, 24),      # sliding window
])
@pytest.mark.parametrize("treespec", ["chain4", "binary2"])
def test_ragged_tree_attention_sweep(B, H, G, L, D, window, treespec):
    """Per-lane bases via scalar prefetch + pl.when early-exit vs the
    per-lane dense tree oracle."""
    from repro.core import tree as trees
    spec = {"chain4": trees.chain(4), "binary2": trees.binary(2)}[treespec]
    T = spec.n_nodes
    ks = jax.random.split(jax.random.PRNGKey(34), 5)
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    kt = jax.random.normal(ks[3], (B, G, T, D))
    vt = jax.random.normal(ks[4], (B, G, T, D))
    rng = np.random.default_rng(34)
    bases = jnp.asarray(rng.integers(1, L, size=B), jnp.int32)
    depths = jnp.asarray(spec.depths, jnp.int32)
    anc = jnp.asarray(spec.ancestor_mask, jnp.int32)
    out = ops.ragged_tree_attention(q, k, v, bases, kt, vt, depths, anc,
                                    window=window, block_l=64)
    exp = ref.ragged_tree_attention_ref(q, k, v, bases, kt, vt, depths, anc,
                                        window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


def test_ragged_tree_empty_lane_attends_tree_only():
    """bases == 0: every cache block early-exits; nodes still attend their
    ancestors, so the output equals tree-only attention (not zeros)."""
    from repro.core import tree as trees
    spec = trees.chain(3)
    B, H, G, L, D = 1, 2, 1, 64, 32
    T = spec.n_nodes
    ks = jax.random.split(jax.random.PRNGKey(35), 5)
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    kt = jax.random.normal(ks[3], (B, G, T, D))
    vt = jax.random.normal(ks[4], (B, G, T, D))
    depths = jnp.asarray(spec.depths, jnp.int32)
    anc = jnp.asarray(spec.ancestor_mask, jnp.int32)
    out = ops.ragged_tree_attention(q, k, v, jnp.zeros((B,), jnp.int32),
                                    kt, vt, depths, anc, block_l=32)
    exp = ref.flash_attention_ref(q, kt, vt, depths,
                                  jnp.arange(T, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


# --------------------------------------------------------------- quantized

@pytest.mark.parametrize("B,H,G,L,D,valid,window", [
    (1, 2, 1, 256, 64, 256, 0),
    (2, 4, 2, 300, 64, 200, 0),      # ragged + invalid slots
    (1, 8, 8, 128, 128, 100, 0),     # MHA, MXU-aligned head dim
    (2, 4, 2, 256, 32, 180, 24),     # sliding window
])
def test_decode_attention_quant_sweep(B, H, G, L, D, valid, window):
    """Int8 dequant-in-register decode kernel vs the quantized oracle, and
    within quantization error of the fp kernel on the same cache."""
    from repro.models.quant import quantize_rows
    ks = jax.random.split(jax.random.PRNGKey(21), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    kq, kscale = quantize_rows(k)
    vq, vscale = quantize_rows(v)
    kpos = jnp.where(jnp.arange(L) < valid, jnp.arange(L), -1).astype(jnp.int32)
    out = ops.decode_attention_quant(q, kq, kscale, vq, vscale,
                                     jnp.int32(valid - 1), kpos,
                                     window=window, block_l=128)
    exp = ref.decode_attention_quant_ref(q, kq, kscale, vq, vscale,
                                         valid - 1, kpos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)
    fp = ref.decode_attention_ref(q, k, v, valid - 1, kpos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fp),
                               atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("B,H,G,N,bs,MB,D,window", [
    (2, 4, 2, 9, 16, 4, 64, 0),
    (3, 2, 1, 17, 8, 6, 32, 0),      # MQA, small blocks
    (2, 8, 8, 9, 16, 4, 128, 0),     # MHA, MXU-aligned head dim
    (2, 4, 2, 9, 16, 4, 64, 12),     # sliding window
])
def test_paged_decode_attention_quant_sweep(B, H, G, N, bs, MB, D, window):
    """Int8 paged kernel (scalar-prefetch payload + scale pools) vs the
    quantized paged oracle."""
    from repro.models.quant import quantize_rows
    ks = jax.random.split(jax.random.PRNGKey(22), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kpool = jax.random.normal(ks[1], (N, bs, G, D))
    vpool = jax.random.normal(ks[2], (N, bs, G, D))
    kq, kscale = quantize_rows(kpool)
    vq, vscale = quantize_rows(vpool)
    tables, lengths = _random_paged_layout(np.random.default_rng(4), B, N, bs, MB)
    out = ops.paged_decode_attention_quant(
        q, kq, kscale, vq, vscale, jnp.asarray(tables), jnp.asarray(lengths),
        window=window)
    exp = ref.paged_decode_attention_quant_ref(
        q, kq, kscale, vq, vscale, jnp.asarray(tables), jnp.asarray(lengths),
        window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


def test_paged_decode_quant_empty_lane_outputs_zero():
    """lengths == 0 under int8 pools: fully-masked lanes still emit zeros
    (the re-mask guard must survive the scale multiplies)."""
    from repro.models.quant import quantize_rows
    B, H, G, N, bs, MB, D = 2, 2, 1, 5, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kq, kscale = quantize_rows(jax.random.normal(ks[1], (N, bs, G, D)))
    vq, vscale = quantize_rows(jax.random.normal(ks[2], (N, bs, G, D)))
    tables = jnp.asarray([[0, 0], [1, 2]], jnp.int32)
    lengths = jnp.asarray([0, 9], jnp.int32)
    out = ops.paged_decode_attention_quant(q, kq, kscale, vq, vscale,
                                           tables, lengths)
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)


# --------------------------------------------------------------- tree

def _tree_fixtures(key, B, H, G, L, D, spec):
    ks = jax.random.split(key, 5)
    T = spec.n_nodes
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, G, L, D))
    v = jax.random.normal(ks[2], (B, G, L, D))
    kt = jax.random.normal(ks[3], (B, G, T, D))
    vt = jax.random.normal(ks[4], (B, G, T, D))
    return q, k, v, kt, vt


@pytest.mark.parametrize("B,H,G,L,D,base,window", [
    (1, 2, 1, 128, 64, 100, 0),
    (2, 4, 2, 130, 64, 90, 0),       # padding path, GQA
    (1, 8, 1, 96, 128, 96, 0),       # MQA, MXU-aligned head dim
    (2, 4, 2, 128, 32, 100, 24),     # sliding window
])
@pytest.mark.parametrize("treespec", ["chain4", "binary2", "b3x2x1"])
def test_tree_attention_sweep(B, H, G, L, D, base, window, treespec):
    from repro.core import tree as trees
    spec = {"chain4": trees.chain(4), "binary2": trees.binary(2),
            "b3x2x1": trees.from_branching((3, 2, 1))}[treespec]
    q, k, v, kt, vt = _tree_fixtures(jax.random.PRNGKey(11), B, H, G, L, D,
                                     spec)
    # rows base..base+9 carry stale future positions: the < base rule must
    # mask them even though kpos <= qpos would admit them
    kpos = jnp.where(jnp.arange(L) < base + 10, jnp.arange(L), -1).astype(jnp.int32)
    qpos = jnp.asarray(base + spec.depths, jnp.int32)
    anc = jnp.asarray(spec.ancestor_mask, jnp.int32)
    out = ops.tree_attention(q, k, v, kpos, jnp.int32(base), kt, vt, qpos,
                             anc, window=window, block_l=64)
    exp = ref.tree_attention_ref(q, k, v, kpos, base, kt, vt, qpos, anc,
                                 window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


def test_tree_attention_chain_matches_flash():
    """A chain-topology tree block == ordinary causal attention over the
    same [cache + suffix] sequence."""
    from repro.core import tree as trees
    B, H, G, L, D = 1, 2, 1, 64, 32
    spec = trees.chain(4)
    q, k, v, kt, vt = _tree_fixtures(jax.random.PRNGKey(12), B, H, G, L, D,
                                     spec)
    base = 40
    kpos = jnp.where(jnp.arange(L) < base, jnp.arange(L), -1).astype(jnp.int32)
    qpos = jnp.asarray(base + spec.depths, jnp.int32)
    anc = jnp.asarray(spec.ancestor_mask, jnp.int32)
    out = ops.tree_attention(q, k, v, kpos, jnp.int32(base), kt, vt, qpos,
                             anc, block_l=32)
    kcat = jnp.concatenate([k[:, :, :base], kt], axis=2)
    vcat = jnp.concatenate([v[:, :, :base], vt], axis=2)
    exp = ref.flash_attention_ref(q, kcat, vcat, qpos,
                                  jnp.arange(base + 4, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,H,G,N,bs,MB,D,window", [
    (2, 4, 2, 9, 16, 4, 64, 0),
    (3, 2, 1, 17, 8, 6, 32, 0),      # MQA, small blocks
    (2, 8, 8, 9, 16, 4, 128, 0),     # MHA, MXU-aligned head dim
    (2, 4, 2, 9, 16, 4, 64, 12),     # sliding window
])
@pytest.mark.parametrize("treespec", ["binary2", "wide3x2"])
def test_paged_tree_attention_sweep(B, H, G, N, bs, MB, D, window, treespec):
    from repro.core import tree as trees
    spec = {"binary2": trees.binary(2), "wide3x2": trees.wide(3, 2)}[treespec]
    T = spec.n_nodes
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    q = jax.random.normal(ks[0], (B, H, T, D))
    kpool = jax.random.normal(ks[1], (N, bs, G, D))
    vpool = jax.random.normal(ks[2], (N, bs, G, D))
    kt = jax.random.normal(ks[3], (B, G, T, D))
    vt = jax.random.normal(ks[4], (B, G, T, D))
    tables, lengths = _random_paged_layout(np.random.default_rng(3), B, N, bs, MB)
    depths = jnp.asarray(spec.depths, jnp.int32)
    anc = jnp.asarray(spec.ancestor_mask, jnp.int32)
    out = ops.paged_tree_attention(q, kpool, vpool, jnp.asarray(tables),
                                   jnp.asarray(lengths), kt, vt, depths, anc,
                                   window=window)
    exp = ref.paged_tree_attention_ref(q, kpool, vpool, jnp.asarray(tables),
                                       jnp.asarray(lengths), kt, vt, depths,
                                       anc, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


def test_paged_tree_empty_lane_attends_tree_only():
    """lengths == 0: every cache block is masked; nodes still attend their
    ancestors, so the output equals tree-only attention (not zeros)."""
    from repro.core import tree as trees
    spec = trees.chain(3)
    B, H, G, N, bs, MB, D = 1, 2, 1, 5, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(14), 5)
    T = spec.n_nodes
    q = jax.random.normal(ks[0], (B, H, T, D))
    kpool = jax.random.normal(ks[1], (N, bs, G, D))
    vpool = jax.random.normal(ks[2], (N, bs, G, D))
    kt = jax.random.normal(ks[3], (B, G, T, D))
    vt = jax.random.normal(ks[4], (B, G, T, D))
    tables = jnp.zeros((1, MB), jnp.int32)
    lengths = jnp.zeros((1,), jnp.int32)
    depths = jnp.asarray(spec.depths, jnp.int32)
    anc = jnp.asarray(spec.ancestor_mask, jnp.int32)
    out = ops.paged_tree_attention(q, kpool, vpool, tables, lengths, kt, vt,
                                   depths, anc)
    exp = ref.flash_attention_ref(q, kt, vt, depths,
                                  jnp.arange(T, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,NC,Q,H,P,G,N", [
    (1, 2, 16, 2, 32, 1, 16),
    (2, 3, 16, 4, 32, 2, 16),    # grouped B/C
    (1, 1, 64, 8, 64, 1, 128),   # mamba2-like dims
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_sweep(B, NC, Q, H, P, G, N, dtype):
    kk = jax.random.split(jax.random.PRNGKey(4), 5)
    xc = jax.random.normal(kk[0], (B, NC, Q, H, P), dtype)
    dtc = jax.nn.softplus(jax.random.normal(kk[1], (B, NC, Q, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(kk[2], (H,)))
    dA = dtc.astype(jnp.float32) * A
    dA_cs = jnp.cumsum(dA, axis=2)
    Bc = jax.random.normal(kk[3], (B, NC, Q, G, N), dtype)
    Cc = jax.random.normal(kk[4], (B, NC, Q, G, N), dtype)
    yk, stk = ops.ssd_chunk(xc, dtc, dA, dA_cs, Bc, Cc)
    yr, sr = ref.ssd_chunk_ref(xc.astype(jnp.float32), dtc.astype(jnp.float32),
                               dA, dA_cs, Bc.astype(jnp.float32),
                               Cc.astype(jnp.float32))
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), **tol)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(sr), **tol)


def test_ssd_kernel_inside_model_path():
    """ssd_chunked(use_kernel=True) == XLA path on full scan."""
    from repro.models.ssm import ssd_chunked
    kk = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(kk[0], (2, 48, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(kk[1], (2, 48, 4)))
    A = -jnp.exp(jax.random.normal(kk[2], (4,)))
    Bm = jax.random.normal(kk[3], (2, 48, 2, 16))
    Cm = jax.random.normal(kk[4], (2, 48, 2, 16))
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=False)
    y2, s2 = ssd_chunked(x, dt, A, Bm, Cm, 16, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-4)
