"""Serving engine: slot-based continuous batching, shared online bandit."""
import numpy as np

from repro.core import make_controller
from repro.core.engine import EngineSpec
from repro.serving.engine import SpecServer


def test_server_drains_and_matches_generate(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=6, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2)
    prompts = [[1, 5, 9, 13], [2, 6, 10, 14], [3, 7, 11, 15]]
    ids = [srv.submit(p, 20) for p in prompts]
    responses = srv.run_until_drained()
    assert len(responses) == 3
    assert {r.request_id for r in responses} == set(ids)
    for r in responses:
        assert r.result.new_tokens >= 20
        assert r.latency_s >= r.queue_delay_s >= 0
    stats = srv.throughput_stats()
    assert stats["n_requests"] == 3
    assert stats["total_new_tokens"] >= 60
    assert 0 <= stats["accept_rate"] <= 1
    assert stats["p95_latency_s"] >= stats["p50_latency_s"] >= 0
    # the shared bandit saw every per-stream session observation
    assert ctrl.bandit.t == sum(len(r.result.sessions) for r in responses)


def test_server_interleaves_streams(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=4, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2)
    srv.submit([1, 5, 9, 13], 40)
    srv.submit([2, 6, 10, 14], 8)
    finished = []
    for _ in range(200):
        finished.extend(srv.step())
        if len(finished) == 2:
            break
    # the short request must finish first despite being submitted second
    assert finished[0] == 1


def test_server_slot_reuse_without_recompile(tiny_dense_pair):
    """A queued request must take over a freed slot and complete; the
    batched session program is shared (fixed B), so the slot handoff is
    just a cache-lane overwrite."""
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=4, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2)
    # same prompt length everywhere -> admission prefill reuses jit programs
    for i in range(5):
        srv.submit([1 + i, 5, 9, 13], 10)
    responses = srv.run_until_drained()
    assert len(responses) == 5
    # with B=2 slots and 5 requests, at least one slot was reused 2+ times
    assert all(r.result.new_tokens >= 10 for r in responses)
    # later arrivals queued behind a full pool
    by_id = {r.request_id: r for r in responses}
    assert by_id[4].queue_delay_s >= by_id[0].queue_delay_s


def test_server_queue_caps_concurrency(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=4, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2)
    for i in range(4):
        srv.submit([1 + i, 5, 9, 13], 12)
    srv.step()
    assert len(srv.active) <= 2
    assert len(srv.queue) == 2
    srv.run_until_drained()
    assert len(srv.responses) == 4


def test_repeated_admission_races_keep_fifo_and_drop_nothing(
        tiny_dense_pair):
    """``can_admit`` is a probe, not a reservation.  When the probe is
    wrong EVERY tick (forced here), each failed ``open_stream`` must
    re-queue the request at the HEAD — so across many consecutive races
    the FIFO order never reshuffles and no request is ever dropped; once
    blocks free up, admission proceeds in the original submit order."""
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    srv = SpecServer(draft, target, ctrl, spec=EngineSpec(
        backend="paged", batch_size=2, max_len=256, block_size=8,
        pool_tokens=9 * 8, prefix_cache=True))    # 9 usable blocks
    rng = np.random.default_rng(3)
    # hog reserves 20 + 16 + 3 + 2 tokens -> 6 of the 9 usable blocks
    hog = srv.submit(rng.integers(1, 60, size=20).tolist(), 16)
    # each waiter needs 4 blocks > the 3 left while the hog runs
    waiters = [srv.submit(rng.integers(1, 60, size=12).tolist(), 10)
               for _ in range(3)]
    srv.engine.can_admit = lambda *a, **k: True      # force the race
    srv.step()
    assert list(srv._slot_rid.values()) == [hog]
    races = 0
    while hog not in [r.request_id for r in srv.responses]:
        assert list(srv.queue) == waiters, \
            "a failed admission reshuffled or dropped the FIFO queue"
        srv.step()
        races += 1
        assert races < 100
    assert srv.backpressure_events >= 2, "expected repeated races"
    res = {r.request_id: r for r in srv.run_until_drained(timeout_s=600)}
    assert sorted(res) == sorted([hog] + waiters), "request dropped"
    for rid in waiters:
        assert res[rid].result.new_tokens >= 10
    # FIFO preserved through every race: first-submitted admits first
    admits = [res[r].queue_delay_ticks for r in waiters]
    assert admits == sorted(admits), admits
    assert srv.engine.dalloc.check_conservation()
    assert srv.engine.talloc.check_conservation()
