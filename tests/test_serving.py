"""Serving engine: round-robin continuous batching, shared online bandit."""
import numpy as np

from repro.core import make_controller
from repro.serving.engine import SpecServer


def test_server_drains_and_matches_generate(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=6, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2)
    prompts = [[1, 5, 9, 13], [2, 6, 10, 14], [3, 7, 11, 15]]
    ids = [srv.submit(p, 20) for p in prompts]
    responses = srv.run_until_drained()
    assert len(responses) == 3
    assert {r.request_id for r in responses} == set(ids)
    for r in responses:
        assert r.result.new_tokens >= 20
        assert r.latency_s >= r.queue_delay_s >= 0
    stats = srv.throughput_stats()
    assert stats["n_requests"] == 3
    assert stats["total_new_tokens"] >= 60
    assert 0 <= stats["accept_rate"] <= 1
    # the shared bandit saw sessions from every request
    assert ctrl.bandit.t == sum(len(r.result.sessions) for r in responses)


def test_server_interleaves_streams(tiny_dense_pair):
    draft, target = tiny_dense_pair
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=4, seed=0)
    srv = SpecServer(draft, target, ctrl, max_len=256, max_concurrency=2)
    srv.submit([1, 5, 9, 13], 40)
    srv.submit([2, 6, 10, 14], 8)
    finished = []
    for _ in range(200):
        rid = srv.step()
        if rid is not None:
            finished.append(rid)
        if len(finished) == 2:
            break
    # the short request must finish first despite being submitted second
    assert finished[0] == 1
