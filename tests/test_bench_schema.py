"""Unit tests for scripts/check_bench_schema.py — the lint-lane gate that
keeps BENCH_serving.json rows attributable (engine blob, drafter identity,
MoE routed-expert stats, encoder shared-segment stats)."""
import importlib.util
import os

_PATH = os.path.join(os.path.dirname(__file__), "..", "scripts",
                     "check_bench_schema.py")
_spec = importlib.util.spec_from_file_location("check_bench_schema", _PATH)
cbs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cbs)

TS = "2026-08-09T00:00:00Z"


def _row(bench, summary):
    return {"bench": bench, "recorded_at": TS, "summary": summary}


def _moe_encoder_summary(**override):
    s = {"claim_encoder_segments_shared": True,
         "claim_moe_routed_cost_banditvisible": True,
         "moe": {"routed_frac": 0.37, "mean_routing_density": 1.4},
         "encoder": {"unique_bytes": 65536, "logical_bytes": 262144,
                     "streams": 4},
         "engine": {"backend": "paged",
                    "moe": {"routed_frac": 0.37}}}
    s.update(override)
    return s


def test_wellformed_rows_pass():
    assert cbs.check_row(0, _row("bench_reward", {"claim_x": True})) == []
    assert cbs.check_row(0, _row("moe_encoder", _moe_encoder_summary())) == []
    assert cbs.check_row(
        0, _row("moe_encoder_smoke", _moe_encoder_summary())) == []


def test_basic_shape_violations():
    assert cbs.check_row(0, ["not", "a", "row"])
    assert cbs.check_row(0, {"bench": "x", "summary": {}})      # missing key
    errs = cbs.check_row(0, _row("x", {"claim_ok": "yes"}))
    assert any("must be bool" in e for e in errs)
    errs = cbs.check_row(0, _row("x", {"tokens_s": 1.0}))
    assert any("no claim_*" in e for e in errs)


def test_moe_encoder_requires_engine_blob():
    errs = cbs.check_row(0, _row("moe_encoder",
                                 _moe_encoder_summary(engine=None)))
    assert any("engine describe() blob" in e for e in errs)


def test_moe_encoder_requires_routed_expert_stats():
    for bad in (None, {}, {"routed_frac": 0.3},
                {"routed_frac": "0.3", "mean_routing_density": 1.2},
                {"routed_frac": True, "mean_routing_density": 1.2}):
        errs = cbs.check_row(0, _row("moe_encoder",
                                     _moe_encoder_summary(moe=bad)))
        assert any("routed-expert stats" in e for e in errs), bad


def test_moe_encoder_requires_shared_segment_stats():
    for bad in (None, {}, {"unique_bytes": 1, "logical_bytes": 2},
                {"unique_bytes": 1, "logical_bytes": None, "streams": 2}):
        errs = cbs.check_row(0, _row("moe_encoder",
                                     _moe_encoder_summary(encoder=bad)))
        assert any("shared-segment stats" in e for e in errs), bad


def test_other_benches_unaffected_by_new_rules():
    """A non-moe_encoder bench needs neither 'moe' nor 'encoder' dicts."""
    assert cbs.check_row(0, _row("prefix_sharing",
                                 {"claim_cow": True,
                                  "engine": {"backend": "paged"}})) == []


def test_committed_bench_file_passes():
    """The repo's own BENCH_serving.json must satisfy the checker."""
    assert cbs.main() == 0
