"""Multi-device parity suite (docs/sharding.md).

Runs when the process sees >= 8 devices — CI's multi-device lane forces
them with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before
pytest starts; on a normal 1-device run every in-process test here skips
and only the subprocess-based ``slow`` test executes.

The invariants (docs/sharding.md#numerics):

* DATA-PARALLEL sharding is bitwise — per-lane arithmetic is untouched,
  so greedy outputs are IDENTICAL to the unsharded engines on every
  backend (dense/paged x chain/tree, fp and int8), and paged==dense
  still holds;
* TENSOR-PARALLEL ("model" axis) reorders reductions, which perturbs
  logits at the ulp level — the TP tests assert logit agreement to float
  tolerance and that the full workload serves end to end (an exact-token
  assertion would hinge on genuine near-ties of the random test model;
  int8 KV quantization amplifies those ulps to full quant steps at write
  time);
* the host-side bandit sees the same observations either way, so its
  state after sharded serving equals the host-only path's.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import ModelBundle, make_controller
from repro.core.controller import TapOutTreeSequence
from repro.launch.mesh import forced_host_env, make_host_mesh
from repro.models import ModelConfig
from repro.models import transformer as T

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

multidev = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def pair():
    """Smaller than conftest's tiny_dense_pair: every test here compiles
    its programs twice (sharded + unsharded)."""
    V = 61
    tcfg = ModelConfig(name="md_tgt", arch_type="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=V)
    dcfg = ModelConfig(name="md_drf", arch_type="dense", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=1, d_ff=64,
                       vocab_size=V)
    return (ModelBundle(T.init_params(dcfg, jax.random.PRNGKey(1)), dcfg),
            ModelBundle(T.init_params(tcfg, jax.random.PRNGKey(0)), tcfg))


PROMPTS = [[1, 5, 9, 13], [2, 6, 10, 14], [3, 7, 11, 15]]


def _controller(tree: bool):
    if tree:
        return TapOutTreeSequence(6, "ucb1", "simple", seed=0)
    return make_controller("tapout_seq_ucb1", gamma_max=4, seed=0)


def _serve(pair, mesh=None, tree=False, ticks=None, max_new=6, **kw):
    from repro.serving.engine import SpecServer
    draft, target = pair
    ctrl = _controller(tree)
    srv = SpecServer(draft, target, ctrl, max_len=128, max_concurrency=2,
                     mesh=mesh, tree=tree, **kw)
    for p in PROMPTS:
        srv.submit(p, max_new)
    if ticks is None:
        srv.run_until_drained()
    else:
        for _ in range(ticks):
            srv.step()
    outs = [r.result.tokens
            for r in sorted(srv.responses, key=lambda r: r.request_id)]
    return outs, ctrl, srv


# ------------------------------------------------------- batched engine

@multidev
def test_sharded_batched_engine_matches_unsharded(pair):
    """B=4 BatchedSpecEngine with each slot lane on its own device
    (data=4) produces the exact greedy outputs of the meshless engine,
    slot for slot — data-parallel sharding is bitwise."""
    from repro.core.engine import BatchedSpecEngine
    prompts = PROMPTS + [[4, 8, 12, 16]]

    def run(mesh):
        draft, target = pair
        eng = BatchedSpecEngine(draft, target,
                                make_controller("tapout_seq_ucb1",
                                                gamma_max=4, seed=0),
                                batch_size=4, max_len=128, mesh=mesh)
        for s, p in enumerate(prompts):
            eng.open_stream(s, list(p))
        for _ in range(4):
            eng.session_step_batch()
        return [list(eng.slots[s]["seq"]) for s in range(4)]

    base = run(None)
    sharded = run(make_host_mesh(data=4))
    assert base == sharded


# ------------------------------------------------------- paged == dense

@multidev
def test_paged_equals_dense_under_2x2_mesh(pair):
    """The paged==dense invariant survives sharding: both backends on the
    same 2x2 mesh drain the same workload to identical outputs."""
    mesh = make_host_mesh(data=2, model=2)
    dense, _, _ = _serve(pair, mesh=mesh)
    paged, _, _ = _serve(pair, mesh=mesh, paged=True, block_size=16,
                         pool_tokens=512)
    assert dense == paged


# ------------------------------------------------------- bandit equality

@multidev
def test_bandit_state_equal_after_sharded_tick(pair):
    """TapOut's policy layer is sharding-invariant: after serving ticks on
    a (4,2) mesh the ONE host-side bandit holds exactly the state the
    host-only path produces (same observations, same order-independent
    merge)."""
    _, ctrl_host, _ = _serve(pair, ticks=2)
    _, ctrl_mesh, _ = _serve(pair, mesh=make_host_mesh(data=4, model=2),
                             ticks=2)
    a, b = ctrl_host.bandit.state_dict(), ctrl_mesh.bandit.state_dict()
    assert a["t"] == b["t"]
    np.testing.assert_array_equal(a["counts"], b["counts"])
    np.testing.assert_allclose(a["means"], b["means"], rtol=0, atol=0)
    np.testing.assert_allclose(a["m2"], b["m2"], rtol=0, atol=0)


# ------------------------------------------------------- backend matrix

BACKENDS = {
    "dense_fp": dict(),
    "paged_int8kv": dict(paged=True, block_size=16, pool_tokens=512,
                         kv_dtype="int8"),
    "tree_int8kv": dict(tree=True, kv_dtype="int8"),
}

SLOW_BACKENDS = {
    "dense_int8kv": dict(kv_dtype="int8"),
    "dense_qdraft": dict(quant_draft=True),
    "paged_fp": dict(paged=True, block_size=16, pool_tokens=512),
    "paged_qdraft": dict(paged=True, block_size=16, pool_tokens=512,
                         quant_draft=True),
    "tree_fp": dict(tree=True),
}


def _backend_parity(pair, kw, check_stats=False):
    """Exact output parity on a data-parallel mesh (slot lanes sharded
    2-way, per-lane numerics bitwise — see module docstring)."""
    kw = dict(kw)
    tree = kw.pop("tree", False)
    base, _, _ = _serve(pair, tree=tree, **kw)
    sharded, _, srv = _serve(pair, mesh=make_host_mesh(data=2),
                             tree=tree, **kw)
    assert base == sharded
    if check_stats:
        stats = srv.throughput_stats()
        assert stats["mesh_devices"] == 2
        assert stats["mesh_axes"] == {"data": 2, "model": 1}


@multidev
@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_server_backend_sharded_matches_unsharded(pair, backend):
    _backend_parity(pair, BACKENDS[backend], check_stats=True)


@multidev
@pytest.mark.slow
@pytest.mark.parametrize("backend", sorted(SLOW_BACKENDS))
def test_server_backend_sharded_matches_unsharded_full(pair, backend):
    _backend_parity(pair, SLOW_BACKENDS[backend])


# ------------------------------------------------------- fused tick

@multidev
@pytest.mark.parametrize("backend", ["batched", "paged"])
def test_fused_tick_matches_synchronous_sharded(pair, backend):
    """The fused single-dispatch tick preserves PR 5's sharding-invariance
    guarantee: on the full (4, 2) mesh, fused and synchronous serving
    produce identical greedy outputs and BIT-IDENTICAL bandit state (the
    one-step-delayed outcome readback changes when the host learns, never
    what it learns)."""
    from repro.core import EngineSpec
    from repro.serving.engine import SpecServer
    draft, target = pair
    results = {}
    for fused in (True, False):
        ctrl = _controller(False)
        spec = EngineSpec(backend=backend, batch_size=2, max_len=128,
                          block_size=16,
                          pool_tokens=512 if backend == "paged" else None,
                          fused=fused, mesh=make_host_mesh(data=4, model=2))
        srv = SpecServer(draft, target, ctrl, spec=spec)
        assert srv.engine.fused is fused
        for p in PROMPTS:
            srv.submit(p, 6)
        srv.run_until_drained()
        outs = [r.result.tokens
                for r in sorted(srv.responses, key=lambda r: r.request_id)]
        results[fused] = (outs, ctrl.bandit.state_dict())
    assert results[True][0] == results[False][0]
    a, b = results[True][1], results[False][1]
    assert a["t"] == b["t"]
    np.testing.assert_array_equal(a["counts"], b["counts"])
    np.testing.assert_allclose(a["means"], b["means"], rtol=0, atol=0)
    np.testing.assert_allclose(a["m2"], b["m2"], rtol=0, atol=0)


# ------------------------------------------------- drafter pool

@multidev
def test_drafter_selection_trace_device_count_invariant(pair):
    """Heterogeneous drafter-pool serving (docs/drafters.md) is
    device-count-invariant: the meta-bandit's per-tick (shape, drafter,
    outcome) trace AND every slot's greedy tokens are identical between
    the meshless engine and 4-way data-parallel lanes — drafter selection
    is host policy, never a function of device topology."""
    from repro.core import default_drafters
    from repro.core.engine import BatchedSpecEngine
    draft, target = pair
    prompts = PROMPTS + [[4, 8, 12, 16]]

    def run(mesh):
        pool = default_drafters(draft, target, seed=0)
        ctrl = TapOutTreeSequence(4, "ucb1", "simple",
                                  shapes=pool.shape_pool(4), seed=0)
        eng = BatchedSpecEngine(None, target, ctrl, batch_size=4,
                                max_len=128, mesh=mesh, drafters=pool)
        for s, p in enumerate(prompts):
            eng.open_stream(s, list(p))
        for _ in range(6):
            eng.session_step_batch()
        trace = [(h["shape"], h["drafter"], h["n_drafted"], h["n_accepted"])
                 for h in ctrl.history]
        return trace, [list(eng.slots[s]["seq"]) for s in range(4)]

    base = run(None)
    sharded = run(make_host_mesh(data=4))
    assert base[0] == sharded[0]
    assert base[1] == sharded[1]


# ------------------------------------------------- tensor-parallel mesh

@multidev
def test_tensor_parallel_mesh_logits_agree_and_serve(pair):
    """On the full (4, 2) data x model mesh: single-step logits agree with
    the unsharded model to float tolerance (TP reduction reordering is
    ulp-level, not structural), and the server drains the whole workload
    on a dense AND a paged backend with complete responses."""
    import jax.numpy as jnp
    from repro.models.sharding import use_mesh
    from repro.launch.shardings import cache_shardings, params_shardings

    mesh = make_host_mesh(data=4, model=2)
    _, target = pair
    cache, spec = T.init_cache(target.cfg, 1, 128, jnp.float32)
    toks = jnp.asarray([PROMPTS[0]], jnp.int32)
    lg0, _ = jax.jit(lambda p, t, c: T.step(p, target.cfg, t, c, spec,
                                            all_logits=True))(
        target.params, toks, cache)
    pp = jax.device_put(target.params,
                        params_shardings(mesh, target.params, mode="serve"))
    cc = jax.device_put(cache, cache_shardings(mesh, cache))
    with use_mesh(mesh):
        lg1, _ = jax.jit(lambda p, t, c: T.step(p, target.cfg, t, c, spec,
                                                all_logits=True))(pp, toks, cc)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), atol=2e-5)

    for kw in (dict(), dict(paged=True, block_size=16, pool_tokens=512)):
        outs, _, srv = _serve(pair, mesh=mesh, **kw)
        assert len(outs) == len(PROMPTS)
        assert all(len(o) >= len(p) + 6 for o, p in zip(outs, PROMPTS))
        assert srv.throughput_stats()["mesh_axes"] == {"data": 4, "model": 2}


# ------------------------------------------------------- pool stats

@multidev
def test_paged_pool_stats_report_per_shard_bytes(pair):
    _, _, srv = _serve(pair, mesh=make_host_mesh(data=4, model=2),
                       paged=True, block_size=16, pool_tokens=512)
    stats = srv.engine.pool_stats()
    assert stats["mesh_devices"] == 8
    assert 0 < stats["cache_pool_bytes_per_shard"] <= stats["cache_pool_bytes"]


# ------------------------------------------------- prefix sharing on mesh

@multidev
@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_prefix_sharing_parity_on_mesh(pair, kv_dtype):
    """Shared-prefix admission is bit-identical to fully private admission
    on the forced-8 mesh too (fp and int8 KV): adoption only rewires host
    tables/lengths, so the sharded device programs see the same physical
    rows either way — tokens, arm trace, and bandit state all match."""
    from repro.core.engine import EngineSpec, make_engine

    shared_prefix = np.random.default_rng(7).integers(
        1, 60, size=17).tolist()
    donor = shared_prefix + [11, 22, 33, 44, 55]
    adopter = list(shared_prefix)               # bs | P-1: the COW case

    def run(prefix_cache):
        ctrl = _controller(False)
        eng = make_engine(*pair, ctrl, EngineSpec(
            backend="paged", batch_size=2, max_len=128, block_size=8,
            pool_tokens=512, kv_dtype=kv_dtype, prefix_cache=prefix_cache,
            mesh=make_host_mesh(data=4, model=2)))
        outs = []
        for slot, p in enumerate((donor, adopter)):
            eng.open_stream(slot, list(p), reserve_tokens=len(p) + 20)
            for _ in range(5):
                eng.session_step_batch()
            st = eng.slots[slot]
            outs.append((list(st["seq"]),
                         [(s.n_drafted, s.n_accepted, s.arm)
                          for s in st["res"].sessions]))
        return outs, ctrl.bandit.state_dict(), eng.pool_stats()

    shared, bs_state, stats = run(True)
    private, bp_state, _ = run(False)
    assert shared == private
    assert stats["prefill_tokens_skipped"] == 16
    assert stats["cow_copies"] == 1
    np.testing.assert_array_equal(bs_state["counts"], bp_state["counts"])
    np.testing.assert_allclose(bs_state["means"], bp_state["means"],
                               rtol=0, atol=0)


# ------------------------------------------------- subprocess fallback

_SUBPROC = """
import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.core import ModelBundle, make_controller
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig
from repro.models import transformer as T
from repro.serving.engine import SpecServer

V = 61
tcfg = ModelConfig(name="tgt", arch_type="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=V)
dcfg = ModelConfig(name="drf", arch_type="dense", num_layers=1, d_model=32,
                   num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=V)
draft = ModelBundle(T.init_params(dcfg, jax.random.PRNGKey(1)), dcfg)
target = ModelBundle(T.init_params(tcfg, jax.random.PRNGKey(0)), tcfg)

def serve(mesh):
    srv = SpecServer(draft, target,
                     make_controller("tapout_seq_ucb1", gamma_max=4, seed=0),
                     max_len=128, max_concurrency=2, mesh=mesh)
    for p in [[1, 5, 9, 13], [2, 6, 10, 14]]:
        srv.submit(p, 6)
    srv.run_until_drained()
    return [r.result.tokens
            for r in sorted(srv.responses, key=lambda r: r.request_id)]

base = serve(None)
sharded = serve(make_host_mesh(data=2))     # data-parallel: bitwise parity
assert base == sharded, (base, sharded)
print("SHARDED_PARITY_OK")
"""


@pytest.mark.slow
def test_sharded_server_parity_subprocess():
    """Fallback that runs even when this process has 1 device: spawn a
    fresh interpreter with 8 forced host devices (``forced_host_env``) and
    assert sharded == unsharded greedy serving outputs inside it."""
    env = forced_host_env(8)
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_PARITY_OK" in r.stdout, r.stdout + "\n" + r.stderr
