"""Speculative decoding engine: exactness, rollback paths, accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ar_greedy_decode
from repro.core import (ModelBundle, SpecEngine, StaticGamma, make_controller)
from repro.models import ModelConfig, RGLRUConfig, SSMConfig
from repro.models import transformer as T

PROMPT = [1, 5, 9, 13]


@pytest.mark.parametrize("ckind", ["static", "fixed_svip", "fixed_max_confidence",
                                   "fixed_adaedl", "tapout_seq_ucb1",
                                   "tapout_seq_ts", "tapout_token_ucb1",
                                   "tapout_token_ts", "tapout_seq_ucb_tuned"])
def test_greedy_equivalence_all_controllers(ckind, tiny_dense_pair):
    draft, target = tiny_dense_pair
    ref = ar_greedy_decode(target.params, target.cfg, PROMPT, 40)
    ctrl = make_controller(ckind, gamma_max=8, seed=0)
    eng = SpecEngine(draft, target, ctrl, max_len=256)
    r = eng.generate(PROMPT, 40)
    assert r.tokens[:len(ref)] == ref[:len(r.tokens)]
    assert r.new_tokens >= 40
    # accounting invariants
    for s in r.sessions:
        assert 0 <= s.n_accepted <= s.n_drafted <= ctrl.gamma_max
    # every session emits exactly m+1 tokens
    assert r.total_accepted + len(r.sessions) == r.new_tokens


def test_greedy_equivalence_recurrent_family():
    V = 61
    tcfg = ModelConfig(name="t", arch_type="ssm", num_layers=3, d_model=128,
                       num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=V,
                       block_pattern=("mamba2",),
                       ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=8))
    dcfg = ModelConfig(name="d", arch_type="hybrid", num_layers=3, d_model=64,
                       num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=V,
                       block_pattern=("rglru", "rglru", "local"), window=16,
                       rglru=RGLRUConfig(lru_width=64))
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    ref = ar_greedy_decode(tp, tcfg, PROMPT, 24)
    eng = SpecEngine(ModelBundle(dp, dcfg), ModelBundle(tp, tcfg),
                     make_controller("tapout_seq_ucb1", gamma_max=6), max_len=128)
    assert not eng.draft_cheap and not eng.target_cheap  # recompute path
    r = eng.generate(PROMPT, 24)
    assert r.tokens[:len(ref)] == ref[:len(r.tokens)]


def test_self_speculation_accepts_everything(tiny_dense_pair):
    _, target = tiny_dense_pair
    eng = SpecEngine(target, target, StaticGamma(gamma=6), max_len=256)
    r = eng.generate(PROMPT, 30)
    assert r.accept_rate == 1.0
    assert r.mean_accepted == 6.0


def test_static_gamma_always_drafts_exactly_gamma(tiny_dense_pair):
    draft, target = tiny_dense_pair
    eng = SpecEngine(draft, target, StaticGamma(gamma=5), max_len=256)
    r = eng.generate(PROMPT, 25)
    assert all(s.n_drafted == 5 for s in r.sessions)


def test_stochastic_output_distribution(tiny_dense_pair):
    """Exact speculative sampling: empirical next-token dist ~= target dist."""
    draft, target = tiny_dense_pair
    cache, spec = T.init_cache(target.cfg, 1, 64, jnp.float32)
    lg, _ = T.step(target.params, target.cfg,
                   jnp.asarray([PROMPT], jnp.int32), cache, spec)
    p_tgt = np.asarray(jax.nn.softmax(lg[0, -1]))
    N = 250
    eng = SpecEngine(draft, target, StaticGamma(gamma=3), max_len=64,
                     temperature=1.0, greedy=False, seed=0)
    counts = np.zeros(target.cfg.vocab_size)
    for _ in range(N):
        r = eng.generate(PROMPT, 1)
        counts[r.tokens[len(PROMPT)]] += 1
    tv = 0.5 * np.abs(counts / N - p_tgt).sum()
    assert tv < 0.22, tv


def test_traces_collected(tiny_dense_pair):
    draft, target = tiny_dense_pair
    eng = SpecEngine(draft, target, StaticGamma(gamma=4), max_len=128)
    eng.collect_traces = True
    r = eng.generate(PROMPT, 12)
    assert len(r.traces) == len(r.sessions)
    tr = r.traces[0]
    assert tr["signals"].shape == (4, 6)
    assert tr["n_drafted"] == 4


def test_modeled_cost_monotone(tiny_dense_pair):
    draft, target = tiny_dense_pair
    eng = SpecEngine(draft, target, StaticGamma(gamma=6), max_len=256)
    r1 = eng.generate(PROMPT, 10)
    r2 = eng.generate(PROMPT, 30)
    assert r2.modeled_cost > r1.modeled_cost > 0
