"""Heterogeneous drafter pool (docs/drafters.md): SSD drafter parity with
the direct ``models/ssm.py`` forward, EAGLE-head dense==paged parity,
greedy-verify invariance while the meta-bandit switches drafters, O(1)
SSD draft state, and the zero-retrace-after-warmup guarantee."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ar_greedy_decode
from conftest import drain_streams as _drain
from repro.core import (EngineSpec, StaticGamma, default_drafters,
                        eagle_bundle, init_eagle_head, make_engine,
                        ssd_draft_bundle)
from repro.core.controller import TapOutTreeSequence
from repro.core.engine import BatchedSpecEngine
from repro.models import transformer as T

PROMPTS = [[1, 5, 9, 13, 17, 21],
           [2, 6, 10, 14, 18, 22, 26, 30],
           [3, 7, 11, 15, 19]]


@pytest.fixture(scope="module")
def pool(tiny_dense_pair):
    draft, target = tiny_dense_pair
    return default_drafters(draft, target, seed=0)


def _pool_controller(pool, gamma_max=4, seed=0, reward="simple"):
    return TapOutTreeSequence(gamma_max, "ucb1", reward,
                              shapes=pool.shape_pool(gamma_max), seed=seed)


# ------------------------------------------------ SSD drafter parity

def test_ssd_incremental_matches_full_forward(tiny_dense_pair):
    """The SSD draft's cached decode recurrence (conv window + ssm state,
    what the engine's draft lanes run) greedy-decodes the exact token
    sequence of the direct full-sequence ``models/ssm.py`` forward."""
    _, target = tiny_dense_pair
    bundle = ssd_draft_bundle(target.cfg, seed=3)
    prompt = [1, 5, 9, 13, 2, 6]
    inc = ar_greedy_decode(bundle.params, bundle.cfg, prompt, 24, max_len=96)
    seq = list(prompt)
    for _ in range(24):
        h, _ = T.forward_hidden(bundle.params, bundle.cfg,
                                jnp.asarray([seq], jnp.int32), remat=False)
        lg = T.logits_fn(bundle.params, bundle.cfg, h[:, -1:])
        seq.append(int(jnp.argmax(lg[0, -1])))
    assert inc == seq


def test_ssd_state_is_o1_in_sequence_length(pool):
    """Per-stream draft-state bytes: constant in L for the SSD drafter,
    strictly linear for the KV drafters."""
    assert pool.state_bytes("ssd", 128) == pool.state_bytes("ssd", 4096)
    for name in ("kv", "eagle"):
        b128, b4k = pool.state_bytes(name, 128), pool.state_bytes(name, 4096)
        assert b4k == 32 * b128 > 0
    # int8 KV shrinks the linear term but not the O(1) recurrent state
    assert pool.state_bytes("kv", 4096, "int8") < pool.state_bytes("kv", 4096)
    assert pool.state_bytes("ssd", 4096, "int8") == pool.state_bytes("ssd",
                                                                     4096)


# ------------------------------------------------ EAGLE head parity

def test_eagle_drafter_dense_vs_paged_identical(tiny_dense_pair):
    """The assembled EAGLE-head bundle is an ordinary 1-layer draft: the
    dense and paged backends serve it to identical greedy tokens."""
    _, target = tiny_dense_pair
    _, head = init_eagle_head(target.cfg, jax.random.PRNGKey(7))
    draft = eagle_bundle(target, head)
    outs = []
    for spec in (EngineSpec(backend="single", max_len=128),
                 EngineSpec(backend="paged", max_len=128, block_size=16,
                            pool_tokens=1024)):
        eng = make_engine(draft, target, StaticGamma(gamma=4), spec)
        if spec.backend == "paged":
            eng.open_stream(0, list(PROMPTS[0]))
            while not eng.slots[0]["done"] and \
                    eng.slots[0]["res"].new_tokens < 20:
                eng.session_step_batch()
            outs.append(eng.close_stream(0)["seq"][:len(PROMPTS[0]) + 20])
        else:
            outs.append(eng.generate(PROMPTS[0], 20).tokens)
    n = len(PROMPTS[0]) + 20
    assert len(outs[0]) >= n and len(outs[1]) >= n
    assert outs[0][:n] == outs[1][:n]


# ------------------------------------------------ pool serving

@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_pool_greedy_invariance_under_drafter_switching(tiny_dense_pair,
                                                        pool, kv_dtype):
    """Greedy-verify invariance survives the drafter axis: with the
    meta-bandit switching (drafter, stop-rule) arms every tick, every
    stream's output equals the pure target greedy decode — and all three
    drafters actually get pulled."""
    _, target = tiny_dense_pair
    ctrl = _pool_controller(pool, gamma_max=4, seed=0)
    eng = BatchedSpecEngine(None, target, ctrl, batch_size=3, max_len=128,
                            kv_dtype=kv_dtype, drafters=pool)
    max_new = 24
    refs = [ar_greedy_decode(target.params, target.cfg, p, max_new)
            for p in PROMPTS]
    states = _drain(eng, PROMPTS, max_new)
    for st, ref in zip(states, refs):
        n = min(len(ref), len(st["seq"]))
        assert st["seq"][:n] == ref[:n]
    assert len(set(pool.names) & {h.get("drafter") for h in ctrl.history}) == 3
    # one bandit pull per LANE per tick, one history row per tick
    assert sum(ctrl.drafter_pulls.values()) == \
        sum(h["batch"] for h in ctrl.history)


def test_describe_and_spec_stamp_drafter_identity(tiny_dense_pair, pool):
    """``engine.describe()`` carries the full drafter blob, and
    ``EngineSpec(drafters=...)`` resolves to the batched backend."""
    draft, target = tiny_dense_pair
    spec = EngineSpec(drafters=pool, batch_size=2, max_len=128)
    assert spec.resolve_backend() == "batched"
    eng = make_engine(draft, target, _pool_controller(pool), spec)
    blob = eng.describe()["drafter"]
    assert blob["name"] == "kv" and blob["kind"] == "kv"
    assert blob["pool"]["names"] == ["kv", "eagle", "ssd"]
    assert blob["pool"]["kinds"]["ssd"] == "ssd"
    assert blob["pool"]["state_bytes"]["kv"] > blob["pool"]["state_bytes"]["ssd"]
    with pytest.raises(ValueError):
        make_engine(draft, target, _pool_controller(pool),
                    EngineSpec(drafters=pool, backend="paged"))


# ------------------------------------------------ zero-retrace switching

def test_drafter_switching_zero_retrace_after_warmup(tiny_dense_pair, pool):
    """After a warmup that visits every (drafter, stop-rule) arm and both
    chunked feed shapes, drafter switching — including stream churn and
    per-drafter lane catch-up — adds ZERO new jit trace-cache entries."""
    _, target = tiny_dense_pair
    ctrl = _pool_controller(pool, gamma_max=4, seed=0)
    # prefill_chunk=4 so prompts and lane catch-up exercise BOTH feed
    # shapes (4 and 1) during warmup
    eng = BatchedSpecEngine(None, target, ctrl, batch_size=2, max_len=256,
                            prefill_chunk=4, drafters=pool)
    # warmup: round-robin every shape arm (instance attr shadows method),
    # with churn so fresh-lane resets and prefill shapes are also traced
    rr = itertools.cycle(range(len(ctrl.shapes)))
    ctrl.begin_shape = lambda: next(rr)
    eng.open_stream(0, PROMPTS[0])
    eng.open_stream(1, PROMPTS[1])
    for tick in range(2 * len(ctrl.shapes)):
        eng.session_step_batch()
        if tick == len(ctrl.shapes):  # churn mid-warmup
            eng.close_stream(0)
            eng.open_stream(0, PROMPTS[2])
    del ctrl.begin_shape  # restore the real meta-bandit draw
    warm = eng.jit_cache_sizes()
    assert all(v != 0 for v in warm.values()), warm

    eng.close_stream(1)
    eng.open_stream(1, PROMPTS[0])
    used = set()
    for _ in range(30):
        eng.session_step_batch()
        used.add(ctrl.history[-1]["drafter"])
        for s in (0, 1):
            st = eng.slots[s]
            if st["done"] or st["res"].new_tokens >= 40:
                eng.close_stream(s)
                eng.open_stream(s, PROMPTS[s])
    assert eng.jit_cache_sizes() == warm, (warm, eng.jit_cache_sizes())
    assert len(used) >= 2, used
