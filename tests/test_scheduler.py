"""SLO-aware scheduling: open-loop workload synthesis, chunked prefill
(bit parity with monolithic admission), preemption/resume (bit parity and
allocator conservation), priority/EDF admission order, and the drain
timeout diagnostic (docs/slo_scheduling.md)."""
import jax
import numpy as np
import pytest

from repro.core import ModelBundle, make_controller
from repro.core.engine import (EngineSpec, _chunk_schedule, make_engine)
from repro.models import ModelConfig
from repro.models import transformer as T
from repro.serving.engine import SpecServer
from repro.serving.scheduler import SLOScheduler
from repro.workload import (LengthDist, WorkloadClass, arrival_ticks,
                            bursty_arrivals, load_trace, poisson_arrivals,
                            save_trace, synthesize)


# --------------------------------------------------------------- workload

def test_poisson_arrivals_deterministic_and_calibrated():
    a = poisson_arrivals(rate=0.5, n=4000, seed=3)
    b = poisson_arrivals(rate=0.5, n=4000, seed=3)
    assert np.array_equal(a, b), "same seed must replay the same trace"
    assert (np.diff(a) > 0).all()
    mean_gap = float(np.diff(a).mean())
    assert abs(mean_gap - 2.0) / 2.0 < 0.1, mean_gap


def test_bursty_arrivals_preserve_mean_rate_but_add_burstiness():
    """The MMPP's two rates are solved so the LONG-RUN rate matches the
    requested one — burstiness changes the variance, not the load."""
    rate, n = 0.5, 6000
    burst = bursty_arrivals(rate=rate, n=n, seed=1, burst_factor=8.0)
    calm = poisson_arrivals(rate=rate, n=n, seed=1)
    mean_gap = float(np.diff(burst).mean())
    assert abs(mean_gap - 1.0 / rate) * rate < 0.15, mean_gap
    # squared coefficient of variation: Poisson ~1, MMPP strictly above
    def cv2(t):
        g = np.diff(t)
        return float(g.var() / g.mean() ** 2)
    assert cv2(burst) > 1.5 * cv2(calm), (cv2(burst), cv2(calm))


def test_arrival_ticks_floor():
    assert arrival_ticks([0.0, 0.9, 1.0, 2.7], tick_s=1.0).tolist() == \
        [0, 0, 1, 2]
    assert arrival_ticks([0.6, 1.1], tick_s=0.5).tolist() == [1, 2]


def test_length_dist_kinds_and_roundtrip():
    rng = np.random.default_rng(0)
    assert (LengthDist("fixed", (7,)).sample(5, rng) == 7).all()
    u = LengthDist("uniform", (4, 9)).sample(500, rng)
    assert u.min() >= 4 and u.max() <= 9
    ln = LengthDist("lognormal", (40.0, 0.6), lo_clip=2).sample(4000, rng)
    assert ln.min() >= 2
    assert abs(float(ln.mean()) - 40.0) / 40.0 < 0.15, float(ln.mean())
    d = LengthDist("uniform", (4, 9), lo_clip=3, hi_clip=8)
    assert LengthDist.from_json(d.to_json()) == d
    with pytest.raises(ValueError):
        LengthDist("zipf", (2.0,))


def test_synthesize_and_trace_roundtrip(tmp_path):
    classes = [
        WorkloadClass(name="interactive", priority=1, slo_ticks=8,
                      prompt_len=LengthDist("uniform", (4, 8)),
                      output_len=LengthDist("fixed", (6,)), weight=0.5),
        WorkloadClass(name="batch", priority=0, slo_ticks=None,
                      prompt_len=LengthDist("fixed", (20,)),
                      output_len=LengthDist("fixed", (16,)), weight=0.5),
    ]
    tr = synthesize(classes, rate=0.5, n=40, seed=9, vocab=61, bursty=True)
    assert tr == synthesize(classes, rate=0.5, n=40, seed=9, vocab=61,
                            bursty=True), "synthesis must be deterministic"
    assert {t.cls for t in tr} == {"interactive", "batch"}
    for t in tr:
        assert all(1 <= tok < 61 for tok in t.prompt)
        if t.cls == "interactive":
            assert t.priority == 1 and t.slo_ticks == 8
            assert 4 <= len(t.prompt) <= 8 and t.max_new_tokens == 6
        else:
            assert t.priority == 0 and t.slo_ticks is None
    p = tmp_path / "trace.json"
    save_trace(str(p), tr)
    assert load_trace(str(p)) == tr


# ---------------------------------------------------------- chunk schedule

def test_chunk_schedule_windows_then_singles():
    assert _chunk_schedule(10, 4) == [(0, 4), (4, 8), (8, 9), (9, 10)]
    assert _chunk_schedule(8, 4) == [(0, 4), (4, 8)]
    assert _chunk_schedule(3, 8) == [(0, 1), (1, 2), (2, 3)]
    assert _chunk_schedule(0, 4) == []


# ----------------------------------------------------------- engine level

@pytest.fixture(scope="module")
def pair():
    V = 61
    tcfg = ModelConfig(name="tgt", arch_type="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=V)
    dcfg = ModelConfig(name="drf", arch_type="dense", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=1, d_ff=64,
                       vocab_size=V)
    tp = T.init_params(tcfg, jax.random.PRNGKey(0))
    dp = T.init_params(dcfg, jax.random.PRNGKey(1))
    return ModelBundle(dp, dcfg), ModelBundle(tp, tcfg)


def _mk(pair, kv_dtype=None, prefix_cache=True, pool_tokens=512):
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    return make_engine(*pair, ctrl, EngineSpec(
        backend="paged", batch_size=4, max_len=256, block_size=8,
        pool_tokens=pool_tokens, prefix_cache=prefix_cache,
        kv_dtype=kv_dtype, prefill_chunk=8))


PROMPT = np.random.default_rng(0).integers(1, 60, size=37).tolist()


def test_chunked_prefill_matches_monolithic_bitwise(pair):
    """Same jitted program (``chunk_prefill_paged``) drives both the
    monolithic admission prefill and the incremental ``prefill_step``
    path, so the decoded continuation is bit-identical; ticks taken while
    a slot is mid-prefill are true no-ops (masked lane, no bandit
    drift)."""
    e1 = _mk(pair)
    e1.open_stream(0, list(PROMPT), reserve_tokens=len(PROMPT) + 30)
    for _ in range(4):
        e1.session_step_batch()
    ref = list(e1.slots[0]["seq"])

    e2 = _mk(pair)
    st = e2.open_stream_chunked(0, list(PROMPT),
                                reserve_tokens=len(PROMPT) + 30)
    assert st.get("prefilling")
    assert not e2.active_mask().any(), "mid-prefill slots must be masked"
    fed_total = 0
    while e2.slots[0].get("prefilling"):
        fed = e2.prefill_step(0, 8)
        assert 1 <= fed <= 8 + 8 - 1, "budget bound: one window of slack"
        fed_total += fed
        if e2.slots[0].get("prefilling"):
            e2.session_step_batch()      # interleaved ticks: no-ops
    assert fed_total == len(PROMPT) - 1
    assert int(np.asarray(e2.dcache["lengths"])[0]) == len(PROMPT) - 1
    for _ in range(4):
        e2.session_step_batch()
    assert list(e2.slots[0]["seq"]) == ref
    assert e2.controller.bandit.t == e1.controller.bandit.t, \
        "masked prefill ticks fed the bandit"


@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_preempt_resume_is_bit_identical(pair, kv_dtype):
    """Preempt mid-decode, run an unrelated stream, resume: the final
    sequence equals the uninterrupted run bit-for-bit (greedy accept =
    target greedy), and resume re-adopts the frozen KV from the prefix
    cache instead of recomputing it."""
    rng = np.random.default_rng(4)
    eng = _mk(pair, kv_dtype=kv_dtype)
    eng.open_stream(0, list(PROMPT), reserve_tokens=len(PROMPT) + 60)
    for _ in range(8):
        eng.session_step_batch()
    ref = list(eng.slots[0]["seq"])

    e2 = _mk(pair, kv_dtype=kv_dtype)
    e2.open_stream(0, list(PROMPT), reserve_tokens=len(PROMPT) + 60)
    for _ in range(3):
        e2.session_step_batch()
    frozen = e2.preempt_stream(0)
    assert e2.slots[0] is None
    other = rng.integers(1, 60, size=12).tolist()
    e2.open_stream(1, other, reserve_tokens=len(other) + 20)
    e2.session_step_batch()
    skipped_before = e2.prefill_tokens_skipped
    e2.open_stream(0, frozen["seq"], frozen["eos_id"],
                   reserve_tokens=len(frozen["seq"]) + 40,
                   resume_from=frozen["res"])
    for _ in range(5):
        e2.session_step_batch()
    assert list(e2.slots[0]["seq"]) == ref
    ps = e2.pool_stats()
    assert ps["preemptions"] == 1 and ps["resumes"] == 1
    assert e2.prefill_tokens_skipped - skipped_before > 0, \
        "resume recomputed KV the prefix cache should have kept warm"
    assert e2.slots[0]["res"] is frozen["res"], \
        "resume must continue the SAME GenResult (session history intact)"


def test_allocator_conservation_across_preemption_churn(pair):
    """free + in_use == num_blocks - 1 (trash block excluded) after many
    preempt/resume/close cycles — no leaked or double-freed blocks."""
    rng = np.random.default_rng(11)
    eng = _mk(pair, pool_tokens=640)
    frozen = {}
    for round_ in range(3):
        for slot in range(3):
            if slot in frozen:
                f = frozen.pop(slot)
                eng.open_stream(slot, f["seq"], f["eos_id"],
                                reserve_tokens=len(f["seq"]) + 30,
                                resume_from=f["res"])
            else:
                p = rng.integers(1, 60, size=rng.integers(9, 30)).tolist()
                eng.open_stream(slot, p, reserve_tokens=len(p) + 30)
        for _ in range(2):
            eng.session_step_batch()
        frozen[round_ % 3] = eng.preempt_stream(round_ % 3)
        for slot in range(3):
            if eng.slots[slot] is not None:
                eng.close_stream(slot)
        assert eng.dalloc.check_conservation(), f"draft pool, round {round_}"
        assert eng.talloc.check_conservation(), f"target pool, round {round_}"
    assert eng.pool_stats()["preemptions"] == 3


def test_check_conservation_catches_corruption(pair):
    from repro.models.cache import BlockAllocator
    a = BlockAllocator(num_blocks=16, max_blocks=8, batch=2)
    a.allocate(0, 3)
    assert a.check_conservation()
    leaked = a.free.pop()                      # leak a block
    assert not a.check_conservation()
    a.free.append(leaked)
    a.free.append(a.owned[0][0])               # double-free a live block
    assert not a.check_conservation()


# ----------------------------------------------------------- server level

def _srv(pair, scheduler, batch_size=2, pool_tokens=512, gamma_max=3):
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=gamma_max, seed=0)
    return SpecServer(*pair, ctrl, spec=EngineSpec(
        backend="paged", batch_size=batch_size, max_len=256, block_size=8,
        pool_tokens=pool_tokens, prefix_cache=True),
        scheduler=scheduler)


def test_slo_scheduler_requires_paged(tiny_dense_pair):
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=3, seed=0)
    with pytest.raises(ValueError, match="paged"):
        SpecServer(*tiny_dense_pair, ctrl, spec=EngineSpec(
            backend="batched", batch_size=2, max_len=256),
            scheduler=SLOScheduler())


def test_priority_and_edf_admission_order(pair):
    """With preemption off and one slot, admission order alone decides
    completion order: priority first, earliest deadline within a
    priority, no-SLO requests last."""
    rng = np.random.default_rng(5)
    srv = _srv(pair, SLOScheduler(preempt=False,
                                  max_prefill_tokens_per_tick=64),
               batch_size=1)
    rids = [
        srv.submit(rng.integers(1, 60, size=6).tolist(), 4,
                   priority=0, slo_ticks=None),           # last
        srv.submit(rng.integers(1, 60, size=6).tolist(), 4,
                   priority=1, slo_ticks=50),             # second
        srv.submit(rng.integers(1, 60, size=6).tolist(), 4,
                   priority=1, slo_ticks=5),              # first: EDF
    ]
    done = []
    for _ in range(200):
        done += srv.step()
        if len(done) == 3:
            break
    assert done == [rids[2], rids[1], rids[0]]
    assert srv.throughput_stats()["preemption_events"] == 0


def test_high_priority_preempts_and_victim_completes(pair):
    """A tight-SLO request arriving into a full pool evicts a low-priority
    stream, meets its deadline, and the victim resumes warm and still
    produces its full output."""
    rng = np.random.default_rng(1)
    srv = _srv(pair, SLOScheduler(max_prefill_tokens_per_tick=16))
    lo = [srv.submit(rng.integers(1, 60, size=24).tolist(), 40, priority=0)
          for _ in range(2)]
    for _ in range(4):
        srv.step()
    hi = srv.submit(rng.integers(1, 60, size=10).tolist(), 8, priority=5,
                    slo_ticks=12)
    res = srv.run_until_drained(timeout_s=600)
    assert len(res) == 3
    by_rid = {r.request_id: r for r in res}
    assert by_rid[hi].slo_met, by_rid[hi].latency_ticks
    stats = srv.throughput_stats()
    assert stats["preemption_events"] >= 1
    assert stats["resume_events"] == stats["preemption_events"]
    assert sum(by_rid[r].n_preemptions for r in lo) == \
        stats["preemption_events"]
    for rid in lo:      # victims keep their full token budget
        assert by_rid[rid].result.new_tokens >= 40
    assert stats["per_priority"]["5"]["slo_met_frac"] == 1.0
    assert srv.engine.dalloc.check_conservation()
    assert srv.engine.talloc.check_conservation()


def test_queue_delay_tick_accounting(pair):
    """queue_delay_ticks = first admission - submit; latency_ticks >=
    queue_delay_ticks; slo_met is a pure tick comparison."""
    rng = np.random.default_rng(6)
    srv = _srv(pair, SLOScheduler(max_prefill_tokens_per_tick=64),
               batch_size=1)
    a = srv.submit(rng.integers(1, 60, size=6).tolist(), 4, slo_ticks=100)
    b = srv.submit(rng.integers(1, 60, size=6).tolist(), 4, slo_ticks=1)
    res = {r.request_id: r for r in srv.run_until_drained(timeout_s=600)}
    # EDF: b's deadline (tick 1) ranks it first despite submit order
    assert res[b].queue_delay_ticks == 0
    assert res[a].queue_delay_ticks > 0, "a waited for b's slot"
    for r in res.values():
        assert r.latency_ticks >= r.queue_delay_ticks >= 0
    assert res[a].slo_met and not res[b].slo_met, \
        "b cannot finish within 1 tick; a's 100-tick SLO holds"
    st = srv.throughput_stats()
    assert st["p95_queue_delay_s"] >= st["p50_queue_delay_s"] >= 0
    assert set(st["per_priority"]) == {"0"}


def test_drain_timeout_raises_with_diagnostic(pair):
    srv = _srv(pair, SLOScheduler())
    srv.submit(np.random.default_rng(2).integers(1, 60, size=10).tolist(),
               200)
    with pytest.raises(TimeoutError) as ei:
        srv.run_until_drained(timeout_s=0.0)
    msg = str(ei.value)
    for needle in ("tick=", "queued=", "backpressure_events=",
                   "pool: free_blocks="):
        assert needle in msg, msg
