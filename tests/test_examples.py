"""Fast smoke for the example entry points: each example's ``main`` runs
end to end against a tiny random-init pair, so interface drift between the
examples and the library (engine/server/controller signatures) breaks CI
instead of users.  Heavy pieces (trained checkpoints, big configs, long
generations) are monkeypatched down to seconds-scale equivalents — the
point is exercising the example's own code path, not its quality."""
import importlib.util
import os
import sys
from dataclasses import replace

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load_example(name):
    path = os.path.join(ROOT, "examples", f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"examples_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def tiny_trained_pair(tiny_dense_pair):
    """Stand-in for ``benchmarks.common.trained_pair`` (which trains for
    minutes): the session-scoped random-init pair with unit costs."""
    draft, target = tiny_dense_pair
    def fake(name, **kw):
        return draft, target
    return fake


def test_quickstart_main(monkeypatch, tiny_trained_pair):
    mod = _load_example("quickstart")
    monkeypatch.setattr(mod, "trained_pair", tiny_trained_pair)
    real_make = mod.make_controller
    monkeypatch.setattr(mod, "make_controller",
                        lambda kind, gamma_max=16, **kw:
                        real_make(kind, gamma_max=4, **kw))
    real_make_engine = mod.make_engine
    def tiny_make_engine(draft, target, controller, spec=None, **fields):
        eng = real_make_engine(draft, target, controller,
                               replace(spec, max_len=160), **fields)
        real_gen = eng.generate
        eng.generate = (lambda prompt, max_new_tokens, eos_id=None:
                        real_gen(prompt[:8], min(max_new_tokens, 8), eos_id))
        return eng
    monkeypatch.setattr(mod, "make_engine", tiny_make_engine)
    mod.main()


def test_serve_tapout_main(monkeypatch, tiny_trained_pair, capsys):
    mod = _load_example("serve_tapout")
    monkeypatch.setattr(mod, "trained_pair", tiny_trained_pair)
    real_make = mod.make_controller
    monkeypatch.setattr(mod, "make_controller",
                        lambda kind, gamma_max=16, **kw:
                        real_make(kind, gamma_max=4, **kw))
    real_static = mod.StaticGamma
    monkeypatch.setattr(mod, "StaticGamma",
                        lambda gamma, **kw: real_static(gamma=3, **kw))
    real_server = mod.SpecServer
    class TinyServer(real_server):
        def __init__(self, draft, target, controller, *, spec, **kw):
            super().__init__(draft, target, controller,
                             spec=replace(spec, max_len=160, batch_size=2))
    monkeypatch.setattr(mod, "SpecServer", TinyServer)
    monkeypatch.setattr(sys, "argv",
                        ["serve_tapout.py", "--requests", "2", "--max-new", "6"])
    mod.main()
    out = capsys.readouterr().out
    assert "modeled speedup over Static-6" in out


def test_arch_spec_decode_main(monkeypatch, capsys):
    mod = _load_example("arch_spec_decode")
    monkeypatch.setattr(sys, "argv",
                        ["arch_spec_decode.py", "--arch", "qwen3-4b",
                         "--max-new", "6"])
    real_make = mod.make_controller
    monkeypatch.setattr(mod, "make_controller",
                        lambda kind, gamma_max=16, **kw:
                        real_make(kind, gamma_max=3, **kw))
    mod.main()
    assert "tokens" in capsys.readouterr().out.lower()
