"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block.

The SSD algorithm splits the sequence into chunks of Q tokens: inside a
chunk the computation is attention-like dense matmuls (MXU work — this
kernel); across chunks a tiny recurrence over (H, P, N) states remains in
XLA (`repro.models.ssm.ssd_chunked`).

Per (batch, chunk, head) grid cell this kernel computes
    y_diag  = ((C B^T) .* L) diag(dt) X        (Q,P)
    state   = B^T  (decay_to_end * dt * X)     (P,N)
where L = exp(segsum(dA)) is the lower-triangular decay matrix.

Layouts: x (B, NC, Q, H, P), dt/dA/dA_cs (B, NC, Q, H), Bm/Cm (B, NC, Q, G, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, dacs_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)
    dacs = dacs_ref[0, 0, :, 0].astype(jnp.float32)     # (Q,) inclusive cumsum of dA
    Bm = b_ref[0, 0, :, 0, :].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0, 0, :, 0, :].astype(jnp.float32)       # (Q, N)
    Q = x.shape[0]

    # L[i,j] = exp(dacs[i] - dacs[j]) for i >= j else 0
    seg = dacs[:, None] - dacs[None, :]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    L = jnp.exp(jnp.where(tri > 0, seg, -jnp.inf)) * tri

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    scores = CB * L * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ()))) # (Q, P)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    decay_to_end = jnp.exp(dacs[-1] - dacs)                      # (Q,)
    w = (dt * decay_to_end)[:, None] * x                         # (Q, P)
    st = jax.lax.dot_general(w, Bm, (((0,), (0,)), ((), ())))    # (P, N)
    st_ref[0, 0, 0, :, :] = st.astype(st_ref.dtype)


def ssd_chunk(xc, dtc, dA, dA_cs, Bc, Cc, *, interpret: bool = False):
    del dA  # dA_cs carries everything the kernel needs
    """Intra-chunk SSD. xc (B,NC,Q,H,P); dtc/dA/dA_cs (B,NC,Q,H);
    Bc/Cc (B,NC,Q,G,N). Returns (y_diag (B,NC,Q,H,P), states (B,NC,H,P,N))."""
    B, NC, Q, H, P = xc.shape
    G, N = Bc.shape[3], Bc.shape[4]
    rep = H // G

    y, st = pl.pallas_call(
        _kernel,
        grid=(B, NC, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, c, h: (b, c, 0, h // rep, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, c, h: (b, c, 0, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NC, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, NC, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, dA_cs, Bc, Cc)
    return y, st
