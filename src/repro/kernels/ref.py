"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, qpos, kpos, *, causal: bool = True,
                        window: int = 0):
    """q (B,H,Sq,D); k,v (B,G,Sk,D). Naive masked softmax attention."""
    B, H, Sq, D = q.shape
    G = k.shape[1]
    rep = H // G
    kr = jnp.repeat(k, rep, axis=1)
    vr = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (D ** 0.5)
    mask = kpos[None, :] >= 0
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window:
        mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, qpos, kpos, *, window: int = 0):
    """q (B,H,D); k,v (B,G,L,D)."""
    out = flash_attention_ref(q[:, :, None, :], k, v,
                              jnp.asarray([qpos], jnp.int32).reshape(1), kpos,
                              causal=True, window=window)
    return out[:, :, 0]


def paged_decode_attention_ref(q, kpool, vpool, tables, lengths, *,
                               window: int = 0):
    """q (B,H,D); kpool/vpool (N,bs,G,D); tables (B,MB); lengths (B,).
    Gathers each stream's logical view and reuses the dense decode oracle."""
    N, bs, G, D = kpool.shape
    B, MB = tables.shape
    rows = (tables[:, :, None] * bs +
            jnp.arange(bs)[None, None, :]).reshape(B, MB * bs)
    kg = kpool.reshape(N * bs, G, D)[rows]          # (B, L, G, D)
    vg = vpool.reshape(N * bs, G, D)[rows]
    outs = []
    for b in range(B):
        L = int(lengths[b])
        kpos = jnp.where(jnp.arange(MB * bs) < L, jnp.arange(MB * bs), -1)
        outs.append(decode_attention_ref(
            q[b:b + 1], kg[b:b + 1].transpose(0, 2, 1, 3),
            vg[b:b + 1].transpose(0, 2, 1, 3), L - 1,
            kpos.astype(jnp.int32), window=window)[0])
    return jnp.stack(outs)


def ragged_decode_attention_ref(q, k, v, lengths, *, window: int = 0):
    """q (B,H,D); k,v (B,G,L,D) contiguous per-lane caches; lengths (B,)
    valid rows per lane (query position = lengths-1).  Per-lane reuse of
    the dense decode oracle; an empty lane (lengths == 0) emits zeros."""
    B = q.shape[0]
    L = k.shape[2]
    outs = []
    for b in range(B):
        n = int(lengths[b])
        kpos = jnp.where(jnp.arange(L) < n, jnp.arange(L), -1).astype(jnp.int32)
        outs.append(decode_attention_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                         n - 1, kpos, window=window)[0])
    return jnp.stack(outs)


def ragged_decode_attention_quant_ref(q, k, kscale, v, vscale, lengths, *,
                                      window: int = 0):
    """Int8 ragged oracle: dequantize and reuse the float ragged oracle."""
    return ragged_decode_attention_ref(q, _dequant(k, kscale),
                                       _dequant(v, vscale), lengths,
                                       window=window)


def ragged_tree_attention_ref(q, k, v, bases, kt, vt, depths, anc, *,
                              window: int = 0):
    """Length-aware dense tree oracle: per-lane reuse of the dense tree
    oracle with base = bases[b] and contiguous stored positions."""
    B = q.shape[0]
    L = k.shape[2]
    outs = []
    for b in range(B):
        base = int(bases[b])
        kpos = jnp.where(jnp.arange(L) < base, jnp.arange(L), -1).astype(jnp.int32)
        outs.append(tree_attention_ref(
            q[b:b + 1], k[b:b + 1], v[b:b + 1], kpos, base,
            kt[b:b + 1], vt[b:b + 1],
            base + jnp.asarray(depths, jnp.int32), anc, window=window)[0])
    return jnp.stack(outs)


def _dequant(qv, scale):
    """int8 payload (..., L, D) + per-row scale (..., L) -> float32."""
    return qv.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def decode_attention_quant_ref(q, k, kscale, v, vscale, qpos, kpos, *,
                               window: int = 0):
    """q (B,H,D); k,v (B,G,L,D) int8; kscale,vscale (B,G,L).  Dequantizes
    the cache and reuses the dense decode oracle."""
    return decode_attention_ref(q, _dequant(k, kscale), _dequant(v, vscale),
                                qpos, kpos, window=window)


def paged_decode_attention_quant_ref(q, kpool, kscale, vpool, vscale, tables,
                                     lengths, *, window: int = 0):
    """Quantized paged oracle: dequantize the pools (payload (N,bs,G,D),
    scale (N,bs,G) — the scale already broadcasts over the head dim) and
    reuse the float paged oracle."""
    return paged_decode_attention_ref(q, _dequant(kpool, kscale),
                                      _dequant(vpool, vscale),
                                      tables, lengths, window=window)


def tree_attention_ref(q, k, v, kpos, base, kt, vt, qpos, anc, *,
                       window: int = 0):
    """Dense tree-verification oracle.

    q (B,H,T,D) tree-node queries; k,v (B,G,L,D) cache; kpos (L,) stored
    positions; base scalar — cache rows visible iff 0 <= kpos < base
    (committed only); kt,vt (B,G,T,D) tree-node K/V; qpos (T,) node
    positions (window only); anc (T,T) ancestor mask.  Concatenates
    cache+tree keys and runs the naive masked softmax."""
    B, H, T, D = q.shape
    G = k.shape[1]
    rep = H // G
    kk = jnp.concatenate([k, kt], axis=2)                       # (B,G,L+T,D)
    vv = jnp.concatenate([v, vt], axis=2)
    kr = jnp.repeat(kk, rep, axis=1).astype(jnp.float32)
    vr = jnp.repeat(vv, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) / (D ** 0.5)
    cmask = (kpos[None, :] >= 0) & (kpos[None, :] < base)       # (1, L)
    cmask = jnp.broadcast_to(cmask, (T, kpos.shape[0]))
    if window:
        cmask &= (qpos[:, None] - kpos[None, :]) < window
    mask = jnp.concatenate([cmask, jnp.asarray(anc, bool)], axis=1)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)


def paged_tree_attention_ref(q, kpool, vpool, tables, lengths, kt, vt,
                             depths, anc, *, window: int = 0):
    """Paged tree-verification oracle: gathers each stream's logical view
    and reuses the dense tree oracle with base = lengths[b]."""
    N, bs, G, D = kpool.shape
    B, MB = tables.shape
    rows = (tables[:, :, None] * bs +
            jnp.arange(bs)[None, None, :]).reshape(B, MB * bs)
    kg = kpool.reshape(N * bs, G, D)[rows]                      # (B, L, G, D)
    vg = vpool.reshape(N * bs, G, D)[rows]
    outs = []
    for b in range(B):
        L = int(lengths[b])
        kpos = jnp.where(jnp.arange(MB * bs) < L, jnp.arange(MB * bs), -1)
        outs.append(tree_attention_ref(
            q[b:b + 1], kg[b:b + 1].transpose(0, 2, 1, 3),
            vg[b:b + 1].transpose(0, 2, 1, 3), kpos.astype(jnp.int32), L,
            kt[b:b + 1], vt[b:b + 1], L + jnp.asarray(depths, jnp.int32),
            anc, window=window)[0])
    return jnp.stack(outs)


def _segsum(x):
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    return jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), seg, -jnp.inf)


def ssd_chunk_ref(xc, dtc, dA, dA_cs, Bc, Cc):
    """Intra-chunk SSD reference (matches repro.models.ssm math).
    xc (B,NC,Q,H,P); dtc/dA/dA_cs (B,NC,Q,H); Bc/Cc (B,NC,Q,G,N)."""
    Bsz, NC, Q, H, P = xc.shape
    G = Bc.shape[3]
    rep = H // G
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))              # (B,NC,H,Q,Q)
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)
    CB = jnp.repeat(CB, rep, axis=2)
    scores = CB * L
    y = jnp.einsum("bchqk,bckh,bckhp->bcqhp", scores, dtc, xc)
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)
    Br = jnp.repeat(Bc, rep, axis=3)                             # per-head B
    st = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Br, dtc * decay_to_end, xc)
    return y.astype(jnp.float32), st.astype(jnp.float32)
