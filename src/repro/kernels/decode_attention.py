"""Pallas TPU flash-decode kernel: single-token query vs a long KV cache.

Decode attention is memory-bound (the entire KV cache streams HBM->VMEM
once); the kernel tiles the cache length L into MXU-aligned blocks and keeps
the online-softmax stats in VMEM scratch across the L sweep.  Ring-buffer
caches are handled by the same position-validity mask used everywhere else
(slots with kpos < 0 or kpos > qpos are dead).

Layouts: q (B, H, D) one query per head; k, v (B, G, L, D); kpos (L,);
qpos scalar int32 (current absolute position). -> (B, H, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, window: int, nl: int):
    i_l = pl.program_id(2)

    @pl.when(i_l == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (1(h), D) -> (D,)? keep (1,D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bl, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bl, D)
    kp = kpos_ref[...]                                # (bl,)
    qp = qpos_ref[0]                                  # scalar

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * scale  # (bl,)
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)                            # (bl,)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + p.sum()
    acc_ref[...] = (acc_ref[...] * corr +
                    jax.lax.dot_general(p[None, :], v, (((1,), (0,)), ((), ()))))
    m_ref[0] = m_new

    @pl.when(i_l == nl - 1)
    def _finalize():
        l = l_ref[0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def decode_attention(q, k, v, qpos, kpos, *, window: int = 0,
                     block_l: int = 512, interpret: bool = False):
    """q (B,H,D); k,v (B,G,L,D); qpos () int32; kpos (L,). -> (B,H,D)."""
    B, H, D = q.shape
    G, L = k.shape[1], k.shape[2]
    assert H % G == 0
    bl = min(block_l, L)
    pL = (-L) % bl
    if pL:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pL), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pL), (0, 0)))
        kpos = jnp.pad(kpos, (0, pL), constant_values=-1)
    Lp = k.shape[2]
    nl = Lp // bl
    rep = H // G
    scale = 1.0 / (D ** 0.5)
    qpos_arr = jnp.asarray(qpos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, nl=nl),
        grid=(B, H, nl),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, il: (0,)),
            pl.BlockSpec((bl,), lambda b, h, il: (il,)),
            pl.BlockSpec((1, 1, D), lambda b, h, il: (b, h, 0)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, il: (b, h // rep, il, 0)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, il: (b, h // rep, il, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, il: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_arr, kpos, q[:, :, None, :].reshape(B, H, D), k, v)
    return out
