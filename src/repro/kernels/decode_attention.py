"""Pallas TPU flash-decode kernels: single-token query vs a long KV cache.

Decode attention is memory-bound (the entire KV cache streams HBM->VMEM
once); the kernels tile the cache length into MXU-aligned blocks and keep
the online-softmax stats in VMEM scratch across the sweep.

``decode_attention`` reads a dense per-stream cache (ring-buffer slots are
handled by the position-validity mask: kpos < 0 or kpos > qpos is dead).

``paged_decode_attention`` reads the PAGED layout: one global block pool
shared by all streams plus a per-stream block table.  The table and lengths
ride in as SCALAR-PREFETCH operands (``PrefetchScalarGridSpec``) so the
BlockSpec index map can steer each grid step's HBM->VMEM DMA straight to
``tables[b, ib]`` — the kernel never materializes a gathered per-stream
view.  Positions are contiguous per stream, so masking degenerates to
``kpos <= lengths[b] - 1`` (+ the optional sliding window).

The ``*_quant`` variants read INT8 K/V (``models/quant.py`` per-row-per-
head scales) and dequantize IN REGISTER: the per-key scale multiplies the
score after the q·k dot, the per-value scale folds into the softmax weight
before the p·v dot — the fp K/V blocks are never materialized, so the
HBM->VMEM traffic of this memory-bound kernel drops ~4x vs fp32 pools
(1 byte payload + one f32 scale per row-head vs 4 bytes per element).

RAGGED LANES.  Serving batches mix sequence lengths, so every kernel that
takes per-lane lengths early-exits per block: the whole compute body sits
under ``@pl.when(i * block < lengths[b])`` and the K/V index maps clamp to
the lane's last valid block, so a short lane neither computes nor re-DMAs
blocks past its length (consecutive identical block indices elide the
copy).  ``ragged_decode_attention`` / ``ragged_decode_attention_quant``
are the dense variants: contiguous per-lane caches (B, G, L, D) with
``lengths`` riding in as a scalar-prefetch operand, query at position
``lengths[b] - 1``, rows ``>= lengths[b]`` dead.  The paged kernels get
the same early-exit on top of their trash-block masking.

Layouts: q (B, H, D) one query per head.
  dense: k, v (B, G, L, D); kpos (L,); qpos scalar int32.
  paged: kpool, vpool (N, bs, G, D); tables (B, MB) int32; lengths (B,).
  quant: payloads int8 in the same layouts; scales (B, G, L) / (N, bs, G).
All -> (B, H, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, window: int, nl: int):
    i_l = pl.program_id(2)

    @pl.when(i_l == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (1(h), D) -> (D,)? keep (1,D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bl, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bl, D)
    kp = kpos_ref[...]                                # (bl,)
    qp = qpos_ref[0]                                  # scalar

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * scale  # (bl,)
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    # re-mask: if every slot so far is masked, m_new == NEG_INF and
    # exp(s - m_new) == 1 would poison l/acc
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)      # (bl,)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + p.sum()
    acc_ref[...] = (acc_ref[...] * corr +
                    jax.lax.dot_general(p[None, :], v, (((1,), (0,)), ((), ()))))
    m_ref[0] = m_new

    @pl.when(i_l == nl - 1)
    def _finalize():
        l = l_ref[0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def decode_attention(q, k, v, qpos, kpos, *, window: int = 0,
                     block_l: int = 512, interpret: bool = False):
    """q (B,H,D); k,v (B,G,L,D); qpos () int32; kpos (L,). -> (B,H,D)."""
    B, H, D = q.shape
    G, L = k.shape[1], k.shape[2]
    assert H % G == 0
    bl = min(block_l, L)
    pL = (-L) % bl
    if pL:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pL), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pL), (0, 0)))
        kpos = jnp.pad(kpos, (0, pL), constant_values=-1)
    Lp = k.shape[2]
    nl = Lp // bl
    rep = H // G
    scale = 1.0 / (D ** 0.5)
    qpos_arr = jnp.asarray(qpos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, nl=nl),
        grid=(B, H, nl),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, il: (0,)),
            pl.BlockSpec((bl,), lambda b, h, il: (il,)),
            pl.BlockSpec((1, 1, D), lambda b, h, il: (b, h, 0)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, il: (b, h // rep, il, 0)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, il: (b, h // rep, il, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, il: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_arr, kpos, q[:, :, None, :].reshape(B, H, D), k, v)
    return out


# ------------------------------------------------------------ dense int8

def _quant_kernel(qpos_ref, kpos_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, scale: float, window: int,
                  nl: int):
    i_l = pl.program_id(2)

    @pl.when(i_l == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bl, D) int8 payload
    v = v_ref[0, 0].astype(jnp.float32)               # (bl, D) int8 payload
    ks = ks_ref[0, 0]                                 # (bl,) f32 scales
    vs = vs_ref[0, 0]                                 # (bl,)
    kp = kpos_ref[...]
    qp = qpos_ref[0]

    # dequant-in-register: the per-key scale multiplies the SCORE (exactly
    # q . (k_int8 * ks) = (q . k_int8) * ks), never the K block itself
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * ks * scale
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + p.sum()
    # per-value scale folds into the softmax weight before the p . v dot
    acc_ref[...] = (acc_ref[...] * corr + jax.lax.dot_general(
        (p * vs)[None, :], v, (((1,), (0,)), ((), ()))))
    m_ref[0] = m_new

    @pl.when(i_l == nl - 1)
    def _finalize():
        l = l_ref[0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def decode_attention_quant(q, k, kscale, v, vscale, qpos, kpos, *,
                           window: int = 0, block_l: int = 512,
                           interpret: bool = False):
    """q (B,H,D) float; k,v (B,G,L,D) int8; kscale,vscale (B,G,L) float32
    per-row-per-head scales; qpos () int32; kpos (L,). -> (B,H,D) float."""
    B, H, D = q.shape
    G, L = k.shape[1], k.shape[2]
    assert H % G == 0 and k.dtype == jnp.int8 and v.dtype == jnp.int8
    assert kscale.shape == (B, G, L) and vscale.shape == (B, G, L)
    bl = min(block_l, L)
    pL = (-L) % bl
    if pL:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pL), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pL), (0, 0)))
        kscale = jnp.pad(kscale, ((0, 0), (0, 0), (0, pL)))
        vscale = jnp.pad(vscale, ((0, 0), (0, 0), (0, pL)))
        kpos = jnp.pad(kpos, (0, pL), constant_values=-1)
    Lp = k.shape[2]
    nl = Lp // bl
    rep = H // G
    scale = 1.0 / (D ** 0.5)
    qpos_arr = jnp.asarray(qpos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, window=window, nl=nl),
        grid=(B, H, nl),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, il: (0,)),
            pl.BlockSpec((bl,), lambda b, h, il: (il,)),
            pl.BlockSpec((1, 1, D), lambda b, h, il: (b, h, 0)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, il: (b, h // rep, il, 0)),
            pl.BlockSpec((1, 1, bl), lambda b, h, il: (b, h // rep, il)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, il: (b, h // rep, il, 0)),
            pl.BlockSpec((1, 1, bl), lambda b, h, il: (b, h // rep, il)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, il: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(qpos_arr, kpos, q.reshape(B, H, D), k, kscale, v, vscale)
    return out


# ----------------------------------------------------------- dense ragged

def _last_block(n, blk):
    """Index of the last block holding valid rows for a lane of ``n`` valid
    tokens (0 for an empty lane — its rows are masked anyway)."""
    return jnp.maximum((n + blk - 1) // blk - 1, 0)


def _ragged_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, window: int,
                   bl: int, nl: int):
    b = pl.program_id(0)
    i_l = pl.program_id(2)

    @pl.when(i_l == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = lengths_ref[b]                           # valid rows in this lane

    @pl.when(i_l * bl < n)                       # EARLY EXIT past the length
    def _compute():
        q = q_ref[0].astype(jnp.float32)         # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (bl, D)
        v = v_ref[0, 0].astype(jnp.float32)
        qp = n - 1                               # query = last stored token
        kp = i_l * bl + jax.lax.broadcasted_iota(jnp.int32, (bl, 1), 0)[:, 0]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * scale
        mask = kp <= qp                          # contiguous: validity==causal
        if window:
            mask &= (qp - kp) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + p.sum()
        acc_ref[...] = (acc_ref[...] * corr + jax.lax.dot_general(
            p[None, :], v, (((1,), (0,)), ((), ()))))
        m_ref[0] = m_new

    # finalize stays UNGUARDED: skipped blocks leave the scratch untouched,
    # and an empty lane (l == 0) falls through to the zero branch
    @pl.when(i_l == nl - 1)
    def _finalize():
        l = l_ref[0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def ragged_decode_attention(q, k, v, lengths, *, window: int = 0,
                            block_l: int = 512, interpret: bool = False):
    """Length-aware dense flash decode: q (B,H,D); k,v (B,G,L,D) contiguous
    per-lane caches; lengths (B,) int32 valid rows per lane (query position
    = lengths-1, rows >= lengths dead). -> (B,H,D).

    ``lengths`` is a SCALAR-PREFETCH operand so (a) the kernel body can
    early-exit every block past a lane's length and (b) the K/V index maps
    clamp to the lane's last valid block — consecutive identical indices
    elide the HBM->VMEM copy, so a short lane in a long batch pays for its
    own length, not the batch max."""
    B, H, D = q.shape
    G, L = k.shape[1], k.shape[2]
    assert H % G == 0 and lengths.shape == (B,)
    bl = min(block_l, L)
    pL = (-L) % bl
    if pL:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pL), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pL), (0, 0)))
    nl = k.shape[2] // bl
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    def kv_map(b, h, il, ln):
        return (b, h // rep, jnp.minimum(il, _last_block(ln[b], bl)), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nl),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, il, ln: (b, h, 0)),
            pl.BlockSpec((1, 1, bl, D), kv_map),
            pl.BlockSpec((1, 1, bl, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, il, ln: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_kernel, scale=scale, window=window,
                          bl=bl, nl=nl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32), q.reshape(B, H, D), k, v)
    return out


def _ragged_quant_kernel(lengths_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                         window: int, bl: int, nl: int):
    b = pl.program_id(0)
    i_l = pl.program_id(2)

    @pl.when(i_l == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = lengths_ref[b]

    @pl.when(i_l * bl < n)                       # EARLY EXIT past the length
    def _compute():
        q = q_ref[0].astype(jnp.float32)         # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (bl, D) int8 payload
        v = v_ref[0, 0].astype(jnp.float32)
        ks = ks_ref[0, 0]                        # (bl,) f32 scales
        vs = vs_ref[0, 0]
        qp = n - 1
        kp = i_l * bl + jax.lax.broadcasted_iota(jnp.int32, (bl, 1), 0)[:, 0]

        # dequant-in-register (see ``_quant_kernel``)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * ks * scale
        mask = kp <= qp
        if window:
            mask &= (qp - kp) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + p.sum()
        acc_ref[...] = (acc_ref[...] * corr + jax.lax.dot_general(
            (p * vs)[None, :], v, (((1,), (0,)), ((), ()))))
        m_ref[0] = m_new

    @pl.when(i_l == nl - 1)
    def _finalize():
        l = l_ref[0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def ragged_decode_attention_quant(q, k, kscale, v, vscale, lengths, *,
                                  window: int = 0, block_l: int = 512,
                                  interpret: bool = False):
    """Int8 variant of ``ragged_decode_attention``: k,v (B,G,L,D) int8 with
    kscale,vscale (B,G,L) per-row-per-head scales; same early-exit and
    clamped-DMA ragged semantics. -> (B,H,D) float."""
    B, H, D = q.shape
    G, L = k.shape[1], k.shape[2]
    assert H % G == 0 and k.dtype == jnp.int8 and v.dtype == jnp.int8
    assert kscale.shape == (B, G, L) and vscale.shape == (B, G, L)
    assert lengths.shape == (B,)
    bl = min(block_l, L)
    pL = (-L) % bl
    if pL:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pL), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pL), (0, 0)))
        kscale = jnp.pad(kscale, ((0, 0), (0, 0), (0, pL)))
        vscale = jnp.pad(vscale, ((0, 0), (0, 0), (0, pL)))
    nl = k.shape[2] // bl
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    def kv_map(b, h, il, ln):
        return (b, h // rep, jnp.minimum(il, _last_block(ln[b], bl)), 0)

    def sc_map(b, h, il, ln):
        return (b, h // rep, jnp.minimum(il, _last_block(ln[b], bl)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nl),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, il, ln: (b, h, 0)),
            pl.BlockSpec((1, 1, bl, D), kv_map),
            pl.BlockSpec((1, 1, bl), sc_map),
            pl.BlockSpec((1, 1, bl, D), kv_map),
            pl.BlockSpec((1, 1, bl), sc_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, il, ln: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_quant_kernel, scale=scale, window=window,
                          bl=bl, nl=nl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32), q.reshape(B, H, D),
      k, kscale, v, vscale)
    return out


# ------------------------------------------------------------------ paged

def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, window: int,
                  bs: int, nmb: int):
    b = pl.program_id(0)
    i_b = pl.program_id(2)                       # logical block index

    @pl.when(i_b == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = lengths_ref[b]

    @pl.when(i_b * bs < n)                       # EARLY EXIT past the length
    def _compute():
        q = q_ref[0].astype(jnp.float32)         # (1, D)
        k = k_ref[0, :, 0].astype(jnp.float32)   # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)   # (bs, D)
        qp = n - 1                               # query = last stored token
        kp = i_b * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)[:, 0]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * scale
        mask = kp <= qp                          # contiguous: validity==causal
        if window:
            mask &= (qp - kp) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        # explicit re-mask: a partially valid block has masked rows whose
        # exp(s - m_new) == 1 would poison l/acc
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + p.sum()
        acc_ref[...] = (acc_ref[...] * corr + jax.lax.dot_general(
            p[None, :], v, (((1,), (0,)), ((), ()))))
        m_ref[0] = m_new

    # finalize stays UNGUARDED: an empty lane (lengths == 0) skips every
    # compute block and falls through to the l == 0 zero branch
    @pl.when(i_b == nmb - 1)
    def _finalize():
        l = l_ref[0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def paged_decode_attention(q, kpool, vpool, tables, lengths, *,
                           window: int = 0, interpret: bool = False):
    """q (B,H,D); kpool/vpool (N,bs,G,D); tables (B,MB) int32 physical block
    ids (0 = the reserved trash block for unallocated entries); lengths (B,)
    valid tokens per stream (query position = lengths-1). -> (B,H,D).

    The grid sweeps every table slot, but a lane stops paying past its own
    length: blocks ``>= ceil(lengths[b]/bs)`` skip compute via ``pl.when``
    early-exit and their DMA index clamps to the lane's last valid block
    (consecutive identical indices elide the copy), so ragged lanes and
    post-rollback states (rows past the truncated length live in HBM but
    dead under the mask) cost what they store, not what the table spans.

    Prefix sharing (docs/prefix_sharing.md) is invisible here: the kernel
    only READS through the table, so two lanes whose tables alias the same
    physical prefix blocks simply DMA the same pool rows — no refcount
    plumbing reaches the device.
    """
    B, H, D = q.shape
    N, bs, G, _ = kpool.shape
    MB = tables.shape[1]
    assert H % G == 0 and vpool.shape == kpool.shape
    assert lengths.shape == (B,) and tables.shape == (B, MB)
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    def kv_map(b, h, ib, tbl, ln):
        return (tbl[b, jnp.minimum(ib, _last_block(ln[b], bs))],
                0, h // rep, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, MB),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, ib, tbl, ln: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, ib, tbl, ln: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, window=window,
                          bs=bs, nmb=MB),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q.reshape(B, H, D), kpool, vpool)
    return out


# ------------------------------------------------------------ paged int8

def _paged_quant_kernel(tables_ref, lengths_ref, q_ref, k_ref, ks_ref,
                        v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        scale: float, window: int, bs: int, nmb: int):
    b = pl.program_id(0)
    i_b = pl.program_id(2)

    @pl.when(i_b == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = lengths_ref[b]

    @pl.when(i_b * bs < n)                       # EARLY EXIT past the length
    def _compute():
        q = q_ref[0].astype(jnp.float32)         # (1, D)
        k = k_ref[0, :, 0].astype(jnp.float32)   # (bs, D) int8 payload
        v = v_ref[0, :, 0].astype(jnp.float32)   # (bs, D) int8 payload
        ks = ks_ref[0, :, 0]                     # (bs,) f32 scales
        vs = vs_ref[0, :, 0]
        qp = n - 1
        kp = i_b * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)[:, 0]

        # dequant-in-register (see ``_quant_kernel``): scales hit the score
        # and the softmax weight, the int8 blocks go straight into the dots
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))[0] * ks * scale
        mask = kp <= qp
        if window:
            mask &= (qp - kp) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + p.sum()
        acc_ref[...] = (acc_ref[...] * corr + jax.lax.dot_general(
            (p * vs)[None, :], v, (((1,), (0,)), ((), ()))))
        m_ref[0] = m_new

    @pl.when(i_b == nmb - 1)
    def _finalize():
        l = l_ref[0]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out[0].astype(o_ref.dtype)


def paged_decode_attention_quant(q, kpool, kscale, vpool, vscale, tables,
                                 lengths, *, window: int = 0,
                                 interpret: bool = False):
    """q (B,H,D) float; kpool/vpool (N,bs,G,D) int8; kscale/vscale
    (N,bs,G) float32 per-row-per-head scale pools (written through the
    same block tables as the payloads, ``models/cache.py``); tables
    (B,MB); lengths (B,). -> (B,H,D) float.

    Same scalar-prefetch DMA steering and ragged early-exit semantics as
    ``paged_decode_attention``; each grid step additionally streams the
    block's scale rows (bs * 4 bytes vs bs * D payload bytes — noise).
    """
    B, H, D = q.shape
    N, bs, G, _ = kpool.shape
    MB = tables.shape[1]
    assert H % G == 0 and vpool.shape == kpool.shape
    assert kpool.dtype == jnp.int8 and vpool.dtype == jnp.int8
    assert kscale.shape == (N, bs, G) and vscale.shape == (N, bs, G)
    assert lengths.shape == (B,) and tables.shape == (B, MB)
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    def kv_map(b, h, ib, tbl, ln):
        return (tbl[b, jnp.minimum(ib, _last_block(ln[b], bs))],
                0, h // rep, 0)

    def sc_map(b, h, ib, tbl, ln):
        return (tbl[b, jnp.minimum(ib, _last_block(ln[b], bs))],
                0, h // rep)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, MB),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, ib, tbl, ln: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, bs, 1), sc_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, bs, 1), sc_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, ib, tbl, ln: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_quant_kernel, scale=scale, window=window,
                          bs=bs, nmb=MB),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q.reshape(B, H, D), kpool, kscale, vpool, vscale)
    return out
