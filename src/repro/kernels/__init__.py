"""Pallas TPU kernels for the decode hot paths, with pure-jnp oracles.

One module per kernel family, each with a compiled TPU target validated
against ``ref.py`` in interpret mode on CPU (``tests/test_kernels.py``):

  * ``flash_attention``    — causal/windowed training & prefill attention
  * ``decode_attention``   — flash-decode vs dense and PAGED caches, in
                             float and INT8 (dequant-in-register) variants
  * ``tree_attention``     — tree-verification attention (dense + paged)
  * ``ssd``                — Mamba-2 SSD intra-chunk scan

``ops.py`` holds the jitted public wrappers and the CPU-interpret
dispatch; model code defaults to the XLA paths and reserves these for the
hardware target.
"""
