"""Pallas TPU tree-verification kernels: T tree-node queries vs cache+tree.

Tree speculation verifies a whole candidate tree in ONE target pass: every
node queries (a) the committed KV cache and (b) the other tree nodes'
fresh K/V under an ANCESTOR mask (siblings share a RoPE position, so the
position rule that masks the chain kernels cannot separate them — the
explicit (T, T) mask can).  Like flash decode, the pass is memory-bound in
the cache sweep: the kernels tile the cache length into MXU-aligned blocks
streamed HBM->VMEM with per-query-row online-softmax stats held in VMEM
scratch, and attend the (tiny) tree block as the final grid step.

``tree_attention`` reads a DENSE cache.  Cache-row visibility is
``0 <= kpos[s] < base`` where ``base`` is the cache pointer: tree passes
never overwrite stale rows before attending (they write nothing), so rows
carrying rolled-back future positions must be masked by the pointer — a
STRICTER rule than the chain kernels' ``kpos <= qpos``.

``paged_tree_attention`` reads the PAGED layout: block tables and lengths
ride in as scalar-prefetch operands (``PrefetchScalarGridSpec``) steering
each grid step's DMA to ``tables[b, ib]`` — the same structure as
``decode_attention.paged_decode_attention``.  Validity degenerates to
``kp < lengths[b]`` (committed rows only, by construction).

``ragged_tree_attention`` is the length-aware dense variant for mixed-
length serving lanes: the per-lane cache pointer rides in as a (B,)
scalar-prefetch operand ``bases``, cache blocks past ``bases[b]`` skip
compute via ``pl.when`` early-exit with their DMA index clamped to the
lane's last valid block, and the tree block always runs (nodes attend
their ancestors even on an empty cache).  The paged kernel applies the
same early-exit on top of its trash-block masking.

Layouts (one query per tree node per head):
  dense: q (B, H, T, D); k, v (B, G, L, D); kpos (L,); base () int32;
         kt, vt (B, G, T, D); qpos (T,) node positions; anc (T, T) int32.
  paged: q (B, H, T, D); kpool, vpool (N, bs, G, D); tables (B, MB);
         lengths (B,); kt, vt (B, G, T, D); depths (T,); anc (T, T).
Both -> (B, H, T, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _online_update(s, mask, v, m_ref, l_ref, acc_ref):
    """One online-softmax accumulation step: s (T, bl) scores, mask (T, bl),
    v (bl, D).  Scratch: m/l (T,), acc (T, D)."""
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # re-mask: rows with every slot masked so far have m_new == NEG_INF and
    # exp(s - m_new) == 1 would poison l/acc
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new


def _finalize(o_ref, m_ref, l_ref, acc_ref):
    l = l_ref[...]
    out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
    out = jnp.where((l > 0)[:, None], out, 0.0)
    o_ref[0, 0] = out.astype(o_ref.dtype)


def _tree_kernel(base_ref, qpos_ref, kpos_ref, anc_ref, q_ref, k_ref, v_ref,
                 kt_ref, vt_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, window: int, bl: int, nl: int):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (T, D)
    qp = qpos_ref[...]                                # (T,)
    base = base_ref[0]

    @pl.when(il < nl)
    def _cache_block():
        k = k_ref[0, 0].astype(jnp.float32)           # (bl, D)
        v = v_ref[0, 0].astype(jnp.float32)
        kp = kpos_ref[...]                            # (bl,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = (kp[None, :] >= 0) & (kp[None, :] < base)
        if window:
            mask &= (qp[:, None] - kp[None, :]) < window
        _online_update(s, mask, v, m_ref, l_ref, acc_ref)

    @pl.when(il == nl)
    def _tree_block():
        kt = kt_ref[0, 0].astype(jnp.float32)         # (T, D)
        vt = vt_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ()))) * scale
        _online_update(s, anc_ref[...] != 0, vt, m_ref, l_ref, acc_ref)
        _finalize(o_ref, m_ref, l_ref, acc_ref)


def tree_attention(q, k, v, kpos, base, kt, vt, qpos, anc, *,
                   window: int = 0, block_l: int = 512,
                   interpret: bool = False):
    """Dense tree verification (see module docstring). -> (B, H, T, D)."""
    B, H, T, D = q.shape
    G, L = k.shape[1], k.shape[2]
    assert H % G == 0
    assert kt.shape == (B, G, T, D) and vt.shape == (B, G, T, D)
    assert anc.shape == (T, T) and qpos.shape == (T,)
    bl = min(block_l, L)
    pL = (-L) % bl
    if pL:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pL), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pL), (0, 0)))
        kpos = jnp.pad(kpos, (0, pL), constant_values=-1)
    nl = k.shape[2] // bl
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    out = pl.pallas_call(
        functools.partial(_tree_kernel, scale=scale, window=window, bl=bl,
                          nl=nl),
        grid=(B, H, nl + 1),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, il: (0,)),
            pl.BlockSpec((T,), lambda b, h, il: (0,)),
            pl.BlockSpec((bl,), lambda b, h, il: (jnp.minimum(il, nl - 1),)),
            pl.BlockSpec((T, T), lambda b, h, il: (0, 0)),
            pl.BlockSpec((1, 1, T, D), lambda b, h, il: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bl, D),
                         lambda b, h, il: (b, h // rep,
                                           jnp.minimum(il, nl - 1), 0)),
            pl.BlockSpec((1, 1, bl, D),
                         lambda b, h, il: (b, h // rep,
                                           jnp.minimum(il, nl - 1), 0)),
            pl.BlockSpec((1, 1, T, D), lambda b, h, il: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, T, D), lambda b, h, il: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, D), lambda b, h, il: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T, D), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(base, jnp.int32).reshape(1), jnp.asarray(qpos, jnp.int32),
      jnp.asarray(kpos, jnp.int32), jnp.asarray(anc, jnp.int32),
      q, k, v, kt, vt)
    return out


# ----------------------------------------------------------- dense ragged

def _last_block(n, blk):
    """Index of the last block holding valid rows for a lane of ``n`` valid
    tokens (0 for an empty lane — its rows are masked anyway)."""
    return jnp.maximum((n + blk - 1) // blk - 1, 0)


def _ragged_tree_kernel(bases_ref, depths_ref, anc_ref, q_ref, k_ref, v_ref,
                        kt_ref, vt_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        scale: float, window: int, bl: int, nl: int):
    b = pl.program_id(0)
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (T, D)
    base = bases_ref[b]                               # per-lane cache pointer

    @pl.when((il < nl) & (il * bl < base))            # EARLY EXIT past base
    def _cache_block():
        k = k_ref[0, 0].astype(jnp.float32)           # (bl, D)
        v = v_ref[0, 0].astype(jnp.float32)
        kp = il * bl + jax.lax.broadcasted_iota(jnp.int32, (bl, 1), 0)[:, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = jnp.broadcast_to(kp[None, :] < base, s.shape)
        if window:
            qp = base + depths_ref[...]               # (T,) node positions
            mask &= (qp[:, None] - kp[None, :]) < window
        _online_update(s, mask, v, m_ref, l_ref, acc_ref)

    # the tree block always runs: nodes attend their ancestors even when
    # the lane's cache is empty, and it carries the finalize
    @pl.when(il == nl)
    def _tree_block():
        kt = kt_ref[0, 0].astype(jnp.float32)
        vt = vt_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ()))) * scale
        _online_update(s, anc_ref[...] != 0, vt, m_ref, l_ref, acc_ref)
        _finalize(o_ref, m_ref, l_ref, acc_ref)


def ragged_tree_attention(q, k, v, bases, kt, vt, depths, anc, *,
                          window: int = 0, block_l: int = 512,
                          interpret: bool = False):
    """Length-aware dense tree verification: q (B,H,T,D); k,v (B,G,L,D)
    contiguous per-lane caches; bases (B,) int32 per-lane cache pointers
    (rows >= bases[b] dead); kt,vt (B,G,T,D) tree-node K/V; depths (T,)
    node depths (window masking only — node position = bases[b] + depth);
    anc (T,T) ancestor mask. -> (B,H,T,D).

    ``bases`` is a SCALAR-PREFETCH operand: cache blocks past a lane's
    pointer early-exit and clamp their DMA to the last valid block, so a
    short lane pays its own cache sweep, not the batch max."""
    B, H, T, D = q.shape
    G, L = k.shape[1], k.shape[2]
    assert H % G == 0 and bases.shape == (B,)
    assert kt.shape == (B, G, T, D) and vt.shape == (B, G, T, D)
    assert anc.shape == (T, T) and depths.shape == (T,)
    bl = min(block_l, L)
    pL = (-L) % bl
    if pL:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pL), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pL), (0, 0)))
    nl = k.shape[2] // bl
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    def kv_map(b, h, il, bs_):
        il_eff = jnp.minimum(jnp.minimum(il, nl - 1),
                             _last_block(bs_[b], bl))
        return (b, h // rep, il_eff, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nl + 1),
        in_specs=[
            pl.BlockSpec((T,), lambda b, h, il, bs_: (0,)),
            pl.BlockSpec((T, T), lambda b, h, il, bs_: (0, 0)),
            pl.BlockSpec((1, 1, T, D), lambda b, h, il, bs_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bl, D), kv_map),
            pl.BlockSpec((1, 1, bl, D), kv_map),
            pl.BlockSpec((1, 1, T, D),
                         lambda b, h, il, bs_: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, T, D),
                         lambda b, h, il, bs_: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, D),
                               lambda b, h, il, bs_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_tree_kernel, scale=scale, window=window,
                          bl=bl, nl=nl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(bases, jnp.int32), jnp.asarray(depths, jnp.int32),
      jnp.asarray(anc, jnp.int32), q, k, v, kt, vt)
    return out


# ------------------------------------------------------------------ paged

def _paged_tree_kernel(tables_ref, lengths_ref, depths_ref, anc_ref, q_ref,
                       k_ref, v_ref, kt_ref, vt_ref, o_ref, m_ref, l_ref,
                       acc_ref, *, scale: float, window: int, bs: int,
                       nmb: int):
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (T, D)
    ln = lengths_ref[b]

    @pl.when((ib < nmb) & (ib * bs < ln))             # EARLY EXIT past length
    def _cache_block():
        k = k_ref[0, :, 0].astype(jnp.float32)        # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        kp = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)[:, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = jnp.broadcast_to(kp[None, :] < ln, s.shape)
        if window:
            qp = ln + depths_ref[...]                 # (T,)
            mask &= (qp[:, None] - kp[None, :]) < window
        _online_update(s, mask, v, m_ref, l_ref, acc_ref)

    @pl.when(ib == nmb)
    def _tree_block():
        kt = kt_ref[0, 0].astype(jnp.float32)
        vt = vt_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ()))) * scale
        _online_update(s, anc_ref[...] != 0, vt, m_ref, l_ref, acc_ref)
        _finalize(o_ref, m_ref, l_ref, acc_ref)


def paged_tree_attention(q, kpool, vpool, tables, lengths, kt, vt, depths,
                         anc, *, window: int = 0, interpret: bool = False):
    """Paged tree verification: the grid sweeps every table slot (scalar-
    prefetch DMA steering) but early-exits blocks past ``lengths[b]`` with
    their DMA clamped to the lane's last valid block, so ragged lengths and
    post-rollback states cost what they store, not what the table spans.
    -> (B, H, T, D)."""
    B, H, T, D = q.shape
    N, bs, G, _ = kpool.shape
    MB = tables.shape[1]
    assert H % G == 0 and vpool.shape == kpool.shape
    assert lengths.shape == (B,) and tables.shape == (B, MB)
    assert kt.shape == (B, G, T, D) and anc.shape == (T, T)
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    def kv_map(b, h, ib, tbl, ln):
        ib_eff = jnp.minimum(jnp.minimum(ib, MB - 1),
                             _last_block(ln[b], bs))
        return (tbl[b, ib_eff], 0, h // rep, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, MB + 1),
        in_specs=[
            pl.BlockSpec((T,), lambda b, h, ib, tbl, ln: (0,)),
            pl.BlockSpec((T, T), lambda b, h, ib, tbl, ln: (0, 0)),
            pl.BlockSpec((1, 1, T, D), lambda b, h, ib, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, bs, 1, D), kv_map),
            pl.BlockSpec((1, 1, T, D),
                         lambda b, h, ib, tbl, ln: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, T, D),
                         lambda b, h, ib, tbl, ln: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T, D),
                               lambda b, h, ib, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T,), jnp.float32),
            pltpu.VMEM((T, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_tree_kernel, scale=scale, window=window,
                          bs=bs, nmb=MB),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      jnp.asarray(depths, jnp.int32), jnp.asarray(anc, jnp.int32),
      q, kpool, vpool, kt, vt)
    return out
