"""Jitted public wrappers around the Pallas kernels.

Dispatch policy:
  * on TPU: compiled Pallas kernels (the hardware target);
  * on CPU: ``interpret=True`` executes the kernel body in Python — used by
    the correctness tests; model code defaults to the XLA paths instead
    (``repro.models.attention.sdpa`` / ``ssm.ssd_chunked``) because
    interpret mode is orders of magnitude slower.

Set ``repro.kernels.ops.FORCE_INTERPRET = True`` (tests do) to exercise the
kernels on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _da
from . import flash_attention as _fa
from . import ssd as _ssd
from . import tree_attention as _ta

FORCE_INTERPRET = False


def _interpret() -> bool:
    return FORCE_INTERPRET or jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, qpos, kpos, *, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, qpos, kpos, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_l"))
def decode_attention(q, k, v, qpos, kpos, *, window: int = 0,
                     block_l: int = 512):
    return _da.decode_attention(q, k, v, qpos, kpos, window=window,
                                block_l=block_l, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window",))
def paged_decode_attention(q, kpool, vpool, tables, lengths, *,
                           window: int = 0):
    return _da.paged_decode_attention(q, kpool, vpool, tables, lengths,
                                      window=window, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_l"))
def decode_attention_quant(q, k, kscale, v, vscale, qpos, kpos, *,
                           window: int = 0, block_l: int = 512):
    return _da.decode_attention_quant(q, k, kscale, v, vscale, qpos, kpos,
                                      window=window, block_l=block_l,
                                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window",))
def paged_decode_attention_quant(q, kpool, kscale, vpool, vscale, tables,
                                 lengths, *, window: int = 0):
    return _da.paged_decode_attention_quant(q, kpool, kscale, vpool, vscale,
                                            tables, lengths, window=window,
                                            interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_l"))
def ragged_decode_attention(q, k, v, lengths, *, window: int = 0,
                            block_l: int = 512):
    return _da.ragged_decode_attention(q, k, v, lengths, window=window,
                                       block_l=block_l,
                                       interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_l"))
def ragged_decode_attention_quant(q, k, kscale, v, vscale, lengths, *,
                                  window: int = 0, block_l: int = 512):
    return _da.ragged_decode_attention_quant(q, k, kscale, v, vscale,
                                             lengths, window=window,
                                             block_l=block_l,
                                             interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_l"))
def ragged_tree_attention(q, k, v, bases, kt, vt, depths, anc, *,
                          window: int = 0, block_l: int = 512):
    return _ta.ragged_tree_attention(q, k, v, bases, kt, vt, depths, anc,
                                     window=window, block_l=block_l,
                                     interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_l"))
def tree_attention(q, k, v, kpos, base, kt, vt, qpos, anc, *,
                   window: int = 0, block_l: int = 512):
    return _ta.tree_attention(q, k, v, kpos, base, kt, vt, qpos, anc,
                              window=window, block_l=block_l,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window",))
def paged_tree_attention(q, kpool, vpool, tables, lengths, kt, vt, depths,
                         anc, *, window: int = 0):
    return _ta.paged_tree_attention(q, kpool, vpool, tables, lengths, kt, vt,
                                    depths, anc, window=window,
                                    interpret=_interpret())


@jax.jit
def ssd_chunk(xc, dtc, dA, dA_cs, Bc, Cc):
    # the cumulative form dA_cs carries everything the kernel needs
    return _ssd.ssd_chunk(xc, dtc, dA, dA_cs, Bc, Cc, interpret=_interpret())
