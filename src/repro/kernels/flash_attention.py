"""Pallas TPU flash-attention (prefill/training) kernel.

Tiling: grid (B, H, nq, nk) with the KV index innermost; online-softmax
running stats (m, l, acc) live in VMEM scratch and persist across the nk
sweep; the output block is written on the last KV step.  Block shapes are
MXU-aligned (q/kv block 128, head-dim lanes 128).  GQA folds q-heads onto
their KV group via the index map (no KV replication in HBM).

Layouts: q (B, H, Sq, D); k, v (B, G, Sk, D); qpos (Sq,), kpos (Sk,) int32
position vectors driving the causal/window/validity mask (same rule as
``repro.models.attention.sdpa``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, window: int,
            causal: bool, nk: int):
    i_k = pl.program_id(3)

    @pl.when(i_k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, D)
    qp = qpos_ref[...]                                  # (bq,)
    kp = kpos_ref[...]                                  # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
    mask = kp[None, :] >= 0
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None] +
                    jax.lax.dot_general(p.astype(v.dtype), v,
                                        (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(i_k == nk - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, qpos, kpos, *, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,H,Sq,D); k,v (B,G,Sk,D); qpos (Sq,); kpos (Sk,). -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    G, Sk = k.shape[1], k.shape[2]
    assert H % G == 0
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        qpos = jnp.pad(qpos, (0, pq), constant_values=-(10 ** 9))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        kpos = jnp.pad(kpos, (0, pk), constant_values=-1)
    Sqp, Skp = q.shape[2], k.shape[2]
    nq, nk = Sqp // bq, Skp // bk
    rep = H // G
    scale = 1.0 / (D ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window,
                          causal=causal, nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((bq,), lambda b, h, iq, ik: (iq,)),
            pl.BlockSpec((bk,), lambda b, h, iq, ik: (ik,)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, q, k, v)
    return out[:, :, :Sq]
