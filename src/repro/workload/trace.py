"""Workload traces: class mixes -> replayable request lists.

A ``WorkloadClass`` bundles what a traffic class looks like (prompt and
output length distributions) with how the scheduler should treat it
(priority, SLO).  ``synthesize`` draws an open-loop trace from a weighted
mix of classes over a Poisson or bursty arrival process; traces are plain
data (JSON round-trip via ``save_trace``/``load_trace``) so a bench row
can name the exact traffic it measured and anyone can replay it.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .arrivals import bursty_arrivals, poisson_arrivals
from .lengths import LengthDist


@dataclass(frozen=True)
class WorkloadClass:
    """One traffic class: length mix + scheduling treatment."""
    name: str
    prompt_len: LengthDist
    output_len: LengthDist
    priority: int = 0              # higher = more urgent
    slo_ticks: Optional[int] = None  # deadline: submit + slo_ticks
    weight: float = 1.0            # sampling weight within the mix


@dataclass
class TraceRequest:
    """One open-loop request, fully materialized (tokens included)."""
    arrival_s: float
    prompt: List[int]
    max_new_tokens: int
    priority: int = 0
    slo_ticks: Optional[int] = None
    cls: str = ""
    request_id: Optional[int] = field(default=None, compare=False)


def synthesize(classes: Sequence[WorkloadClass], *, rate: float, n: int,
               seed: int = 0, vocab: int = 64,
               bursty: bool = False, burst_factor: float = 8.0
               ) -> List[TraceRequest]:
    """Draw ``n`` requests from the weighted class mix over a Poisson
    (or bursty) arrival process at ``rate`` requests per unit time.
    Prompt token ids are uniform over ``[1, vocab)`` (0 is reserved as a
    conventional pad/eos in the toy vocabularies)."""
    assert classes and n >= 0
    rng = np.random.default_rng(seed)
    if bursty:
        times = bursty_arrivals(rate, n, seed=seed + 1,
                                burst_factor=burst_factor)
    else:
        times = poisson_arrivals(rate, n, seed=seed + 1)
    w = np.array([c.weight for c in classes], float)
    picks = rng.choice(len(classes), size=n, p=w / w.sum())
    reqs: List[TraceRequest] = []
    for i in range(n):
        c = classes[picks[i]]
        plen = int(c.prompt_len.sample(1, rng)[0])
        olen = int(c.output_len.sample(1, rng)[0])
        prompt = rng.integers(1, vocab, size=plen).tolist()
        reqs.append(TraceRequest(
            arrival_s=float(times[i]), prompt=[int(t) for t in prompt],
            max_new_tokens=olen, priority=c.priority,
            slo_ticks=c.slo_ticks, cls=c.name))
    return reqs


def save_trace(path: str, reqs: Sequence[TraceRequest]) -> None:
    rows = [{"arrival_s": r.arrival_s, "prompt": r.prompt,
             "max_new_tokens": r.max_new_tokens, "priority": r.priority,
             "slo_ticks": r.slo_ticks, "cls": r.cls} for r in reqs]
    with open(path, "w") as f:
        json.dump({"version": 1, "requests": rows}, f)


def load_trace(path: str) -> List[TraceRequest]:
    with open(path) as f:
        data = json.load(f)
    assert data.get("version") == 1, "unknown trace version"
    return [TraceRequest(
        arrival_s=float(r["arrival_s"]), prompt=list(r["prompt"]),
        max_new_tokens=int(r["max_new_tokens"]),
        priority=int(r.get("priority", 0)),
        slo_ticks=(None if r.get("slo_ticks") is None
                   else int(r["slo_ticks"])),
        cls=r.get("cls", "")) for r in data["requests"]]
