"""Prompt/output length distributions for heterogeneous request mixes.

Real serving traffic mixes short interactive prompts with long document
dumps; a fixed-length workload hides exactly the head-of-line blocking
the SLO scheduler exists to fix.  ``LengthDist`` is a small declarative
sampler — ``("fixed", n)``, ``("uniform", lo, hi)`` or ``("lognormal",
mean, sigma)`` — always clamped to ``[lo_clip, hi_clip]`` and integer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class LengthDist:
    kind: str                      # "fixed" | "uniform" | "lognormal"
    params: Tuple[float, ...]      # fixed: (n,); uniform: (lo, hi);
    #                                lognormal: (mean, sigma) of the value
    lo_clip: int = 2
    hi_clip: int = 1 << 30

    def __post_init__(self):
        kinds = ("fixed", "uniform", "lognormal")
        if self.kind not in kinds:
            raise ValueError(f"kind {self.kind!r} not in {kinds}")

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        if self.kind == "fixed":
            out = np.full(n, self.params[0])
        elif self.kind == "uniform":
            lo, hi = self.params
            out = rng.integers(int(lo), int(hi) + 1, size=n).astype(float)
        else:
            mean, sigma = self.params
            # parametrize by the VALUE's mean, not the underlying normal's
            mu = np.log(max(mean, 1e-9)) - 0.5 * sigma * sigma
            out = rng.lognormal(mu, sigma, size=n)
        out = np.clip(np.rint(out), self.lo_clip, self.hi_clip)
        return out.astype(np.int64)

    def to_json(self) -> dict:
        return {"kind": self.kind, "params": list(self.params),
                "lo_clip": self.lo_clip, "hi_clip": self.hi_clip}

    @classmethod
    def from_json(cls, d: dict) -> "LengthDist":
        return cls(d["kind"], tuple(d["params"]),
                   d.get("lo_clip", 2), d.get("hi_clip", 1 << 30))
