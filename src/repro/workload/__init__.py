"""Open-loop workload harness for serving benchmarks (docs/slo_scheduling.md).

Closed-loop benches (submit-everything-then-drain) measure throughput but
say nothing about tail behavior under load: arrivals never queue behind a
busy server, so queue delay and SLO misses are structurally zero.  This
package generates OPEN-LOOP traffic — requests arrive on their own clock
whether or not the server keeps up — as deterministic, seeded traces:

* ``arrivals`` — Poisson and bursty (two-state modulated Poisson)
  arrival-time processes, plus the map onto discrete scheduler ticks;
* ``lengths`` — prompt/output length distributions (fixed, uniform,
  lognormal) for heterogeneous request mixes;
* ``trace`` — ``WorkloadClass`` mixes (priority + SLO per class) composed
  into replayable ``TraceRequest`` lists, with JSON save/load.

Everything is driven by explicit seeds and returns plain data, so a bench
row's workload is reproducible from its recorded parameters.
"""
from .arrivals import arrival_ticks, bursty_arrivals, poisson_arrivals
from .lengths import LengthDist
from .trace import (TraceRequest, WorkloadClass, load_trace, save_trace,
                    synthesize)

__all__ = [
    "arrival_ticks", "bursty_arrivals", "poisson_arrivals",
    "LengthDist", "TraceRequest", "WorkloadClass",
    "load_trace", "save_trace", "synthesize",
]
