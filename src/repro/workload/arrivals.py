"""Open-loop arrival processes (deterministic, seeded).

Arrival TIMES are continuous; the serving loop is discrete (one batched
tick at a time), so ``arrival_ticks`` quantizes a time series onto the
tick grid — a request whose arrival falls inside tick ``t`` becomes
visible to the scheduler at the START of tick ``t``.
"""
from __future__ import annotations

import numpy as np


def poisson_arrivals(rate: float, n: int, seed: int = 0) -> np.ndarray:
    """``n`` arrival times of a homogeneous Poisson process with ``rate``
    arrivals per unit time (i.i.d. exponential inter-arrival gaps)."""
    assert rate > 0 and n >= 0
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def bursty_arrivals(rate: float, n: int, seed: int = 0, *,
                    burst_factor: float = 8.0,
                    mean_burst: int = 8,
                    mean_calm: int = 24) -> np.ndarray:
    """Two-state Markov-modulated Poisson arrivals with overall mean
    ``rate``: the process alternates between a CALM state and a BURST
    state whose instantaneous rate is ``burst_factor`` times calm's.
    State dwell lengths (in arrivals) are geometric with means
    ``mean_burst`` / ``mean_calm``.  The calm/burst rates are solved so
    the long-run average stays ``rate`` — same offered load as
    ``poisson_arrivals``, much heavier queueing tail."""
    assert rate > 0 and n >= 0 and burst_factor > 1.0
    rng = np.random.default_rng(seed)
    # time fraction in burst = dwell_burst/rate_burst over total;
    # arrival fractions are dwell-proportional by construction
    f_burst = mean_burst / (mean_burst + mean_calm)
    # rate = time-weighted harmonic mix; solve calm rate r_c with
    # r_b = burst_factor * r_c:  E[gap] = f_burst/r_b + (1-f_burst)/r_c
    r_calm = rate * (f_burst / burst_factor + (1.0 - f_burst))
    r_burst = burst_factor * r_calm
    gaps = np.empty(n)
    i = 0
    in_burst = False
    while i < n:
        dwell = 1 + rng.geometric(1.0 / (mean_burst if in_burst
                                         else mean_calm))
        k = min(dwell, n - i)
        r = r_burst if in_burst else r_calm
        gaps[i:i + k] = rng.exponential(1.0 / r, size=k)
        i += k
        in_burst = not in_burst
    return np.cumsum(gaps)


def arrival_ticks(times: np.ndarray, tick_s: float = 1.0) -> np.ndarray:
    """Map arrival times onto discrete scheduler tick indices: a request
    arriving during tick ``t`` is submittable at the start of tick ``t``."""
    assert tick_s > 0
    return np.floor(np.asarray(times) / tick_s).astype(np.int64)
