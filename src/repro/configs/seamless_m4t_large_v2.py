"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

[arXiv:2308.11596]  24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
The mel-spectrogram + conformer feature frontend is a STUB: input_specs()
provides precomputed frame embeddings (frontend_dim=1024); the transformer
backbone here is the text decoder (24L) + speech encoder (24L) with
cross-attention.
"""
from repro.models import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    rope_theta=10000.0,
    block_pattern=("attn",),
    encdec=EncDecConfig(num_encoder_layers=24, encoder_is_causal=False,
                        frontend_dim=1024, frontend_len=1024),
    source="arXiv:2308.11596 (SeamlessM4T v2 large)",
)
