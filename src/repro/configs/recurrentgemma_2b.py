"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427 (Griffin) / RecurrentGemma]  26L d_model=2560 10H (kv=1)
d_ff=7680 vocab=256000, window 2048.  Pattern cycle (R, R, A); 26 = 8*(3) + 2,
the 2-layer tail stays recurrent.
"""
from repro.models import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="geglu",
    rope_theta=10000.0,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4),
    source="arXiv:2402.19427 (Griffin / RecurrentGemma-2B)",
)
