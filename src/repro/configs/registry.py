"""Architecture registry: ``--arch <id>`` resolution + paper model pairs."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ModelConfig

# arch id -> module (one file per assigned architecture, as required)
_ARCH_MODULES: Dict[str, str] = {
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests
    (<=2 layers beyond one pattern cycle, d_model<=512, <=4 experts)."""
    cfg = get_config(arch)
    layers = max(2, len(cfg.block_pattern))
    return cfg.reduced(layers=layers, d_model=256, n_experts=4, vocab=512)


def draft_config(arch: str) -> ModelConfig:
    """Same-family draft model for speculative decoding with this target:
    ~1/4 depth, ~1/2 width, same vocab/tokenizer (a paper requirement)."""
    cfg = get_config(arch)
    d_model = max(256, cfg.d_model // 2)
    heads = max(1, cfg.num_heads // 2)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    layers = max(len(cfg.block_pattern), cfg.num_layers // 4)
    kw = dict(name=cfg.name + "-draft", num_layers=layers, d_model=d_model,
              num_heads=heads, num_kv_heads=kv,
              d_ff=max(128, cfg.d_ff // 2))
    if cfg.moe is not None:
        import dataclasses
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=max(4, cfg.moe.num_experts // 8),
            d_expert=max(128, cfg.moe.d_expert // 2),
            dense_layers=tuple(i for i in cfg.moe.dense_layers if i < layers))
    if cfg.encdec is not None:
        import dataclasses
        kw["encdec"] = dataclasses.replace(
            cfg.encdec, num_encoder_layers=max(2, cfg.encdec.num_encoder_layers // 4))
    return cfg.replace(**kw)


# -------- the paper's own evaluation pairs, mapped to in-repo tiny models
# (trained on the synthetic corpus; DESIGN.md §6). Sizes chosen so the
# draft/target capability gap mirrors 1B/8B-style pairs at CPU scale.
def paper_pair(name: str = "llama-1b-8b", vocab: int = 259):
    """Returns (draft_cfg, target_cfg) for a paper model pair analog."""
    # sizes picked for a single-CPU-core budget: the draft/target capability
    # gap is what matters for spec-decode dynamics, not absolute size
    pairs = {
        # analog of Llama-3.2 1B / 3.1 8B
        "llama-1b-8b": (dict(num_layers=2, d_model=128, num_heads=4,
                             num_kv_heads=2, d_ff=256),
                        dict(num_layers=6, d_model=224, num_heads=4,
                             num_kv_heads=2, d_ff=448)),
        # analog of Llama-3.2 1B / 3.1 70B (bigger gap)
        "llama-1b-70b": (dict(num_layers=2, d_model=128, num_heads=4,
                              num_kv_heads=2, d_ff=256),
                         dict(num_layers=8, d_model=256, num_heads=8,
                              num_kv_heads=4, d_ff=512)),
        # analog of Gemma3 270M / 27B (very small draft, MQA+geglu family)
        "gemma-270m-27b": (dict(num_layers=1, d_model=96, num_heads=2,
                                num_kv_heads=1, d_ff=192,
                                activation="geglu"),
                           dict(num_layers=6, d_model=224, num_heads=4,
                                num_kv_heads=1, d_ff=512,
                                activation="geglu")),
        # analog of OLMo-2 1B / 32B (qk_norm family)
        "olmo2-1b-32b": (dict(num_layers=2, d_model=128, num_heads=4,
                              num_kv_heads=4, d_ff=256, qk_norm=True),
                         dict(num_layers=6, d_model=224, num_heads=4,
                              num_kv_heads=4, d_ff=448, qk_norm=True)),
    }
    dkw, tkw = pairs[name]
    base = dict(arch_type="dense", vocab_size=vocab, block_pattern=("attn",))
    return (ModelConfig(name=f"{name}-draft", **base, **dkw),
            ModelConfig(name=f"{name}-target", **base, **tkw))


PAPER_PAIRS = ["llama-1b-8b", "llama-1b-70b", "gemma-270m-27b", "olmo2-1b-32b"]

# Real draft:target forward-cost ratios of the paper's pairs. The tiny analog
# models supply the acceptance DYNAMICS; the cost model must use the real
# pair's FLOP ratio or speedups land in the wrong regime (drafting looks
# artificially expensive at tiny scale, where draft ~ target/6).
PAIR_COST_RATIO = {
    "llama-1b-8b": 1 / 8.0,
    "llama-1b-70b": 1 / 70.0,
    "gemma-270m-27b": 0.27 / 27.0,
    "olmo2-1b-32b": 1 / 32.0,
}
