"""qwen2.5-3b [dense] — GQA kv=2, QKV bias.

[hf:Qwen/Qwen2.5 family card]  36L d_model=2048 16H (kv=2) d_ff=11008
vocab=151936.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
    source="hf:Qwen/Qwen2.5-3B",
)
