"""internvl2-26b [vlm] — InternViT (STUB frontend) + InternLM2-20B backbone.

[arXiv:2404.16821]  48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.
The vision frontend is a stub per the assignment carve-out: input_specs()
provides precomputed InternViT patch embeddings (vit_dim=3200); a 2-layer
MLP projector maps them into the LM embedding space.
"""
from repro.models import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    activation="swiglu",
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
    vision=VisionStubConfig(vit_dim=3200, num_patches=256,
                            projector_hidden=12288),
    source="arXiv:2404.16821 (InternVL2-26B: InternViT-6B + InternLM2-20B)",
)
