"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed MoE top-6.

[arXiv:2405.04434]  27L d_model=2048 16H d_ff(expert)=1408 vocab=102400.
Assignment header says "MoE 64e top-6"; the free-text "160 routed" belongs to
full DeepSeek-V2 — V2-Lite is 64 routed + 2 shared (model card), so we follow
the structured "64e" field.  Layer 0 keeps the dense 10944-wide FFN (model
card); d_ff below is that dense layer's width, experts use d_expert=1408.
"""
from repro.models import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab_size=102400,
    activation="swiglu",
    rope_theta=10000.0,
    block_pattern=("mla",),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                  num_shared_experts=2, d_shared=1408,
                  capacity_factor=1.25, dense_layers=(0,)),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434 (DeepSeek-V2; V2-Lite model card)",
)
