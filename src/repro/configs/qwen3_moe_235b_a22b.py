"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4.

[hf:Qwen/Qwen3-30B-A3B family / Qwen3-235B-A22B]  94L d_model=4096 64H (kv=4)
d_ff(expert)=1536 vocab=151936.
"""
from repro.models import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    activation="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536,
                  capacity_factor=1.25),
    source="hf:Qwen/Qwen3-235B-A22B",
)
