"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060]  48L d_model=2048 vocab=50280, d_state=128, expand=2,
head_dim=64, conv=4, chunk=256.
"""
from repro.models import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    block_pattern=("mamba2",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  ngroups=1, chunk_size=256),
    source="arXiv:2405.21060 (Mamba-2)",
)
