"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1).

[arXiv:2403.08295]  18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    block_pattern=("attn",),
    source="arXiv:2403.08295 (Gemma)",
)
