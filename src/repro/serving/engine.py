"""Batched serving engine: continuous batching at the session level.

Each request owns its own cache pair (stream); all streams share ONE jit
cache (identical shapes) and ONE TapOut controller — the bandit is online
across requests, exactly the paper's deployment setting (the policy adapts
as the prompt distribution shifts).

The scheduler interleaves at draft-session granularity: every scheduler
tick runs one draft+verify session for the next unfinished stream
(round-robin), so a long generation cannot starve the queue.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.controller import Controller
from repro.core.engine import GenResult, ModelBundle, SpecEngine


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    submitted_at: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    request_id: int
    result: GenResult
    latency_s: float
    queue_delay_s: float


class SpecServer:
    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *, max_len: int = 2048,
                 max_concurrency: int = 8, temperature: float = 0.0,
                 greedy: bool = True, seed: int = 0):
        self.engine = SpecEngine(draft, target, controller, max_len=max_len,
                                 temperature=temperature, greedy=greedy,
                                 seed=seed)
        self.max_concurrency = max_concurrency
        self.queue: deque = deque()
        self.active: Dict[int, dict] = {}   # request_id -> stream state
        self.requests: Dict[int, Request] = {}
        self.responses: List[Response] = []
        self._next_id = 0
        self._rr: deque = deque()           # round-robin order of active ids

    # ------------------------------------------------------------- api
    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, prompt, max_new_tokens, eos_id)
        self.requests[rid] = req
        self.queue.append(rid)
        return rid

    def step(self) -> Optional[int]:
        """One scheduler tick: admit + run one session. Returns the finished
        request id if a stream completed this tick."""
        # admit
        while self.queue and len(self.active) < self.max_concurrency:
            rid = self.queue.popleft()
            req = self.requests[rid]
            st = self.engine.start_stream(req.prompt)
            st["started_at"] = time.perf_counter()
            self.active[rid] = st
            self._rr.append(rid)
        if not self._rr:
            return None
        rid = self._rr.popleft()
        st = self.active[rid]
        req = self.requests[rid]
        st = self.engine.session_step(st, req.eos_id)
        self.active[rid] = st
        res: GenResult = st["res"]
        if st["done"] or res.new_tokens >= req.max_new_tokens:
            now = time.perf_counter()
            res.wall_time_s = now - st["started_at"]
            self.responses.append(Response(
                rid, res, latency_s=now - req.submitted_at,
                queue_delay_s=st["started_at"] - req.submitted_at))
            del self.active[rid]
            return rid
        self._rr.append(rid)
        return None

    def run_until_drained(self, max_ticks: int = 1_000_000) -> List[Response]:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.responses

    # ------------------------------------------------------------- stats
    def throughput_stats(self) -> dict:
        if not self.responses:
            return {}
        toks = sum(r.result.new_tokens for r in self.responses)
        cost = sum(r.result.modeled_cost for r in self.responses)
        wall = sum(r.result.wall_time_s for r in self.responses)
        acc = sum(r.result.total_accepted for r in self.responses)
        drf = sum(r.result.total_drafted for r in self.responses)
        return {
            "n_requests": len(self.responses),
            "total_new_tokens": toks,
            "modeled_cost_per_token": cost / max(toks, 1),
            "wall_s_per_token": wall / max(toks, 1),
            "accept_rate": acc / max(drf, 1),
            "mean_latency_s": sum(r.latency_s for r in self.responses)
                               / len(self.responses),
        }
