"""Continuous-batching serving engine over ``BatchedSpecEngine`` (dense
slot-stacked caches) or ``PagedSpecEngine`` (global block pools + per-stream
block tables, ``paged=True``).

Scheduler model
---------------
The server owns a fixed pool of ``max_concurrency`` slots backed by ONE
cache pair and ONE jitted batched draft/verify program (compiled once per
(B, gamma_max) — admission never recompiles it).  POLICY — which request
gets a slot, when prefill runs, who gets evicted — lives in a pluggable
scheduler (``serving/scheduler.py``, docs/slo_scheduling.md):

* ``FIFOScheduler`` (default): every tick begins by prefilling queued
  requests into free slots (FIFO) until the pool is full; an admitted
  request generates in that same tick's batched session.  In-flight
  streams are never paused.  Paged mode is additionally BLOCK-AWARE:
  admission reserves the request's worst-case KV blocks (prompt + token
  budget + draft overshoot) from the shared pool, and when the
  head-of-queue request cannot be covered the scheduler BACKPRESSURES —
  the request stays queued (FIFO order intact) until completions release
  enough blocks.  Reserving worst-case up front means a running stream
  can never hit pool exhaustion mid-flight.
* ``SLOScheduler`` (paged only): priority classes + per-request deadlines
  (``priority=`` / ``slo_ticks=`` on ``submit``), chunked admission
  prefill under a per-tick token budget, and preemption of
  strictly-lower-priority streams via ``engine.preempt_stream`` — frozen
  streams resume through the prefix cache with their KV warm.
* **Slot reuse**: when a stream finishes (EOS / token budget / max_len) its
  slot is released at the end of the tick and the next queued request takes
  it over — the lane's stale cache contents are fully overwritten by the
  admission prefill.
* **Active-mask semantics**: a tick always runs the full fixed-B program;
  slots that are empty (or finished mid-tick, or still mid-chunked-prefill)
  ride along with their lane masked — their device outputs are zeroed
  (``n_drafted == n_accepted == 0``), their bandit observations are
  dropped, and their cache lanes are reconciled by the engine's batched
  rollback, so a masked slot can never perturb its neighbors.

* **Sharding** (``mesh=``, docs/sharding.md): the server hands the mesh to
  its engine, which places params (serve-mode tensor-parallel rules) and
  caches (slot lanes / paged tables over the ("pod","data") batch axes,
  pool heads over "model") at init and compiles the batched session
  programs with NamedSharding in/out shardings.  Admission prefills run
  against mesh-resident state, so a new stream's lane lands directly on
  the shard that owns its slot.

All streams share ONE TapOut controller — the bandit is online across
requests, exactly the paper's deployment setting.  Each tick yields one
batch of per-stream (arms, n_drafted, n_accepted) observations, consumed by
``controller.update_batch`` as an ORDER-INDEPENDENT merge against the
pre-tick bandit state (slot index carries no information).

Per-request accounting: queue delay (submit -> FIRST admission), latency
(submit -> completion, wall seconds AND deterministic scheduler ticks),
SLO attainment, preemption counts and per-stream session stats are
recorded on the ``Response``; ``throughput_stats`` aggregates tokens/s,
p50/p95 latency and queue delay, and per-priority tails.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.controller import Controller
from repro.core.engine import (EngineSpec, GenResult, ModelBundle,
                               engine_spec_from_legacy, make_engine)
from repro.serving.scheduler import FIFOScheduler


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    priority: int = 0                      # higher = more urgent
    slo_ticks: Optional[int] = None        # deadline: submitted_tick + slo
    submitted_at: float = field(default_factory=time.perf_counter)
    submitted_tick: int = 0


@dataclass
class Response:
    request_id: int
    result: GenResult
    latency_s: float
    queue_delay_s: float
    priority: int = 0
    slo_ticks: Optional[int] = None
    latency_ticks: int = 0                 # submit tick -> completion tick
    queue_delay_ticks: int = 0             # submit tick -> first admission
    slo_met: bool = True                   # latency_ticks <= slo_ticks
    n_preemptions: int = 0


_LEGACY_KWARGS = ("max_len", "max_concurrency", "temperature", "greedy",
                  "seed", "paged", "block_size", "pool_tokens", "tree",
                  "kv_dtype", "quant_draft", "mesh")


class SpecServer:
    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *,
                 spec: Optional[EngineSpec] = None,
                 scheduler=None, **legacy):
        # ONE construction surface: an EngineSpec describes the whole
        # deployment (backend, concurrency, precision, placement — see
        # ``core.engine.EngineSpec`` and docs/serving.md) and the factory
        # builds the matching engine.  The pre-spec keyword surface
        # (max_concurrency=, paged=, tree=, ...) still works through
        # ``engine_spec_from_legacy`` but is deprecated.
        if spec is not None and legacy:
            raise TypeError(
                f"pass spec= OR legacy engine kwargs, not both: {sorted(legacy)}")
        if spec is None:
            unknown = set(legacy) - set(_LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"unknown SpecServer kwargs: {sorted(unknown)}")
            if legacy:
                warnings.warn(
                    "SpecServer(max_concurrency=..., paged=..., tree=..., ...)"
                    " is deprecated; pass spec=EngineSpec(...) instead"
                    " (docs/serving.md has the migration table)",
                    DeprecationWarning, stacklevel=2)
            spec = engine_spec_from_legacy(**legacy)
        # serving needs a slot engine: the single-stream and B=1-tree
        # backends promote to their slot facades
        backend = spec.resolve_backend()
        backend = {"single": "batched", "tree": "tree_slot"}.get(backend,
                                                                 backend)
        self.engine = make_engine(draft, target, controller, spec,
                                  backend=backend)
        self.spec = spec
        self.backend = backend
        self.mesh = spec.mesh
        self.paged = backend == "paged"
        self.tree = backend == "tree_slot"
        self.scheduler = scheduler if scheduler is not None else FIFOScheduler()
        if getattr(self.scheduler, "requires_paged", False) and not self.paged:
            raise ValueError(
                f"scheduler {self.scheduler.name!r} needs the paged backend "
                "(chunked prefill and preemption live on block pools)")
        self.gamma_max = controller.gamma_max
        self.max_concurrency = spec.batch_size
        self.queue: deque = deque()
        self.requests: Dict[int, Request] = {}
        self.responses: List[Response] = []
        self._next_id = 0
        self._slot_rid: Dict[int, int] = {}      # slot -> request_id
        self._slot_started: Dict[int, float] = {}
        self._frozen: Dict[int, dict] = {}       # rid -> preempt handle
        self._queue_delay: Dict[int, float] = {}  # rid -> submit->1st admit
        self._admit_tick: Dict[int, int] = {}
        self._rid_preempts: Dict[int, int] = {}
        self.tick_count = 0
        self.backpressure_events = 0
        self.preemption_events = 0
        self.resume_events = 0
        self.max_prefill_tokens_per_tick = 0
        self.peak_concurrency = 0

    # ------------------------------------------------------------- api
    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None, *, priority: int = 0,
               slo_ticks: Optional[int] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.requests[rid] = Request(rid, prompt, max_new_tokens, eos_id,
                                     priority=priority, slo_ticks=slo_ticks,
                                     submitted_tick=self.tick_count)
        self.queue.append(rid)
        return rid

    @property
    def active(self) -> Dict[int, dict]:
        """request_id -> live stream state (monitoring view)."""
        return {rid: self.engine.slots[slot]
                for slot, rid in self._slot_rid.items()}

    def _reserve_tokens(self, rid: int) -> int:
        """Worst-case sequence length of a request: prompt + budget + the
        draft's maximum overshoot within one session.  A preempted request
        resumes from its frozen sequence with only its REMAINING token
        budget outstanding."""
        req = self.requests[rid]
        frozen = self._frozen.get(rid)
        if frozen is not None:
            remaining = max(req.max_new_tokens - frozen["res"].new_tokens, 0)
            return len(frozen["seq"]) + remaining + self.gamma_max + 2
        return len(req.prompt) + req.max_new_tokens + self.gamma_max + 2

    def can_admit(self, rid: int) -> bool:
        """Block-feasibility probe for schedulers (paged backend)."""
        frozen = self._frozen.get(rid)
        prompt = frozen["seq"] if frozen else self.requests[rid].prompt
        return self.engine.can_admit(self._reserve_tokens(rid), prompt=prompt)

    # ------------------------------------------- scheduler mechanisms
    def _open(self, slot: int, rid: int, chunked: bool = False) -> None:
        """Open (or RESUME) request ``rid`` in ``slot``.  Raises
        ``PoolExhausted`` without consuming the frozen handle, so a failed
        attempt can retry later."""
        req = self.requests[rid]
        frozen = self._frozen.get(rid)
        prompt = frozen["seq"] if frozen else req.prompt
        if not self.paged:
            self.engine.open_stream(slot, prompt, req.eos_id)
        else:
            opener = (self.engine.open_stream_chunked if chunked
                      else self.engine.open_stream)
            opener(slot, prompt, req.eos_id,
                   reserve_tokens=self._reserve_tokens(rid),
                   resume_from=frozen["res"] if frozen else None)
        if frozen is not None:
            del self._frozen[rid]
            self.resume_events += 1
        self._slot_rid[slot] = rid
        now = time.perf_counter()
        self._slot_started[slot] = now
        if rid not in self._queue_delay:       # first admission only
            self._queue_delay[rid] = now - req.submitted_at
            self._admit_tick[rid] = self.tick_count

    def _preempt(self, slot: int) -> int:
        """Freeze the stream in ``slot`` and requeue its request as
        resumable.  The engine registers the stream's computed KV in the
        prefix cache before releasing the blocks, so resume re-adopts it
        instead of recomputing."""
        rid = self._slot_rid.pop(slot)
        started = self._slot_started.pop(slot)
        frozen = self.engine.preempt_stream(slot)
        frozen["res"].wall_time_s += time.perf_counter() - started
        self._frozen[rid] = frozen
        self._rid_preempts[rid] = self._rid_preempts.get(rid, 0) + 1
        self.preemption_events += 1
        self.queue.append(rid)
        return rid

    def step(self) -> List[int]:
        """One scheduler tick, PIPELINED against the device:

          1. flush tick t-1 (read back its device-resident outcomes, do
             per-stream accounting, feed the bandit),
          2. release the slots that finished,
          3. run the scheduler (admission, chunked prefill, preemption —
             the engine's tick is fully flushed here, so preemption's
             rollback-and-release cannot race a pending device program),
          4. launch tick t (fused engines: one asynchronous device
             program; its outcomes are read by the NEXT step's flush).

        The bandit therefore consumes acceptance outcomes one step behind
        the device, but its begin/update call sequence — and so its state
        — is exactly what back-to-back synchronous ticks produce.  Returns
        the request ids that completed this tick (i.e. in the flushed
        tick t-1; several streams can finish in one tick)."""
        self.engine.session_step_flush()
        finished = self._release_finished()
        before = getattr(self.engine, "prefill_tokens_computed", None)
        self.scheduler.schedule(self)
        if before is not None:
            # per-tick decode stall from admission prefill (chunked
            # schedulers bound this; monolithic admission pays the whole
            # non-cached prompt suffix at once)
            self.max_prefill_tokens_per_tick = max(
                self.max_prefill_tokens_per_tick,
                self.engine.prefill_tokens_computed - before)
        if self._slot_rid:
            self.peak_concurrency = max(self.peak_concurrency,
                                        len(self._slot_rid))
            self.engine.session_step_launch()
        self.tick_count += 1
        return finished

    def _release_finished(self) -> List[int]:
        finished: List[int] = []
        for slot in list(self._slot_rid):
            st = self.engine.slots[slot]
            if st.get("prefilling"):
                continue
            rid = self._slot_rid[slot]
            req = self.requests[rid]
            res: GenResult = st["res"]
            if st["done"] or res.new_tokens >= req.max_new_tokens:
                now = time.perf_counter()
                started = self._slot_started.pop(slot)
                res.wall_time_s += now - started
                lat_ticks = self.tick_count - req.submitted_tick
                self.responses.append(Response(
                    rid, res, latency_s=now - req.submitted_at,
                    queue_delay_s=self._queue_delay.pop(rid),
                    priority=req.priority, slo_ticks=req.slo_ticks,
                    latency_ticks=lat_ticks,
                    queue_delay_ticks=(self._admit_tick.pop(rid)
                                       - req.submitted_tick),
                    slo_met=(req.slo_ticks is None
                             or lat_ticks <= req.slo_ticks),
                    n_preemptions=self._rid_preempts.pop(rid, 0)))
                self.engine.close_stream(slot)
                del self._slot_rid[slot]
                finished.append(rid)
        return finished

    def run_until_drained(self, max_ticks: int = 1_000_000,
                          timeout_s: Optional[float] = None
                          ) -> List[Response]:
        """Tick until every submitted request has completed (bounded by
        ``max_ticks``).  ``timeout_s`` adds a WALL-CLOCK bound: a wedged
        stream (device hang, scheduler livelock) raises ``TimeoutError``
        carrying a stuck-stream diagnostic instead of spinning silently
        for a million ticks."""
        # the loop condition naturally drains the pipeline: after the last
        # launch, _slot_rid stays non-empty until the final flush+release
        deadline = (None if timeout_s is None
                    else time.perf_counter() + timeout_s)
        ticks = 0
        while (self.queue or self._slot_rid) and ticks < max_ticks:
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(
                    f"SpecServer drain exceeded timeout_s={timeout_s}\n"
                    + self._stuck_diagnostic())
            self.step()
            ticks += 1
        return self.responses

    def _stuck_diagnostic(self) -> str:
        """What is the server waiting on?  One line per live slot plus
        queue/backpressure state — enough to tell a wedged stream (done
        never set, length frozen) from pool starvation (deep queue, high
        backpressure count, no free blocks)."""
        lines = [f"tick={self.tick_count} queued={len(self.queue)} "
                 f"head={list(self.queue)[:8]} "
                 f"frozen={sorted(self._frozen)} "
                 f"backpressure_events={self.backpressure_events}"]
        for slot, rid in sorted(self._slot_rid.items()):
            st = self.engine.slots[slot]
            tag = "prefilling" if st.get("prefilling") else (
                "done" if st["done"] else "decoding")
            lines.append(
                f"  slot {slot}: rid={rid} {tag} seq_len={len(st['seq'])} "
                f"new_tokens={st['res'].new_tokens}"
                f"/{self.requests[rid].max_new_tokens}")
        if self.paged:
            lines.append(f"  pool: free_blocks="
                         f"{len(self.engine.dalloc.free)}(draft)/"
                         f"{len(self.engine.talloc.free)}(target)")
        return "\n".join(lines)

    # ------------------------------------------------------------- stats
    def throughput_stats(self) -> dict:
        if not self.responses:
            return {}
        toks = sum(r.result.new_tokens for r in self.responses)
        cost = sum(r.result.modeled_cost for r in self.responses)
        wall = sum(r.result.wall_time_s for r in self.responses)
        acc = sum(r.result.total_accepted for r in self.responses)
        drf = sum(r.result.total_drafted for r in self.responses)
        lats = np.array([r.latency_s for r in self.responses])
        qds = np.array([r.queue_delay_s for r in self.responses])
        sessions = sum(len(r.result.sessions) for r in self.responses)
        stats = {
            "n_requests": len(self.responses),
            "total_new_tokens": toks,
            "modeled_cost_per_token": cost / max(toks, 1),
            "wall_s_per_token": wall / max(toks, 1),
            "accept_rate": acc / max(drf, 1),
            # canonical across ALL backends (the tree-vs-chain objective is
            # just its specialization): accepted tokens per verify forward
            "accepted_per_verify": acc / max(sessions, 1),
            "mean_latency_s": float(lats.mean()),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "mean_queue_delay_s": float(qds.mean()),
            "p50_queue_delay_s": float(np.percentile(qds, 50)),
            "p95_queue_delay_s": float(np.percentile(qds, 95)),
            "per_priority": self._per_priority_stats(),
            "scheduler": self.scheduler.name,
            "peak_concurrency": self.peak_concurrency,
            "backpressure_events": self.backpressure_events,
            "preemption_events": self.preemption_events,
            "resume_events": self.resume_events,
            "max_prefill_tokens_per_tick": self.max_prefill_tokens_per_tick,
            # canonical settings blob: what produced these numbers
            "engine": self.engine.describe(),
        }
        if self.mesh is not None:
            stats["mesh_devices"] = int(self.mesh.devices.size)
            stats["mesh_axes"] = {k: int(v)
                                  for k, v in self.mesh.shape.items()}
        if self.paged:
            stats.update(self.engine.pool_stats())
        if self.tree:
            # the bandit's shape preferences after serving this workload
            ctrl = self.engine.controller
            stats["shape_names"] = [s.name for s in ctrl.shapes]
            stats["shape_pulls"] = ctrl.shape_pulls.tolist()
            stats["shape_values"] = np.asarray(ctrl.arm_values).tolist()
        if getattr(self.engine, "drafters", None) is not None:
            # drafter-axis marginals: which drafter the meta-bandit pulled
            ctrl = self.engine.controller
            stats["shape_names"] = [s.name for s in ctrl.shapes]
            stats["shape_pulls"] = ctrl.shape_pulls.tolist()
            stats["drafter_names"] = self.engine.drafters.names
            stats["drafter_pulls"] = ctrl.drafter_pulls
        return stats

    def _per_priority_stats(self) -> dict:
        """Per-priority-class tails: the whole point of the SLO scheduler
        is that these DIVERGE (interactive p95 stays low while batch
        absorbs the queueing) even when the aggregate numbers match."""
        out: Dict[str, dict] = {}
        for p in sorted({r.priority for r in self.responses}):
            rs = [r for r in self.responses if r.priority == p]
            lats = np.array([r.latency_s for r in rs])
            qds = np.array([r.queue_delay_s for r in rs])
            slo = [r for r in rs if r.slo_ticks is not None]
            out[str(p)] = {
                "n_requests": len(rs),
                "p50_latency_s": float(np.percentile(lats, 50)),
                "p95_latency_s": float(np.percentile(lats, 95)),
                "p95_queue_delay_s": float(np.percentile(qds, 95)),
                "slo_met_frac": (sum(r.slo_met for r in slo) / len(slo)
                                 if slo else 1.0),
            }
        return out
