"""Continuous-batching serving engine over ``BatchedSpecEngine`` (dense
slot-stacked caches) or ``PagedSpecEngine`` (global block pools + per-stream
block tables, ``paged=True``).

Scheduler model
---------------
The server owns a fixed pool of ``max_concurrency`` slots backed by ONE
cache pair and ONE jitted batched draft/verify program (compiled once per
(B, gamma_max) — admission never recompiles it).

* **Admission**: every tick begins by prefilling queued requests into free
  slots (FIFO) until the pool is full; an admitted request generates in
  that same tick's batched session.  In-flight streams are never paused.
  Paged mode is additionally BLOCK-AWARE: admission reserves the request's
  worst-case KV blocks (prompt + token budget + draft overshoot) from the
  shared pool, and when the head-of-queue request cannot be covered the
  scheduler BACKPRESSURES — the request stays queued (FIFO order intact)
  until completions release enough blocks.  Reserving worst-case up front
  means a running stream can never hit pool exhaustion mid-flight.
* **Slot reuse**: when a stream finishes (EOS / token budget / max_len) its
  slot is released at the end of the tick and the next queued request takes
  it over — the lane's stale cache contents are fully overwritten by the
  admission prefill.
* **Active-mask semantics**: a tick always runs the full fixed-B program;
  slots that are empty (or finished mid-tick) ride along with their lane
  masked — their device outputs are zeroed (``n_drafted == n_accepted ==
  0``), their bandit observations are dropped, and their cache lanes are
  reconciled by the engine's batched rollback, so a masked slot can never
  perturb its neighbors.

* **Sharding** (``mesh=``, docs/sharding.md): the server hands the mesh to
  its engine, which places params (serve-mode tensor-parallel rules) and
  caches (slot lanes / paged tables over the ("pod","data") batch axes,
  pool heads over "model") at init and compiles the batched session
  programs with NamedSharding in/out shardings.  Admission prefills run
  against mesh-resident state, so a new stream's lane lands directly on
  the shard that owns its slot.

All streams share ONE TapOut controller — the bandit is online across
requests, exactly the paper's deployment setting.  Each tick yields one
batch of per-stream (arms, n_drafted, n_accepted) observations, consumed by
``controller.update_batch`` as an ORDER-INDEPENDENT merge against the
pre-tick bandit state (slot index carries no information).

Per-request accounting: queue delay (submit -> admission), latency
(submit -> completion) and per-stream session stats are recorded on the
``Response``; ``throughput_stats`` aggregates tokens/s and p50/p95 latency.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.controller import Controller
from repro.core.engine import (EngineSpec, GenResult, ModelBundle,
                               engine_spec_from_legacy, make_engine)
from repro.models.cache import PoolExhausted


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    submitted_at: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    request_id: int
    result: GenResult
    latency_s: float
    queue_delay_s: float


_LEGACY_KWARGS = ("max_len", "max_concurrency", "temperature", "greedy",
                  "seed", "paged", "block_size", "pool_tokens", "tree",
                  "kv_dtype", "quant_draft", "mesh")


class SpecServer:
    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *,
                 spec: Optional[EngineSpec] = None, **legacy):
        # ONE construction surface: an EngineSpec describes the whole
        # deployment (backend, concurrency, precision, placement — see
        # ``core.engine.EngineSpec`` and docs/serving.md) and the factory
        # builds the matching engine.  The pre-spec keyword surface
        # (max_concurrency=, paged=, tree=, ...) still works through
        # ``engine_spec_from_legacy`` but is deprecated.
        if spec is not None and legacy:
            raise TypeError(
                f"pass spec= OR legacy engine kwargs, not both: {sorted(legacy)}")
        if spec is None:
            unknown = set(legacy) - set(_LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"unknown SpecServer kwargs: {sorted(unknown)}")
            if legacy:
                warnings.warn(
                    "SpecServer(max_concurrency=..., paged=..., tree=..., ...)"
                    " is deprecated; pass spec=EngineSpec(...) instead"
                    " (docs/serving.md has the migration table)",
                    DeprecationWarning, stacklevel=2)
            spec = engine_spec_from_legacy(**legacy)
        # serving needs a slot engine: the single-stream and B=1-tree
        # backends promote to their slot facades
        backend = spec.resolve_backend()
        backend = {"single": "batched", "tree": "tree_slot"}.get(backend,
                                                                 backend)
        self.engine = make_engine(draft, target, controller, spec,
                                  backend=backend)
        self.spec = spec
        self.backend = backend
        self.mesh = spec.mesh
        self.paged = backend == "paged"
        self.tree = backend == "tree_slot"
        self.gamma_max = controller.gamma_max
        self.max_concurrency = spec.batch_size
        self.queue: deque = deque()
        self.requests: Dict[int, Request] = {}
        self.responses: List[Response] = []
        self._next_id = 0
        self._slot_rid: Dict[int, int] = {}      # slot -> request_id
        self._slot_started: Dict[int, float] = {}
        self.backpressure_events = 0
        self.peak_concurrency = 0

    # ------------------------------------------------------------- api
    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.requests[rid] = Request(rid, prompt, max_new_tokens, eos_id)
        self.queue.append(rid)
        return rid

    @property
    def active(self) -> Dict[int, dict]:
        """request_id -> live stream state (monitoring view)."""
        return {rid: self.engine.slots[slot]
                for slot, rid in self._slot_rid.items()}

    def _reserve_tokens(self, req: Request) -> int:
        """Worst-case sequence length of a request: prompt + budget + the
        draft's maximum overshoot within one session."""
        return len(req.prompt) + req.max_new_tokens + self.gamma_max + 2

    def _admit(self) -> None:
        for slot in self.engine.free_slots():
            if not self.queue:
                break
            rid = self.queue[0]
            req = self.requests[rid]
            if self.paged and not self.engine.can_admit(
                    self._reserve_tokens(req), prompt=req.prompt):
                # backpressure: head-of-queue request stays queued (FIFO
                # preserved) until completed streams release blocks
                self.backpressure_events += 1
                break
            self.queue.popleft()
            if self.paged:
                try:
                    self.engine.open_stream(
                        slot, req.prompt, req.eos_id,
                        reserve_tokens=self._reserve_tokens(req))
                except PoolExhausted:
                    # ``can_admit`` is a feasibility PROBE, not a
                    # reservation: anything that shifts evictability
                    # between probe and admission lands here.  The request
                    # goes back to the head of the queue (FIFO intact) —
                    # backpressure, never a dropped request or a crashed
                    # serving loop.
                    self.queue.appendleft(rid)
                    self.backpressure_events += 1
                    break
            else:
                self.engine.open_stream(slot, req.prompt, req.eos_id)
            self._slot_rid[slot] = rid
            self._slot_started[slot] = time.perf_counter()

    def step(self) -> List[int]:
        """One scheduler tick, PIPELINED against the device:

          1. flush tick t-1 (read back its device-resident outcomes, do
             per-stream accounting, feed the bandit),
          2. release the slots that finished,
          3. admit queued requests into the free slots,
          4. launch tick t (fused engines: one asynchronous device
             program; its outcomes are read by the NEXT step's flush).

        The bandit therefore consumes acceptance outcomes one step behind
        the device, but its begin/update call sequence — and so its state
        — is exactly what back-to-back synchronous ticks produce.  Returns
        the request ids that completed this tick (i.e. in the flushed
        tick t-1; several streams can finish in one tick)."""
        self.engine.session_step_flush()
        finished = self._release_finished()
        self._admit()
        if self._slot_rid:
            self.peak_concurrency = max(self.peak_concurrency,
                                        len(self._slot_rid))
            self.engine.session_step_launch()
        return finished

    def _release_finished(self) -> List[int]:
        finished: List[int] = []
        for slot in list(self._slot_rid):
            st = self.engine.slots[slot]
            rid = self._slot_rid[slot]
            req = self.requests[rid]
            res: GenResult = st["res"]
            if st["done"] or res.new_tokens >= req.max_new_tokens:
                now = time.perf_counter()
                started = self._slot_started.pop(slot)
                res.wall_time_s = now - started
                self.responses.append(Response(
                    rid, res, latency_s=now - req.submitted_at,
                    queue_delay_s=started - req.submitted_at))
                self.engine.close_stream(slot)
                del self._slot_rid[slot]
                finished.append(rid)
        return finished

    def run_until_drained(self, max_ticks: int = 1_000_000) -> List[Response]:
        # the loop condition naturally drains the pipeline: after the last
        # launch, _slot_rid stays non-empty until the final flush+release
        ticks = 0
        while (self.queue or self._slot_rid) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.responses

    # ------------------------------------------------------------- stats
    def throughput_stats(self) -> dict:
        if not self.responses:
            return {}
        toks = sum(r.result.new_tokens for r in self.responses)
        cost = sum(r.result.modeled_cost for r in self.responses)
        wall = sum(r.result.wall_time_s for r in self.responses)
        acc = sum(r.result.total_accepted for r in self.responses)
        drf = sum(r.result.total_drafted for r in self.responses)
        lats = np.array([r.latency_s for r in self.responses])
        sessions = sum(len(r.result.sessions) for r in self.responses)
        stats = {
            "n_requests": len(self.responses),
            "total_new_tokens": toks,
            "modeled_cost_per_token": cost / max(toks, 1),
            "wall_s_per_token": wall / max(toks, 1),
            "accept_rate": acc / max(drf, 1),
            # canonical across ALL backends (the tree-vs-chain objective is
            # just its specialization): accepted tokens per verify forward
            "accepted_per_verify": acc / max(sessions, 1),
            "mean_latency_s": float(lats.mean()),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "peak_concurrency": self.peak_concurrency,
            "backpressure_events": self.backpressure_events,
            # canonical settings blob: what produced these numbers
            "engine": self.engine.describe(),
        }
        if self.mesh is not None:
            stats["mesh_devices"] = int(self.mesh.devices.size)
            stats["mesh_axes"] = {k: int(v)
                                  for k, v in self.mesh.shape.items()}
        if self.paged:
            stats.update(self.engine.pool_stats())
        if self.tree:
            # the bandit's shape preferences after serving this workload
            ctrl = self.engine.controller
            stats["shape_names"] = [s.name for s in ctrl.shapes]
            stats["shape_pulls"] = ctrl.shape_pulls.tolist()
            stats["shape_values"] = np.asarray(ctrl.arm_values).tolist()
        return stats
