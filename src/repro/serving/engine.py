"""Continuous-batching serving engine over ``BatchedSpecEngine``.

Scheduler model
---------------
The server owns a fixed pool of ``max_concurrency`` slots backed by ONE
slot-stacked cache pair and ONE jitted batched draft/verify program
(compiled once per (B, gamma_max) — admission never recompiles it).

* **Admission**: every tick begins by prefilling queued requests into free
  slots (FIFO) until the pool is full; an admitted request generates in
  that same tick's batched session.  In-flight streams are never paused.
* **Slot reuse**: when a stream finishes (EOS / token budget / max_len) its
  slot is released at the end of the tick and the next queued request takes
  it over — the lane's stale cache contents are fully overwritten by the
  admission prefill.
* **Active-mask semantics**: a tick always runs the full fixed-B program;
  slots that are empty (or finished mid-tick) ride along with their lane
  masked — their device outputs are zeroed (``n_drafted == n_accepted ==
  0``), their bandit observations are dropped, and their cache lanes are
  reconciled by the engine's batched rollback, so a masked slot can never
  perturb its neighbors.

All streams share ONE TapOut controller — the bandit is online across
requests, exactly the paper's deployment setting.  Each tick yields one
batch of per-stream (arms, n_drafted, n_accepted) observations, consumed by
``controller.update_batch`` as an ORDER-INDEPENDENT merge against the
pre-tick bandit state (slot index carries no information).

Per-request accounting: queue delay (submit -> admission), latency
(submit -> completion) and per-stream session stats are recorded on the
``Response``; ``throughput_stats`` aggregates tokens/s and p50/p95 latency.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.controller import Controller
from repro.core.engine import BatchedSpecEngine, GenResult, ModelBundle


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    submitted_at: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    request_id: int
    result: GenResult
    latency_s: float
    queue_delay_s: float


class SpecServer:
    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *, max_len: int = 2048,
                 max_concurrency: int = 8, temperature: float = 0.0,
                 greedy: bool = True, seed: int = 0):
        self.engine = BatchedSpecEngine(
            draft, target, controller, batch_size=max_concurrency,
            max_len=max_len, temperature=temperature, greedy=greedy,
            seed=seed)
        self.max_concurrency = max_concurrency
        self.queue: deque = deque()
        self.requests: Dict[int, Request] = {}
        self.responses: List[Response] = []
        self._next_id = 0
        self._slot_rid: Dict[int, int] = {}      # slot -> request_id
        self._slot_started: Dict[int, float] = {}

    # ------------------------------------------------------------- api
    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.requests[rid] = Request(rid, prompt, max_new_tokens, eos_id)
        self.queue.append(rid)
        return rid

    @property
    def active(self) -> Dict[int, dict]:
        """request_id -> live stream state (monitoring view)."""
        return {rid: self.engine.slots[slot]
                for slot, rid in self._slot_rid.items()}

    def _admit(self) -> None:
        for slot in self.engine.free_slots():
            if not self.queue:
                break
            rid = self.queue.popleft()
            req = self.requests[rid]
            self.engine.open_stream(slot, req.prompt, req.eos_id)
            self._slot_rid[slot] = rid
            self._slot_started[slot] = time.perf_counter()

    def step(self) -> List[int]:
        """One scheduler tick: admit, run one batched session across all
        active slots, release finished slots.  Returns the request ids that
        completed this tick (several streams can finish in one tick)."""
        self._admit()
        if not self._slot_rid:
            return []
        self.engine.session_step_batch()
        finished: List[int] = []
        for slot in list(self._slot_rid):
            st = self.engine.slots[slot]
            rid = self._slot_rid[slot]
            req = self.requests[rid]
            res: GenResult = st["res"]
            if st["done"] or res.new_tokens >= req.max_new_tokens:
                now = time.perf_counter()
                started = self._slot_started.pop(slot)
                res.wall_time_s = now - started
                self.responses.append(Response(
                    rid, res, latency_s=now - req.submitted_at,
                    queue_delay_s=started - req.submitted_at))
                self.engine.close_stream(slot)
                del self._slot_rid[slot]
                finished.append(rid)
        return finished

    def run_until_drained(self, max_ticks: int = 1_000_000) -> List[Response]:
        ticks = 0
        while (self.queue or self._slot_rid) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.responses

    # ------------------------------------------------------------- stats
    def throughput_stats(self) -> dict:
        if not self.responses:
            return {}
        toks = sum(r.result.new_tokens for r in self.responses)
        cost = sum(r.result.modeled_cost for r in self.responses)
        wall = sum(r.result.wall_time_s for r in self.responses)
        acc = sum(r.result.total_accepted for r in self.responses)
        drf = sum(r.result.total_drafted for r in self.responses)
        lats = np.array([r.latency_s for r in self.responses])
        return {
            "n_requests": len(self.responses),
            "total_new_tokens": toks,
            "modeled_cost_per_token": cost / max(toks, 1),
            "wall_s_per_token": wall / max(toks, 1),
            "accept_rate": acc / max(drf, 1),
            "mean_latency_s": float(lats.mean()),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
        }
