"""Scheduling policies for ``SpecServer`` (docs/slo_scheduling.md).

The server owns MECHANISM — slots, streams, accounting, the engine tick —
and delegates POLICY to a scheduler object with one hook::

    scheduler.schedule(server)   # between tick flush and tick launch

At that point the previous tick is fully flushed (``engine._pending is
None``), finished slots are released, and whatever the scheduler admits
rides the tick launched right after.  Two policies ship:

* ``FIFOScheduler`` — the classic baseline: head-of-queue admission into
  free slots with monolithic admission prefill, block-aware backpressure
  on the paged backend.  Exactly the server's historical behavior.
* ``SLOScheduler`` — priority classes + earliest-deadline-first within a
  class, CHUNKED admission prefill under a per-tick token budget (a long
  prompt never stalls in-flight decodes for more than one bounded chunk),
  and PREEMPTION of strictly-lower-priority streams when a waiting
  request cannot otherwise get a slot or blocks.  Preempted streams are
  frozen through ``engine.preempt_stream`` — their computed KV stays warm
  in the prefix cache, so resume is an admission-time adoption.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.models.cache import PoolExhausted


class FIFOScheduler:
    """Head-of-queue admission, monolithic prefill — the baseline the SLO
    scheduler is benchmarked against, and the default policy."""

    name = "fifo"
    requires_paged = False

    def schedule(self, server) -> None:
        for slot in server.engine.free_slots():
            if not server.queue:
                break
            rid = server.queue[0]
            if server.paged and not server.can_admit(rid):
                # backpressure: head-of-queue request stays queued (FIFO
                # preserved) until completed streams release blocks
                server.backpressure_events += 1
                break
            server.queue.popleft()
            try:
                server._open(slot, rid)
            except PoolExhausted:
                # ``can_admit`` is a feasibility PROBE, not a
                # reservation: anything that shifts evictability between
                # probe and admission lands here.  The request goes back
                # to the head of the queue (FIFO intact) — backpressure,
                # never a dropped request or a crashed serving loop.
                server.queue.appendleft(rid)
                server.backpressure_events += 1
                break


class SLOScheduler:
    """Priority + EDF admission, chunked prefill, preemption.

    Ordering: waiting requests are ranked by ``(-priority, deadline,
    request_id)`` where ``deadline = submitted_tick + slo_ticks``
    (requests without an SLO sort last within their priority).  Admission
    is STRICT-PRIORITY: when the top-ranked request cannot be admitted —
    no slot, no preemptable victim, not enough blocks — the scheduler
    backpressures rather than admitting anything ranked below it, so a
    burst of cheap low-priority traffic can never starve the head.

    Preemption: a waiting request may evict a running (or mid-prefill)
    stream of STRICTLY lower priority; among victims the one with the
    fewest generated tokens goes first (least progress to keep warm).
    Victims re-enter the queue as resumable frozen handles.

    Chunked prefill: admitted prompts reserve their blocks immediately
    (``open_stream_chunked``) but feed at most
    ``max_prefill_tokens_per_tick`` prompt tokens per tick across all
    mid-prefill slots, highest-ranked first — the per-admission decode
    stall is bounded by one chunk schedule window instead of one full
    prompt."""

    name = "slo"
    requires_paged = True

    def __init__(self, *, max_prefill_tokens_per_tick: int = 32,
                 preempt: bool = True):
        assert max_prefill_tokens_per_tick >= 1
        self.max_prefill_tokens_per_tick = max_prefill_tokens_per_tick
        self.preempt = preempt

    # ----------------------------------------------------------- ranking
    def _rank(self, server, rid: int):
        req = server.requests[rid]
        deadline = (req.submitted_tick + req.slo_ticks
                    if req.slo_ticks is not None else math.inf)
        return (-req.priority, deadline, rid)

    def _pick_victim(self, server, rid: int) -> Optional[int]:
        """Occupied slot to evict for ``rid``: strictly lower priority
        only, fewest generated tokens first."""
        pri = server.requests[rid].priority
        best, best_key = None, None
        for slot, vrid in server._slot_rid.items():
            vreq = server.requests[vrid]
            if vreq.priority >= pri:
                continue
            st = server.engine.slots[slot]
            key = (vreq.priority, st["res"].new_tokens, -slot)
            if best is None or key < best_key:
                best, best_key = slot, key
        return best

    # --------------------------------------------------------- the hook
    def schedule(self, server) -> None:
        # 1. admission, strict priority order (reservation only — the
        #    prompt feed happens in the budgeted phase below)
        for rid in sorted(server.queue, key=lambda r: self._rank(server, r)):
            admitted = False
            while True:
                free = server.engine.free_slots()
                if free and server.can_admit(rid):
                    server.queue.remove(rid)
                    try:
                        server._open(free[0], rid, chunked=True)
                        admitted = True
                    except PoolExhausted:
                        # probe/admission race: requeue at head, FIFO-
                        # within-rank intact (same protocol as FIFO)
                        server.queue.appendleft(rid)
                        server.backpressure_events += 1
                    break
                victim = (self._pick_victim(server, rid)
                          if self.preempt else None)
                if victim is None:
                    server.backpressure_events += 1
                    break
                server._preempt(victim)   # frees the slot AND its blocks
            if not admitted:
                break                     # strict priority: nobody jumps
        # 2. budgeted chunked prefill, highest-ranked streams first
        budget = self.max_prefill_tokens_per_tick
        pref = sorted(server.engine.prefilling_slots(),
                      key=lambda s: self._rank(server, server._slot_rid[s]))
        for slot in pref:
            if budget <= 0:
                break
            budget -= server.engine.prefill_step(slot, budget)
