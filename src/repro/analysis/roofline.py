"""Three-term roofline model from a compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
    memory term     = HLO_bytes   / (chips * HBM_bw)
    collective term = coll_bytes  / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the (SPMD-partitioned, per-device)
HLO text and sum the traffic of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, converting each op's
result size to ring-algorithm bytes-on-the-wire per chip:

    all-gather        result * (g-1)/g        (receives everyone else's shard)
    all-reduce        2 * size * (g-1)/g      (reduce-scatter + all-gather)
    reduce-scatter    operand * (g-1)/g  ~= result * (g-1)
    all-to-all        size * (g-1)/g
    collective-permute size

where g = replica-group size parsed from the op attributes.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

__all__ = ["Roofline", "build_roofline"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return total_devices


def collective_bytes_from_hlo(hlo_text: str, total_devices: int) -> Dict[str, float]:
    """Per-chip on-the-wire bytes per collective kind (per program run)."""
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    for m in _COLL_RE.finditer(hlo_text):
        tuple_part, dtype, dims, kind = m.group(1), m.group(2), m.group(3), m.group(4)
        line = m.group(0)
        if tuple_part:
            size = sum(_shape_bytes(d, s)
                       for d, s in _SHAPE_RE.findall(tuple_part))
        else:
            size = _shape_bytes(dtype, dims)
        g = max(_group_size(line, total_devices), 1)
        if kind == "all-gather":
            traffic = size * (g - 1) / g
        elif kind == "all-reduce":
            traffic = 2 * size * (g - 1) / g
        elif kind == "reduce-scatter":
            traffic = size * (g - 1)          # result is 1/g of operand
        elif kind == "all-to-all":
            traffic = size * (g - 1) / g
        else:                                  # collective-permute
            traffic = size
        out[kind] += traffic
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # whole-program FLOPs (all chips)
    hlo_bytes: float            # whole-program HBM bytes (all chips)
    collective: Dict[str, float]  # per-chip wire bytes by kind
    model_flops: float = 0.0    # 6*N*D (active) useful FLOPs
    # link count per chip: v5e 2D torus -> 4 ICI links usable
    links_per_chip: int = 4

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective.values())

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.links_per_chip * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_per_chip_bytes": self.collective,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
        }


def model_flops_for(cfg, kind: str, batch: int, seq_len: int) -> float:
    """6*N_active*D for train (fwd+bwd), 2*N_active*D for inference fwd."""
    n = cfg.active_param_count()
    tokens = batch * (seq_len if kind in ("train", "prefill") else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def build_roofline(arch: str, shape: str, mesh_name: str, chips: int,
                   cost: dict, hlo_text: str, cfg, kind: str,
                   batch: int, seq_len: int) -> Roofline:
    # cost_analysis reports per-device numbers on SPMD-partitioned modules;
    # scale to whole-program to keep the roofline definition uniform.
    flops = float(cost.get("flops", 0.0)) * chips
    byts = float(cost.get("bytes accessed", 0.0)) * chips
    coll = collective_bytes_from_hlo(hlo_text, chips)
    return Roofline(arch, shape, mesh_name, chips, flops, byts, coll,
                    model_flops=model_flops_for(cfg, kind, batch, seq_len))
