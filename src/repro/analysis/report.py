"""Generate EXPERIMENTS.md from dry-run + benchmark artifacts.

Each section renders one artifact family from ``artifacts/``: the dry-run
compile/memory results (``launch/dryrun.py``), the roofline terms
(``analysis/roofline.py``) and the benchmark JSON payloads.  Run as
``python -m repro.analysis.report``; missing artifacts render as empty
sections, never errors.
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

__all__ = ["dryrun_section", "roofline_section", "bench_section",
           "serving_section", "build", "main"]

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
ART = os.path.join(ROOT, "artifacts")
BENCH_SERVING = os.path.join(ROOT, "BENCH_serving.json")

ARCH_ORDER = ["deepseek-v2-lite-16b", "gemma-2b", "qwen3-4b",
              "recurrentgemma-2b", "qwen3-moe-235b-a22b", "mamba2-1.3b",
              "qwen2.5-3b", "internvl2-26b", "seamless-m4t-large-v2",
              "phi4-mini-3.8b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(pattern):
    out = {}
    for f in glob.glob(os.path.join(ART, "dryrun", pattern)):
        d = json.load(open(f))
        if isinstance(d, list):
            d = d[0]
        out[os.path.basename(f)[:-5]] = d
    return out


def _fmt_bytes(n):
    for u in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.1f}PB"


def _ms(x):
    return f"{x*1e3:.2f}"


def dryrun_section() -> str:
    rows = []
    data = _load("*.json")
    lines = ["## §Dry-run", "",
             "Every (architecture x input-shape) pair lowered **and compiled** "
             "with `jax.jit(...).lower().compile()` on both production meshes "
             "(single pod 16x16 = 256 chips; multi-pod 2x16x16 = 512 chips), "
             "512 forced host devices. `memory_analysis()` / `cost_analysis()` "
             "captured per pair (JSON in `artifacts/dryrun/`).", "",
             "| arch | shape | mesh | status | step kind | args/chip | temp/chip | compile s |",
             "|---|---|---|---|---|---|---|---|"]
    n_ok = n_tot = 0
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for tag, mesh in (("single", "16x16"), ("multi", "2x16x16")):
                key = f"{arch}_{shape}_{tag}"
                d = data.get(key)
                if d is None:
                    continue
                n_tot += 1
                ok = d.get("status") == "compiled"
                n_ok += ok
                mem = d.get("memory", {})
                lines.append(
                    f"| {arch} | {shape} | {mesh} | "
                    f"{'OK' if ok else 'FAIL: ' + str(d.get('error'))[:60]} | "
                    f"{d.get('kind','')} | "
                    f"{_fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
                    f"{_fmt_bytes(mem.get('temp_size_in_bytes', 0))} | "
                    f"{d.get('compile_s', 0):.1f} |")
    lines.insert(3, f"**{n_ok}/{n_tot} combinations compiled.**")
    lines.append("")
    lines.append("Notes: decode shapes lower `serve_step` (1 new token vs a "
                 "seq_len cache); `long_500k` uses the native bounded state "
                 "for SSM/hybrid archs and the ring-buffer sliding-window "
                 "variant (window 8192) for full-attention archs "
                 "(DESIGN.md §4.2) — all 10 archs run all 4 shapes.")
    return "\n".join(lines)


def roofline_section() -> str:
    data = _load("*_single_unroll.json")
    lines = ["## §Roofline", "",
             "Three-term roofline per (arch x shape) on the single-pod mesh "
             "(256 chips), from the **unrolled** compiled dry-run "
             "(scan-over-layers bodies are counted once by XLA cost analysis, "
             "so the roofline pass unrolls; the compile-proof pass above uses "
             "the scanned production config). Hardware: 197 TFLOP/s bf16, "
             "819 GB/s HBM, 4x50 GB/s ICI links per chip.", "",
             "| arch | shape | t_compute ms | t_memory ms | t_collective ms | "
             "dominant | MODEL_FLOPS/HLO_FLOPS | what would move it |",
             "|---|---|---|---|---|---|---|---|"]
    suggestions = {
        ("compute", "train"): "more chips or lower-precision matmuls",
        ("memory", "train"): "larger per-chip batch (raise arithmetic intensity), fuse remat reads",
        ("collective", "train"): "overlap grad reduce-scatter with backward; shard experts 2D",
        ("memory", "prefill"): "bigger flash-attention blocks; keep weights resident (reduce re-streaming)",
        ("compute", "prefill"): "near-roofline already; only kernel-level wins left",
        ("collective", "prefill"): "reshard activations to cut per-layer gathers",
        ("memory", "decode"): "decode is weight/cache-streaming bound: batch more requests per chip or quantize cache",
        ("collective", "decode"): "move vocab/head gathers off the critical path (all-gather on logits only)",
        ("compute", "decode"): "unexpected for decode: check redundant recompute",
    }
    scanned = _load("*_single.json")
    n_cycles = {"deepseek-v2-lite-16b": 26, "gemma-2b": 18, "qwen3-4b": 36,
                "recurrentgemma-2b": 8, "qwen3-moe-235b-a22b": 94,
                "mamba2-1.3b": 48, "qwen2.5-3b": 36, "internvl2-26b": 48,
                "seamless-m4t-large-v2": 24, "phi4-mini-3.8b": 32}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = data.get(f"{arch}_{shape}_single_unroll")
            approx = ""
            if not d or d.get("status") != "compiled":
                # fall back to the scanned run with a trip-count correction
                # on the loop-body-once-counted cost terms (upper-bounds by
                # scaling everything by n_cycles; marked ~)
                d = scanned.get(f"{arch}_{shape}_single")
                if not d or d.get("status") != "compiled":
                    continue
                d = json.loads(json.dumps(d))
                rl = d["roofline"]
                k = n_cycles.get(arch, 1)
                for key in ("t_compute_s", "t_memory_s", "t_collective_s"):
                    rl[key] = rl[key] * k
                rl["useful_flops_frac"] = rl["useful_flops_frac"] / k
                terms = {"compute": rl["t_compute_s"],
                         "memory": rl["t_memory_s"],
                         "collective": rl["t_collective_s"]}
                rl["dominant"] = max(terms, key=terms.get)
                approx = "~"
            rl = d["roofline"]
            kind = d.get("kind", "?")
            dom = rl["dominant"]
            frac = rl["useful_flops_frac"]
            lines.append(
                f"| {arch} | {shape} | {approx}{_ms(rl['t_compute_s'])} | "
                f"{approx}{_ms(rl['t_memory_s'])} | "
                f"{approx}{_ms(rl['t_collective_s'])} | "
                f"**{dom}** | {frac:.2f} | "
                f"{suggestions.get((dom, kind), '-')} |")
    lines.append("")
    lines.append("(~ = scanned-run fallback, terms scaled by the layer-scan "
                 "trip count — an approximation used only if the unrolled "
                 "compile exceeded its time budget.)")
    return "\n".join(lines)


def bench_section() -> str:
    lines = ["## §Paper-validation", ""]
    bdir = os.path.join(ART, "bench")
    claims = {
        "table2_reward": ("C1 (Table 2): r_blend >= r_simple on acceptance "
                          "rate & speedup (pooled online run)",
                          ["claim_blend_higher_accept_rate",
                           "claim_blend_higher_speedup",
                           "claim_simple_speculates_longer"]),
        "fig4_ucb_variants": ("C2 (Fig 4): UCB1 >= UCB-Tuned (pooled)",
                              ["claim_ucb1_geq_ucbtuned",
                               "claim_ucb1_geq_ucbtuned_frac"]),
        "table3_main": ("C3 (Table 3): Seq-UCB1 top-2 speedup, tuning-free",
                        ["claim_sequcb1_top2_frac"]),
        "table5_specbench": ("C3' (Table 5): SpecBench",
                             ["claim_sequcb1_top2_frac"]),
        "fig2_entropy": ("C4 (Fig 2): coding entropy lower; decays with t",
                         ["claim_coding_lower_entropy", "claim_entropy_decays"]),
        "table4_specdecpp": ("C6 (Table 4): Seq-UCB1 beats trained SpecDec++",
                             ["claim_sequcb1_beats_specdecpp"]),
        "a2_more_arms": ("C7 (A.2): small pool beats multi-threshold pool",
                         ["claim_small_pool_wins"]),
    }
    for name, (desc, keys) in claims.items():
        p = os.path.join(bdir, f"{name}.json")
        if not os.path.exists(p):
            lines.append(f"- {desc}: _not yet run_")
            continue
        d = json.load(open(p))
        vals = ", ".join(f"{k.replace('claim_','')}={d.get(k)}" for k in keys)
        lines.append(f"- {desc}: **{vals}**")
    p = os.path.join(bdir, "fig5_6_arm_values.json")
    if os.path.exists(p):
        d = json.load(open(p))
        for ds, row in d.items():
            lines.append(f"- C5 (Figs 5/6, {ds}): spearman(arm values, "
                         f"standalone speedups)="
                         f"{row['spearman_values_vs_speedup']:.2f}, "
                         f"value spread={row['value_spread']:.3f}")
    lines.append("")
    lines.append("""Full tables: `artifacts/bench/*.json`. Scale note: the CPU
reproduction uses tiny trained analog pairs, the REAL paper pairs' FLOP
ratios for the cost model, gamma_max=16 as the proxy for the paper's 128,
and the paper's own tuning protocol (baselines grid-searched on the
Llama-1B/8B analog x SpecBench; TapOut pool calibrated by scale-free signal
quantiles, no performance feedback).

**Validation summary (honest read).**
- C1 (reward blending) reproduces cleanly in the pooled online setting:
  r_blend wins acceptance rate AND speedup, and r_simple over-speculates —
  the paper's Fig. 3/Table 2 story.
- C2 (UCB1 vs UCB-Tuned) does NOT reproduce at this scale: pooled UCB-Tuned
  edges out UCB1. The paper attributes UCB1's win to the LOW variance of the
  blended reward; with tiny char-level models the blend reward is
  substantially noisier, which by the paper's own variance argument favors
  UCB-Tuned — the MECHANISM (reward variance decides the winner) is
  consistent; the operating point differs.
- C3 (Seq-UCB1 top-2): partial. TapOut is consistently competitive and never
  catastrophic, but with only ~100 drafting sessions per run the bandit pays
  a visible exploration tax against grid-search-tuned single heuristics; the
  paper's runs give the bandit 1-2 orders of magnitude more sessions.
- C4 (entropy analysis): coding entropy < non-coding reproduces; the decay-
  with-position claim does not at char level (line-structured synthetic code
  has periodic entropy spikes at statement boundaries).
- C5 (interpretability): see the spearman(arm values, standalone speedups)
  numbers above — the ordering correspondence is the paper's Fig. 6 check.
- C6 (vs SpecDec++): reproduces — the training-free Seq-UCB1 beats the
  trained classifier transplanted to this scale.
- C7 (arm-pool ablation): see a2_more_arms above.""")
    return "\n".join(lines)


def serving_section() -> str:
    """Latest serving-bench trajectory from the committed
    ``BENCH_serving.json`` — reads ONLY the canonical row schema
    (``tokens_per_s`` keyed by B, the ``engine`` describe() blob, and the
    ``claim_*`` gates; docs/serving.md#canonical-stats)."""
    lines = ["## §Serving", ""]
    if not os.path.exists(BENCH_SERVING):
        lines.append("_no serving runs recorded yet_")
        return "\n".join(lines)
    try:
        runs = json.load(open(BENCH_SERVING)).get("runs", [])
    except (ValueError, OSError):
        runs = []
    latest = {}
    for r in runs:                      # last run per bench wins
        latest[r.get("bench", "?")] = r
    if not latest:
        lines.append("_no serving runs recorded yet_")
        return "\n".join(lines)
    lines += ["| bench | recorded | tokens/s by B | backend | gates |",
              "|---|---|---|---|---|"]
    for name in sorted(latest):
        r = latest[name]
        s = r.get("summary", {})
        tps = s.get("tokens_per_s", {})
        trend = "  ".join(f"{b}:{v:.1f}" for b, v in sorted(
            tps.items(), key=lambda kv: int(kv[0])))
        engines = s.get("engine", {})
        # ``engine`` is either ONE describe() blob or a dict of
        # per-config blobs (e.g. {"fifo": {...}, "slo": {...}})
        if isinstance(engines, dict) and "backend" in engines:
            any_engine = engines
        else:
            any_engine = next((v for v in engines.values()
                               if isinstance(v, dict)), {}) \
                if isinstance(engines, dict) else {}
        backend = any_engine.get("backend", "?")
        fused = any_engine.get("fused", "?")
        gates = ", ".join(f"{k.replace('claim_', '')}={v}"
                          for k, v in sorted(s.items())
                          if k.startswith("claim_"))
        lines.append(f"| {name} | {r.get('recorded_at', '?')} | {trend} | "
                     f"{backend} (fused={fused}) | {gates} |")
    lines.append("")
    tail = _slo_subsection(latest)
    if tail:
        lines += tail
    lines.append("(Full per-run rows, each stamped with the engine settings "
                 "that produced it, accumulate in `BENCH_serving.json` — its "
                 "git history is the cross-PR perf trajectory.)")
    return "\n".join(lines)


def _slo_subsection(latest: dict) -> list:
    """Queue-delay / SLO tails for rows that carry them (the open-loop
    ``slo_serving`` bench and any server stats recorded with the
    queue-delay satellites): goodput under p95-SLO per scheduler, p95
    queue delay, and per-priority latency tails."""
    lines = []
    for name in sorted(latest):
        s = latest[name].get("summary", {})
        good = s.get("goodput_tokens_per_tick")
        if isinstance(good, dict) and good:
            lines += [f"### {name}: goodput under p95 SLO", "",
                      "| scheduler | goodput tok/tick | slo met | "
                      "p95 queue delay (ticks) | preemptions |",
                      "|---|---|---|---|---|"]
            for sched in sorted(good):
                met = s.get("slo_met_frac", {}).get(sched, "?")
                qd = s.get("p95_queue_delay_ticks", {}).get(sched, "?")
                pre = s.get("preemption_events", {}).get(sched, "?")
                met = f"{met:.2f}" if isinstance(met, float) else met
                lines.append(f"| {sched} | {good[sched]:.2f} | {met} | "
                             f"{qd} | {pre} |")
            lines.append("")
        per_pri = s.get("per_priority")
        if isinstance(per_pri, dict) and per_pri:
            for sched in sorted(per_pri):
                classes = per_pri[sched]
                if (not isinstance(classes, dict) or not classes
                        or not all(isinstance(c, dict)
                                   for c in classes.values())):
                    continue
                row = "  ".join(
                    f"pri{p}: p95={c.get('p95_latency_s', 0):.3f}s "
                    f"(n={c.get('n_requests', '?')})"
                    for p, c in sorted(classes.items()))
                lines.append(f"- {name}/{sched} per-priority tails: {row}")
            lines.append("")
    return lines


def build(perf_md: str = "") -> str:
    parts = ["# EXPERIMENTS", "",
             "Generated by `python -m repro.analysis.report`. "
             "Paper: TapOut (bandit-based dynamic speculative decoding).", "",
             dryrun_section(), "", roofline_section(), "", bench_section(),
             "", serving_section()]
    if not perf_md:
        perf_path = os.path.join(ART, "perf_log.md")
        if os.path.exists(perf_path):
            perf_md = open(perf_path).read()
    parts += ["", perf_md or "## §Perf\n\n_(see artifacts/perf_log.md)_"]
    return "\n".join(parts)


def main():
    md = build()
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(md)
    print("wrote", out, len(md), "bytes")


if __name__ == "__main__":
    main()
