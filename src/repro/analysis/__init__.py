"""Analysis layer: roofline cost model + experiment-report generation.

``roofline`` turns a compiled dry-run's ``cost_analysis()`` + HLO text
into the three-term (compute / memory / collective) roofline used by
``launch/dryrun.py``; ``report`` renders EXPERIMENTS.md from the dry-run
and benchmark artifacts under ``artifacts/``.
"""
from repro.analysis.roofline import Roofline, build_roofline

__all__ = ["Roofline", "build_roofline"]
