"""Jitted speculative-decoding primitives: dynamic-stop drafting + parallel
verification with exact speculative sampling (Leviathan et al. 2023).

Device/host split (DESIGN.md §3): the drafting while-loop (with the stopping
heuristic evaluated via ``lax.switch`` on a traced arm index) and the
verification forward are single jitted programs; the bandit update and
sequence assembly run on host between sessions.

Cache invariant used throughout: ``cache["pos"] == len(generated_seq) - 1``
— the final token of the sequence has not been fed to the model yet.

Two entry points per primitive:

* ``draft_session`` / ``verify_session`` — the single-stream programs
  (leading dim B over LOCKSTEP rows sharing one cache position).
* ``draft_session_batched`` / ``verify_session_batched`` — ONE jitted
  program serving B independent streams at different sequence positions:
  the single-stream core is ``vmap``-ped over a leading stream axis
  (stacked caches carry per-stream ``pos``), with per-stream arm indices,
  per-stream RNG and a per-stream ``active`` mask.  Outputs of inactive
  (finished/empty) slots are zeroed on device so the host never has to
  special-case them; their cache lanes are reconciled by the engine's
  batched rollback.

And a third pair for the PAGED cache (``models/cache.py``):

* ``draft_session_paged`` / ``verify_session_paged`` — BATCH-NATIVE cores
  over the shared block pool.  vmap cannot serve here: every lane writes
  into ONE pool (its own pages), and per-lane functional updates of a
  shared buffer do not compose under vmap.  Instead the model step itself
  is batched (``transformer.paged_step``: per-stream positions via block
  tables + lengths), the per-stream arm dispatch evaluates every arm on
  the batch and selects per row (what vmap-of-``lax.switch`` lowers to
  anyway), and sampling uses per-row PRNG keys.  Inactive lanes are forced
  ``stopped`` from step 0 and their writes land in the reserved trash
  block, so a masked lane can never touch a neighbor's pages.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.cache import CacheSpec, paged_rollback, rollback
from repro.models.sharding import BATCH_AXES, constrain, resolve_spec
from .arms import Arm, SIGNAL_VECTOR_DIM, signal_vector, signals_from_probs

# static_argnames of the session primitives — shared with the per-engine
# re-jits below so an engine can rebuild a primitive without restating them
DRAFT_STATICS = ("cfg", "spec", "gamma_max", "temperature", "arms",
                 "n_prompt_tokens")
VERIFY_STATICS = ("cfg", "spec", "gamma_max", "temperature", "greedy")


def _lane_constrain(*arrays):
    """Pin the leading STREAM-LANE axis of flat (B, ...) session tensors to
    the ("pod","data") batch axes.  A no-op without an active mesh; under a
    mesh this keeps per-lane inputs/outputs resident with their lane's
    shard instead of letting GSPMD replicate them."""
    return tuple(constrain(a, BATCH_AXES) for a in arrays)


class DraftResult(NamedTuple):
    tokens: jnp.ndarray        # (B, gamma_max) int32 (padded with 0)
    n_drafted: jnp.ndarray     # (B,) int32
    qprobs: jnp.ndarray        # (B, gamma_max, V) draft distributions
    cache: dict                # draft cache AFTER drafting
    entropies: jnp.ndarray     # (B, gamma_max) sqrt-entropy per position (diag)
    signals: jnp.ndarray       # (B, gamma_max, 6) per-position signal vector


class VerifyResult(NamedTuple):
    n_accepted: jnp.ndarray    # (B,) accepted DRAFT tokens m <= n_drafted
    out_tokens: jnp.ndarray    # (B, gamma_max+1) accepted + replacement/bonus
    n_out: jnp.ndarray         # (B,) = m + 1
    cache: dict                # target cache AFTER verify forward (pos NOT rolled back)


def _sample(logits, rng, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature, axis=-1).astype(jnp.int32)


def _probs(logits, temperature: float):
    t = max(temperature, 1e-4)
    return jax.nn.softmax(logits.astype(jnp.float32) / t, axis=-1)


# ------------------------------------------------------------------ draft

def _run_draft_loop(step_fn, eval_stop, split_fn, sample_fn, cache,
                    in_tokens, rng, *, B: int, V: int, gamma_max: int,
                    temperature: float, force_stop=None):
    """THE dynamic-stop drafting loop, shared by every session flavor.

    The dense single-stream core, its vmapped batched wrapper and the
    batch-native paged core all run this exact body; they differ only in
    the injected callables:

      step_fn(tokens, cache) -> (logits, cache)     model advance
      eval_stop(i, sig_probs, prev_ent)
          -> (stop (B,), ent (B,), sigvec (B, 6))   arm dispatch
      split_fn(rng) -> (rng, key)                    PRNG split
      sample_fn(logits, key) -> (B,) int32           token sampling
      force_stop: (B,) bool — lanes forced stopped from step 0 (masked
          paged lanes; their writes land in the trash block).
    """
    # feed the known suffix; logits for the first drafted token
    logits, cache = step_fn(in_tokens, cache)
    rng, k0 = split_fn(rng)
    probs0 = _probs(logits[:, -1], temperature)
    sig_probs0 = _probs(logits[:, -1], 1.0)   # signals use the raw dist
    tok0 = sample_fn(logits[:, -1], k0)

    tokens_buf = jnp.zeros((B, gamma_max), jnp.int32)
    qprobs_buf = jnp.zeros((B, gamma_max, V), jnp.float32)
    ent_buf = jnp.zeros((B, gamma_max), jnp.float32)
    sig_buf = jnp.zeros((B, gamma_max, SIGNAL_VECTOR_DIM), jnp.float32)
    written = jnp.zeros((B, gamma_max), jnp.int32)

    stop0, ent0, sv0 = eval_stop(0, sig_probs0, jnp.zeros((B,), jnp.float32))
    if force_stop is not None:
        stop0 = stop0 | force_stop
    tokens_buf = tokens_buf.at[:, 0].set(tok0)
    qprobs_buf = qprobs_buf.at[:, 0].set(probs0)
    ent_buf = ent_buf.at[:, 0].set(ent0)
    sig_buf = sig_buf.at[:, 0].set(sv0)
    written = written.at[:, 0].set(1)

    def cond(state):
        i, _, _, _, _, stopped, _, _, _, _, _ = state
        return (i < gamma_max) & ~jnp.all(stopped)

    def body(state):
        i, tok, prev_ent, tbuf, qbuf, stopped, ebuf, sbuf, wrt, cache, rng = state
        logits, cache = step_fn(tok[:, None], cache)
        rng, k = split_fn(rng)
        probs = _probs(logits[:, -1], temperature)
        sig_probs = _probs(logits[:, -1], 1.0)
        nxt = sample_fn(logits[:, -1], k)
        stop_i, ent_i, sv_i = eval_stop(i, sig_probs, prev_ent)
        tbuf = tbuf.at[:, i].set(jnp.where(stopped, tbuf[:, i], nxt))
        qbuf = qbuf.at[:, i].set(jnp.where(stopped[:, None], qbuf[:, i], probs))
        ebuf = ebuf.at[:, i].set(jnp.where(stopped, ebuf[:, i], ent_i))
        sbuf = sbuf.at[:, i].set(jnp.where(stopped[:, None], sbuf[:, i], sv_i))
        wrt = wrt.at[:, i].set(jnp.where(stopped, wrt[:, i], 1))
        stopped = stopped | stop_i
        return (i + 1, nxt, ent_i, tbuf, qbuf, stopped, ebuf, sbuf, wrt, cache, rng)

    state = (jnp.int32(1), tok0, ent0, tokens_buf, qprobs_buf, stop0,
             ent_buf, sig_buf, written, cache, rng)
    _, _, _, tbuf, qbuf, _, ebuf, sbuf, wrt, cache, _ = jax.lax.while_loop(
        cond, body, state)

    n_drafted = jnp.sum(wrt, axis=1)
    return DraftResult(tbuf, n_drafted, qbuf, cache, ebuf, sbuf)


def _signals_with_diff_fix(sig_probs, prev_ent, lam, i):
    """Per-token signal dict; SVIP-Difference needs a previous step, so the
    diff is defined as 0 at i == 0."""
    sig = signals_from_probs(sig_probs, prev_ent, lam, i)
    sig["prev_sqrt_entropy"] = jnp.where(
        i == 0, sig["sqrt_entropy"], sig["prev_sqrt_entropy"])
    return sig


def _draft_core(params, cfg, spec: CacheSpec, cache, in_tokens, arm_per_pos,
                lam, rng, *, arms: Tuple[Arm, ...], gamma_max: int,
                temperature: float = 0.0):
    """Single-stream drafting core (traced; see ``draft_session`` for the
    jitted wrapper and ``draft_session_batched`` for the vmapped one).
    Arm dispatch is ``lax.switch`` on the (shared) per-position arm index."""
    arm_fns = tuple(a.fn for a in arms)

    def eval_stop(i, sig_probs, prev_ent):
        sig = _signals_with_diff_fix(sig_probs, prev_ent, lam, i)
        per_arm = jax.lax.switch(arm_per_pos[i],
                                 [lambda s=s: s(sig) for s in arm_fns])
        return per_arm, sig["sqrt_entropy"], signal_vector(sig)

    return _run_draft_loop(
        lambda toks, c: T.step(params, cfg, toks, c, spec),
        eval_stop,
        lambda r: tuple(jax.random.split(r)),
        lambda lg, k: _sample(lg, k, temperature),
        cache, in_tokens, rng, B=in_tokens.shape[0], V=cfg.vocab_size,
        gamma_max=gamma_max, temperature=temperature)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "spec", "gamma_max", "temperature", "arms",
                     "n_prompt_tokens"))
def draft_session(params, cfg, spec: CacheSpec, cache, in_tokens, arm_per_pos,
                  lam, rng, *, arms: Tuple[Arm, ...], gamma_max: int,
                  temperature: float = 0.0, n_prompt_tokens: int = 2):
    """Draft up to gamma_max tokens with bandit-selected dynamic stopping.

    in_tokens: (B, n_prompt_tokens) — the last token(s) of the accepted
      sequence (2 for pointer-rollback caches, 1 for recompute caches).
    arm_per_pos: (gamma_max,) int32 — arm index per draft position
      (sequence-level bandits broadcast one arm; token-level vary).
    lam: AdaEDL online threshold (scalar, host-updated between sessions).
    """
    return _draft_core(params, cfg, spec, cache, in_tokens, arm_per_pos, lam,
                       rng, arms=arms, gamma_max=gamma_max,
                       temperature=temperature)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "spec", "gamma_max", "temperature", "arms",
                     "n_prompt_tokens"))
def draft_session_batched(params, cfg, spec: CacheSpec, caches, in_tokens,
                          arm_mat, lam, rngs, active, *,
                          arms: Tuple[Arm, ...], gamma_max: int,
                          temperature: float = 0.0, n_prompt_tokens: int = 2):
    """One jitted program drafting for B independent streams.

    caches: pytree of per-stream caches stacked on a leading stream axis
      (each lane is a B=1 cache, so per-stream ``pos`` comes for free).
    in_tokens: (B, n_prompt_tokens); arm_mat: (B, gamma_max) PER-STREAM arm
      indices; rngs: (B, 2) per-stream PRNG keys; active: (B,) bool mask —
      outputs of inactive lanes are zeroed (n_drafted == 0).
    Returns DraftResult with tokens (B, gamma_max) padded to gamma_max.
    """
    in_tokens, arm_mat, rngs, active = _lane_constrain(in_tokens, arm_mat,
                                                       rngs, active)

    def lane(cache, toks, arm_row, rng):
        r = _draft_core(params, cfg, spec, cache, toks[None, :], arm_row,
                        lam, rng, arms=arms, gamma_max=gamma_max,
                        temperature=temperature)
        return DraftResult(r.tokens[0], r.n_drafted[0], r.qprobs[0], r.cache,
                           r.entropies[0], r.signals[0])

    r = jax.vmap(lane)(caches, in_tokens, arm_mat, rngs)
    n_drafted = jnp.where(active, r.n_drafted, 0)
    tokens = jnp.where(active[:, None], r.tokens, 0)
    tokens, n_drafted, qprobs, ent, sig = _lane_constrain(
        tokens, n_drafted, r.qprobs, r.entropies, r.signals)
    return DraftResult(tokens, n_drafted, qprobs, r.cache, ent, sig)


def _split_rows(rngs):
    """(B, 2) keys -> (next (B, 2), use (B, 2))."""
    ks = jax.vmap(jax.random.split)(rngs)
    return ks[:, 0], ks[:, 1]


def _sample_rows(logits, rngs, temperature: float):
    """Per-row sampling with per-row keys (matches the vmapped lanes)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.vmap(lambda lg, k: jax.random.categorical(
        k, lg / temperature, axis=-1))(logits, rngs).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "spec", "gamma_max", "temperature", "arms",
                     "n_prompt_tokens"))
def draft_session_paged(params, cfg, spec, cache, in_tokens, arm_mat, lam,
                        rngs, active, *, arms: Tuple[Arm, ...],
                        gamma_max: int, temperature: float = 0.0,
                        n_prompt_tokens: int = 2):
    """Batch-native drafting over the paged cache (see module docstring).

    cache: paged cache pytree ({"lengths", "tables", "layers"}); in_tokens:
    (B, n_prompt_tokens); arm_mat: (B, gamma_max); rngs: (B, 2); active:
    (B,) bool.  Semantics match ``draft_session_batched`` lane for lane:
    inactive rows leave with n_drafted == 0 and zeroed tokens.  Same loop
    body as the dense core (``_run_draft_loop``); per-stream arms evaluate
    every arm on the batch and select per row (what vmap-of-``lax.switch``
    lowers to anyway), sampling uses per-row PRNG keys.
    """
    B = in_tokens.shape[0]
    in_tokens, arm_mat, rngs, active = _lane_constrain(in_tokens, arm_mat,
                                                       rngs, active)
    arm_fns = tuple(a.fn for a in arms)
    rows = jnp.arange(B)

    def eval_stop(i, sig_probs, prev_ent):
        sig = _signals_with_diff_fix(sig_probs, prev_ent, lam, i)
        per_arm = jnp.stack([fn(sig) for fn in arm_fns])       # (A, B)
        arm_i = jax.lax.dynamic_index_in_dim(arm_mat, i, 1, keepdims=False)
        return per_arm[arm_i, rows], sig["sqrt_entropy"], signal_vector(sig)

    r = _run_draft_loop(
        lambda toks, c: T.paged_step(params, cfg, toks, c, spec),
        eval_stop,
        _split_rows,
        lambda lg, k: _sample_rows(lg, k, temperature),
        cache, in_tokens, rngs, B=B, V=cfg.vocab_size, gamma_max=gamma_max,
        temperature=temperature,
        force_stop=~active)               # masked lanes never draft on

    n_drafted = jnp.where(active, r.n_drafted, 0)
    tokens = jnp.where(active[:, None], r.tokens, 0)
    tokens, n_drafted, qprobs, ent, sig = _lane_constrain(
        tokens, n_drafted, r.qprobs, r.entropies, r.signals)
    return DraftResult(tokens, n_drafted, qprobs, r.cache, ent, sig)


# ------------------------------------------------------------------ verify

def _accept_and_outputs(logits, drafted, n_drafted, qprobs, rng, *,
                        gamma_max: int, temperature: float, greedy: bool,
                        split_fn, uniform_fn, categorical_fn):
    """THE chain accept-loop, shared by the dense and paged verifiers.

    logits (B, gamma+1, V) from the ``[last_token] + drafted`` feed —
    logits[:, j] is the target dist for drafted[:, j].  Greedy mode accepts
    while draft == target argmax; stochastic mode is exact speculative
    sampling — accept with prob min(1, p/q), resample the first rejection
    from norm(max(p - q, 0)).  PRNG handling is injected: the dense path
    splits one key, the paged path per-row key vectors — draw ORDER is
    identical so each flavor's stream is reproducible.

      split_fn(rng) -> (rng, key); uniform_fn(key) -> (B, gamma_max) in
      [0,1); categorical_fn(dist (B, V), key) -> (B,) int32 samples.

    Returns (m, out) — accepted length and the (B, gamma_max+1) output
    buffer holding accepted tokens + the replacement/bonus token at m.
    """
    B = drafted.shape[0]
    pprobs = _probs(logits, temperature)                        # (B, g+1, V)

    idx = jnp.arange(gamma_max)
    in_draft = idx[None, :] < n_drafted[:, None]                # (B, gamma)
    p_of_draft = jnp.take_along_axis(
        pprobs[:, :gamma_max], drafted[..., None], axis=-1)[..., 0]
    q_of_draft = jnp.take_along_axis(
        qprobs, drafted[..., None], axis=-1)[..., 0]

    if greedy:
        tgt_argmax = jnp.argmax(logits[:, :gamma_max], axis=-1).astype(jnp.int32)
        accept = (drafted == tgt_argmax) & in_draft
    else:
        rng, k_acc = split_fn(rng)
        u = uniform_fn(k_acc)
        ratio = p_of_draft / jnp.maximum(q_of_draft, 1e-20)
        accept = (u < jnp.minimum(ratio, 1.0)) & in_draft

    # m = accepted prefix length
    acc_prefix = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    m = jnp.sum(acc_prefix, axis=1)                             # (B,)

    # replacement token at position m: residual distribution if m < n_drafted,
    # otherwise the bonus token straight from the target dist.
    p_at_m = jnp.take_along_axis(pprobs, m[:, None, None], axis=1)[:, 0]  # (B,V)
    q_at_m = jnp.take_along_axis(
        jnp.concatenate([qprobs, jnp.zeros((B, 1, qprobs.shape[-1]))], axis=1),
        m[:, None, None], axis=1)[:, 0]
    rejected_inside = m < n_drafted
    if greedy:
        repl = jnp.argmax(p_at_m, axis=-1).astype(jnp.int32)
    else:
        resid = jnp.maximum(p_at_m - q_at_m, 0.0)
        resid_sum = resid.sum(-1, keepdims=True)
        resid = jnp.where(resid_sum > 1e-20, resid / jnp.maximum(resid_sum, 1e-20), p_at_m)
        dist = jnp.where(rejected_inside[:, None], resid, p_at_m)
        rng, k_r = split_fn(rng)
        repl = categorical_fn(dist, k_r)

    out = jnp.where(idx[None, :] < m[:, None], drafted, 0)
    out = jnp.concatenate([out, jnp.zeros((B, 1), jnp.int32)], axis=1)
    out = out.at[jnp.arange(B), m].set(repl)
    return m, out


def _verify_core(params, cfg, spec: CacheSpec, cache, last_token, drafted,
                 n_drafted, qprobs, rng, *, gamma_max: int,
                 temperature: float = 0.0, greedy: bool = True):
    """Single-stream verification core (traced; see ``verify_session``)."""
    B = last_token.shape[0]
    inp = jnp.concatenate([last_token, drafted], axis=1)       # (B, gamma+1)
    logits, cache = T.step(params, cfg, inp, cache, spec, all_logits=True)
    m, out = _accept_and_outputs(
        logits, drafted, n_drafted, qprobs, rng,
        gamma_max=gamma_max, temperature=temperature, greedy=greedy,
        split_fn=lambda r: tuple(jax.random.split(r)),
        uniform_fn=lambda k: jax.random.uniform(k, (B, gamma_max)),
        categorical_fn=lambda d, k: jax.random.categorical(
            k, jnp.log(jnp.maximum(d, 1e-30))).astype(jnp.int32))
    return VerifyResult(m, out, m + 1, cache)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "spec", "gamma_max", "temperature", "greedy"))
def verify_session(params, cfg, spec: CacheSpec, cache, last_token, drafted,
                   n_drafted, qprobs, rng, *, gamma_max: int,
                   temperature: float = 0.0, greedy: bool = True):
    """Verify drafted tokens with the target model in one forward pass.

    last_token: (B, 1) final accepted token (not yet fed to target).
    drafted: (B, gamma_max); n_drafted: (B,); qprobs: (B, gamma_max, V).

    Greedy mode: accept while draft token == target argmax. Stochastic mode:
    exact speculative sampling — accept with prob min(1, p/q), resample the
    first rejection from norm(max(p-q, 0)) so the output distribution equals
    the target model's.
    """
    return _verify_core(params, cfg, spec, cache, last_token, drafted,
                        n_drafted, qprobs, rng, gamma_max=gamma_max,
                        temperature=temperature, greedy=greedy)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "spec", "gamma_max", "temperature", "greedy"))
def verify_session_batched(params, cfg, spec: CacheSpec, caches, last_tokens,
                           drafted, n_drafted, qprobs, rngs, active, *,
                           gamma_max: int, temperature: float = 0.0,
                           greedy: bool = True):
    """One jitted program verifying B independent streams.

    caches: stacked per-stream target caches (leading stream axis);
    last_tokens: (B, 1); drafted: (B, gamma_max); n_drafted: (B,);
    qprobs: (B, gamma_max, V); rngs: (B, 2); active: (B,) bool.
    Inactive lanes come in with n_drafted == 0 and leave with
    n_accepted == n_out == 0 and zeroed out_tokens.
    """
    last_tokens, drafted, n_drafted, qprobs, rngs, active = _lane_constrain(
        last_tokens, drafted, n_drafted, qprobs, rngs, active)

    def lane(cache, last, drf, nd, qp, rng):
        r = _verify_core(params, cfg, spec, cache, last[None, :], drf[None],
                         nd[None], qp[None], rng, gamma_max=gamma_max,
                         temperature=temperature, greedy=greedy)
        return VerifyResult(r.n_accepted[0], r.out_tokens[0], r.n_out[0],
                            r.cache)

    r = jax.vmap(lane)(caches, last_tokens, drafted, n_drafted, qprobs, rngs)
    m = jnp.where(active, r.n_accepted, 0)
    out = jnp.where(active[:, None], r.out_tokens, 0)
    m, out, n_out = _lane_constrain(m, out, jnp.where(active, r.n_out, 0))
    return VerifyResult(m, out, n_out, r.cache)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "spec", "gamma_max", "temperature", "greedy"))
def verify_session_paged(params, cfg, spec, cache, last_tokens, drafted,
                         n_drafted, qprobs, rngs, active, *, gamma_max: int,
                         temperature: float = 0.0, greedy: bool = True):
    """Batch-native verification over the paged cache.

    One ``paged_step`` forward serves every stream at its own position;
    the accept-loop is the SAME ``_accept_and_outputs`` body as the dense
    verifier, with per-row PRNG keys injected.  Inactive lanes (n_drafted
    == 0) leave with zeroed outputs; their cache writes land in the trash
    block.
    """
    last_tokens, drafted, n_drafted, qprobs, rngs, active = _lane_constrain(
        last_tokens, drafted, n_drafted, qprobs, rngs, active)
    inp = jnp.concatenate([last_tokens, drafted], axis=1)       # (B, g+1)
    logits, cache = T.paged_step(params, cfg, inp, cache, spec, all_logits=True)
    m, out = _accept_and_outputs(
        logits, drafted, n_drafted, qprobs, rngs,
        gamma_max=gamma_max, temperature=temperature, greedy=greedy,
        split_fn=_split_rows,
        uniform_fn=jax.vmap(lambda k: jax.random.uniform(k, (gamma_max,))),
        categorical_fn=lambda d, k: jax.vmap(
            lambda d1, k1: jax.random.categorical(
                k1, jnp.log(jnp.maximum(d1, 1e-30))))(d, k).astype(jnp.int32))
    m = jnp.where(active, m, 0)
    out = jnp.where(active[:, None], out, 0)
    m, out, n_out = _lane_constrain(m, out, jnp.where(active, m + 1, 0))
    return VerifyResult(m, out, n_out, cache)


# ----------------------------------------------------------- chunk prefill

CHUNK_PREFILL_STATICS = ("cfg", "spec")


@functools.partial(jax.jit, static_argnames=CHUNK_PREFILL_STATICS)
def chunk_prefill_paged(params, cfg, spec, lane, tokens, n_valid):
    """Resumable chunk-prefill session over ONE paged lane view.

    Feeds a ``(1, C)`` token buffer whose first ``n_valid`` entries are
    real prompt tokens; any pad tail rides through the forward (causal
    attention keeps it invisible to the real tokens, and its pool writes
    land at positions the rollback marks dead inside the stream's own
    reserved pages) and is erased by an O(1) ``paged_rollback`` to
    ``start + n_valid``.  Position state lives entirely in the lane's
    ``lengths`` vector, so the program RESUMES AT ARBITRARY OFFSETS: a
    scheduler can interleave one bounded chunk per serving tick instead of
    stalling a tick on a full-prompt prefill, and every chunk of every
    prompt reuses one compiled shape per chunk width.  With ``n_valid ==
    C`` (no pads) the rollback is the identity length write, which is how
    the engines keep chunked and monolithic prefill BIT-IDENTICAL: both
    feed the same chunk schedule through this one program.
    """
    start = lane["lengths"]
    _, lane = T.paged_step(params, cfg, tokens, lane, spec)
    return paged_rollback(lane, start + jnp.asarray(n_valid, jnp.int32))


# ------------------------------------------------------------- sharded jits

def fresh_session_jits(*, paged: bool = False):
    """Per-engine re-jits of the single-stream (or paged batch-native)
    session primitives, with the same static argnames as the module-level
    ones.

    A mesh-aware engine must NOT share the module-level jits: the models'
    ``constrain`` annotations resolve against the mesh active at TRACE
    time, and a jit's trace cache is keyed on avals only — so one engine's
    meshless trace would be silently reused for another engine's sharded
    call (or a mesh-bound trace would poison a single-device engine).
    Giving each mesh-bound engine fresh jit objects keeps trace caches
    per-placement.
    """
    d = draft_session_paged if paged else draft_session
    v = verify_session_paged if paged else verify_session
    return (jax.jit(d.__wrapped__, static_argnames=DRAFT_STATICS),
            jax.jit(v.__wrapped__, static_argnames=VERIFY_STATICS))


def lane_sharding(mesh, shape) -> NamedSharding:
    """NamedSharding placing the leading stream-lane axis of ``shape`` on
    the ("pod","data") batch axes (indivisible axes drop per
    ``resolve_spec``, so B=1 / odd-B shapes degrade to replicated)."""
    return NamedSharding(mesh, resolve_spec(mesh, (BATCH_AXES,), shape))


def make_sharded_sessions(mesh, *, cfg_d, cfg_t, dspec, tspec, dparams_sh,
                          tparams_sh, dcache_sh, tcache_sh, batch_size: int,
                          gamma_max: int, arms: Tuple[Arm, ...],
                          temperature: float, greedy: bool,
                          n_prompt_tokens: int, paged: bool = False):
    """Jit the batched (or paged batch-native) draft/verify programs with
    explicit ``NamedSharding`` in/out shardings for one engine's
    (B, gamma_max) deployment on ``mesh``.

    Slot lanes — tokens, arm rows, PRNG keys, active masks, and every
    per-lane output — shard over the ("pod","data") batch axes; params and
    caches use the pytree shardings the engine placed them with
    (``launch/shardings.py``), so the compiled program never re-lays-out
    its resident state.  Returns ``(draft_fn, verify_fn)`` with the
    signatures of the module-level primitives minus the static arguments
    (closed over here).
    """
    B, g = batch_size, gamma_max
    rep = NamedSharding(mesh, P())
    lane = functools.partial(lane_sharding, mesh)
    draft_raw = (draft_session_paged if paged else
                 draft_session_batched).__wrapped__
    verify_raw = (verify_session_paged if paged else
                  verify_session_batched).__wrapped__

    def draft_fn(params, caches, in_tokens, arm_mat, lam, rngs, active):
        return draft_raw(params, cfg_d, dspec, caches, in_tokens, arm_mat,
                         lam, rngs, active, arms=arms, gamma_max=g,
                         temperature=temperature,
                         n_prompt_tokens=n_prompt_tokens)

    def verify_fn(params, caches, last_tokens, drafted, n_drafted, qprobs,
                  rngs, active):
        return verify_raw(params, cfg_t, tspec, caches, last_tokens, drafted,
                          n_drafted, qprobs, rngs, active, gamma_max=g,
                          temperature=temperature, greedy=greedy)

    V = cfg_d.vocab_size
    draft_jit = jax.jit(
        draft_fn,
        in_shardings=(dparams_sh, dcache_sh, lane((B, n_prompt_tokens)),
                      lane((B, g)), rep, lane((B, 2)), lane((B,))),
        out_shardings=DraftResult(
            lane((B, g)), lane((B,)), lane((B, g, V)), dcache_sh,
            lane((B, g)), lane((B, g, SIGNAL_VECTOR_DIM))))
    verify_jit = jax.jit(
        verify_fn,
        in_shardings=(tparams_sh, tcache_sh, lane((B, 1)), lane((B, g)),
                      lane((B,)), lane((B, g, V)), lane((B, 2)),
                      lane((B,))),
        out_shardings=VerifyResult(
            lane((B,)), lane((B, g + 1)), lane((B,)), tcache_sh))
    return draft_jit, verify_jit


# ------------------------------------------------------------- fused tick

class FusedTick(NamedTuple):
    """Device-resident outcome buffer of one fused serving tick.

    The host reads the integer/trace fields ONE STEP BEHIND (the engine's
    launch/flush split); the rolled-back caches feed the next tick without
    ever leaving the device."""
    n_drafted: jnp.ndarray     # (B,) int32
    n_accepted: jnp.ndarray    # (B,) int32
    out_tokens: jnp.ndarray    # (B, gamma_max+1) accepted + replacement/bonus
    entropies: jnp.ndarray     # (B, gamma_max) per-position sqrt-entropy
    signals: jnp.ndarray       # (B, gamma_max, 6) per-position signal vector
    dcache: dict               # draft cache AFTER output-side rollback
    tcache: dict               # target cache AFTER output-side rollback


FUSED_STATICS = ("cfg_d", "cfg_t", "dspec", "tspec", "arms", "gamma_max",
                 "temperature", "greedy", "n_prompt_tokens", "paged")


def _fused_tick_core(dparams, tparams, cfg_d, cfg_t, dspec: CacheSpec,
                     tspec: CacheSpec, dcaches, tcaches, in_tokens,
                     last_tokens, arm_mat, lam, drngs, vrngs, active,
                     lengths, dkeep, tkeep, *, arms: Tuple[Arm, ...],
                     gamma_max: int, temperature: float, greedy: bool,
                     n_prompt_tokens: int, paged: bool):
    """ONE device program per serving tick: input-side rollback -> draft
    while-loop -> verify forward -> accept -> output-side rollback.

    Calls the exact traced bodies of the synchronous primitives
    (``draft_session_batched`` / ``verify_session_batched`` or their paged
    twins), so per-lane arithmetic — and therefore every (n_drafted,
    n_accepted, out_tokens) outcome the bandit consumes — is the same
    computation the two-dispatch path runs; only the host round-trips
    between the stages disappear.

    lengths: (B,) int32 per-lane sequence lengths (len(seq));
    dkeep/tkeep: (B,) int32 cache pointers (dense) or lengths (paged) to
    KEEP for inactive lanes — the on-device analog of the engine's host
    mirrors.  Requires cheap-rollback caches on both models (the engine
    gates fusion on ``CacheSpec.cheap_rollback``)."""
    lengths, dkeep, tkeep = _lane_constrain(lengths, dkeep, tkeep)
    rb = paged_rollback if paged else rollback
    draft_raw = (draft_session_paged if paged else
                 draft_session_batched).__wrapped__
    verify_raw = (verify_session_paged if paged else
                  verify_session_batched).__wrapped__

    # input-side rollback: re-feed the last two accepted tokens
    dcaches_in = rb(dcaches, jnp.where(active, lengths - 2, dkeep))
    dres = draft_raw(dparams, cfg_d, dspec, dcaches_in, in_tokens, arm_mat,
                     lam, drngs, active, arms=arms, gamma_max=gamma_max,
                     temperature=temperature,
                     n_prompt_tokens=n_prompt_tokens)
    vres = verify_raw(tparams, cfg_t, tspec, tcaches, last_tokens,
                      dres.tokens, dres.n_drafted, dres.qprobs, vrngs,
                      active, gamma_max=gamma_max, temperature=temperature,
                      greedy=greedy)
    m = vres.n_accepted
    # output-side rollback (cache invariant: pos/length == len(seq) - 1 fed)
    tcache = rb(vres.cache, jnp.where(active, lengths + m, tkeep))
    dcache = rb(dres.cache, jnp.where(active, lengths + m - 1, dkeep))
    return FusedTick(dres.n_drafted, m, vres.out_tokens, dres.entropies,
                     dres.signals, dcache, tcache)


@functools.partial(jax.jit, static_argnames=FUSED_STATICS)
def fused_session_tick(dparams, tparams, cfg_d, cfg_t, dspec, tspec,
                       dcaches, tcaches, in_tokens, last_tokens, arm_mat,
                       lam, drngs, vrngs, active, lengths, dkeep, tkeep, *,
                       arms: Tuple[Arm, ...], gamma_max: int,
                       temperature: float = 0.0, greedy: bool = True,
                       n_prompt_tokens: int = 2, paged: bool = False):
    """Jitted fused serving tick (see ``_fused_tick_core``)."""
    return _fused_tick_core(dparams, tparams, cfg_d, cfg_t, dspec, tspec,
                            dcaches, tcaches, in_tokens, last_tokens,
                            arm_mat, lam, drngs, vrngs, active, lengths,
                            dkeep, tkeep, arms=arms, gamma_max=gamma_max,
                            temperature=temperature, greedy=greedy,
                            n_prompt_tokens=n_prompt_tokens, paged=paged)


def fresh_fused_jit():
    """Per-engine re-jit of ``fused_session_tick`` (same trace-cache
    hygiene as ``fresh_session_jits``)."""
    return jax.jit(fused_session_tick.__wrapped__,
                   static_argnames=FUSED_STATICS)


def make_sharded_fused(mesh, *, cfg_d, cfg_t, dspec, tspec, dparams_sh,
                       tparams_sh, dcache_sh, tcache_sh, batch_size: int,
                       gamma_max: int, arms: Tuple[Arm, ...],
                       temperature: float, greedy: bool,
                       n_prompt_tokens: int, paged: bool = False):
    """Jit the fused tick with explicit in/out shardings for one engine's
    deployment on ``mesh`` (``launch/shardings.fused_tick_shardings``):
    per-lane operands — tokens, arm rows, PRNG keys, the ragged length /
    keep-pointer vectors — shard over the ("pod","data") batch axes, params
    and caches keep their resident pytree shardings."""
    from repro.launch.shardings import fused_tick_shardings
    ins, outs = fused_tick_shardings(
        mesh, batch_size=batch_size, gamma_max=gamma_max,
        n_prompt_tokens=n_prompt_tokens, signal_dim=SIGNAL_VECTOR_DIM,
        dparams_sh=dparams_sh, tparams_sh=tparams_sh,
        dcache_sh=dcache_sh, tcache_sh=tcache_sh)

    def tick_fn(dparams, tparams, dcaches, tcaches, in_tokens, last_tokens,
                arm_mat, lam, drngs, vrngs, active, lengths, dkeep, tkeep):
        return _fused_tick_core(
            dparams, tparams, cfg_d, cfg_t, dspec, tspec, dcaches, tcaches,
            in_tokens, last_tokens, arm_mat, lam, drngs, vrngs, active,
            lengths, dkeep, tkeep, arms=arms, gamma_max=gamma_max,
            temperature=temperature, greedy=greedy,
            n_prompt_tokens=n_prompt_tokens, paged=paged)

    return jax.jit(tick_fn, in_shardings=ins,
                   out_shardings=FusedTick(**outs))
