"""Host-driven speculative-decoding generation engine.

Runs the draft/verify session loop around the jitted primitives in
``spec_decode.py``, maintains the cache invariants for both rollback
strategies (pointer rollback for attention/MLA caches, snapshot+recompute
for recurrent state), and reports the paper's metrics: accepted length m,
acceptance rate %, and speedup s (wall-clock and an analytic cost model —
CPU wall-clock is not TPU wall-clock, DESIGN.md §6).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.cache import rollback
from .controller import Controller
from .spec_decode import draft_session, verify_session


@dataclass
class ModelBundle:
    params: dict
    cfg: object
    # relative cost of one forward token (roofline-style: active params)
    cost_per_token: float = 0.0

    def __post_init__(self):
        if not self.cost_per_token:
            self.cost_per_token = float(self.cfg.active_param_count())


@dataclass
class SessionStats:
    n_drafted: int
    n_accepted: int
    arm: int


@dataclass
class GenResult:
    tokens: List[int]
    prompt_len: int
    sessions: List[SessionStats] = field(default_factory=list)
    wall_time_s: float = 0.0
    modeled_cost: float = 0.0
    traces: List[dict] = field(default_factory=list)

    @property
    def new_tokens(self) -> int:
        return len(self.tokens) - self.prompt_len

    @property
    def total_drafted(self) -> int:
        return sum(s.n_drafted for s in self.sessions)

    @property
    def total_accepted(self) -> int:
        return sum(s.n_accepted for s in self.sessions)

    @property
    def accept_rate(self) -> float:
        d = self.total_drafted
        return self.total_accepted / d if d else 0.0

    @property
    def mean_accepted(self) -> float:
        n = len(self.sessions)
        return self.total_accepted / n if n else 0.0


class SpecEngine:
    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *, max_len: int = 2048,
                 temperature: float = 0.0, greedy: bool = True,
                 cache_dtype=jnp.float32, seed: int = 0):
        self.draft, self.target = draft, target
        self.controller = controller
        self.gamma_max = controller.gamma_max
        self.max_len = max_len
        self.temperature = temperature
        self.greedy = greedy
        self.cache_dtype = cache_dtype
        self.rng = jax.random.PRNGKey(seed)
        self.collect_traces = False
        self._step_cache: Dict[tuple, callable] = {}
        _, self.dspec = T.init_cache(draft.cfg, 1, max_len, cache_dtype)
        _, self.tspec = T.init_cache(target.cfg, 1, max_len, cache_dtype)
        self.draft_cheap = self.dspec.cheap_rollback
        self.target_cheap = self.tspec.cheap_rollback

    # -------------------------------------------------------- helpers
    def _jit_step(self, which: str, length: int, all_logits: bool):
        key = (which, length, all_logits)
        if key not in self._step_cache:
            bundle = self.draft if which == "draft" else self.target
            spec = self.dspec if which == "draft" else self.tspec

            @jax.jit
            def fn(params, tokens, cache):
                return T.step(params, bundle.cfg, tokens, cache, spec,
                              all_logits=all_logits)
            self._step_cache[key] = fn
        return self._step_cache[key]

    def _advance(self, which: str, params, cache, tokens: np.ndarray):
        """Feed ``tokens`` (1, L) through the model, return new cache."""
        if tokens.shape[1] == 0:
            return cache
        fn = self._jit_step(which, tokens.shape[1], False)
        _, cache = fn(params, jnp.asarray(tokens, jnp.int32), cache)
        return cache

    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    # -------------------------------------------------------- streams
    def start_stream(self, prompt: List[int]) -> dict:
        """Prefill a new generation stream; returns the stream state."""
        assert len(prompt) >= 2, "need >= 2 prompt tokens"
        seq = list(prompt)
        res = GenResult(tokens=seq, prompt_len=len(prompt))
        dcache, _ = T.init_cache(self.draft.cfg, 1, self.max_len, self.cache_dtype)
        tcache, _ = T.init_cache(self.target.cfg, 1, self.max_len, self.cache_dtype)
        pre = np.asarray(seq[:-1], np.int32)[None]   # invariant pos = len-1
        dcache = self._advance("draft", self.draft.params, dcache, pre)
        tcache = self._advance("target", self.target.params, tcache, pre)
        return {"seq": seq, "res": res, "dcache": dcache, "tcache": tcache,
                "done": False}

    def session_step(self, state: dict, eos_id: Optional[int] = None) -> dict:
        """Run ONE draft/verify session on a stream (serving-layer unit)."""
        seq, res = state["seq"], state["res"]
        dcache, tcache = state["dcache"], state["tcache"]
        c_d = self.draft.cost_per_token
        c_t = self.target.cost_per_token
        if True:
            L = len(seq)
            arm_per_pos = self.controller.begin()
            gamma = len(arm_per_pos)

            # ---- draft
            if self.draft_cheap:
                dcache_in = rollback(dcache, L - 2)
                in_toks = jnp.asarray([seq[-2:]], jnp.int32)
                n_in = 2
            else:
                dcache_snapshot = dcache
                dcache_in = dcache
                in_toks = jnp.asarray([seq[-1:]], jnp.int32)
                n_in = 1
            dres = draft_session(
                self.draft.params, self.draft.cfg, self.dspec, dcache_in,
                in_toks, jnp.asarray(arm_per_pos), jnp.float32(self.controller.lam),
                self._next_rng(), arms=self.controller.arms, gamma_max=gamma,
                temperature=self.temperature, n_prompt_tokens=n_in)
            n_drafted = int(dres.n_drafted[0])

            # ---- verify
            if not self.target_cheap:
                tcache_snapshot = tcache
            vres = verify_session(
                self.target.params, self.target.cfg, self.tspec, tcache,
                jnp.asarray([seq[-1:]], jnp.int32)[:, 0:1], dres.tokens,
                dres.n_drafted, dres.qprobs, self._next_rng(),
                gamma_max=gamma, temperature=self.temperature,
                greedy=self.greedy)
            m = int(vres.n_accepted[0])
            out = np.asarray(vres.out_tokens[0, :m + 1]).tolist()

            # ---- cache maintenance (invariant: pos = len(seq)-1)
            accepted_feed = np.asarray([seq[-1:] + out[:-1]], np.int32)  # (1, m+1)
            seq.extend(out)
            if self.target_cheap:
                tcache = rollback(vres.cache, L + m)
            else:
                tcache = self._advance("target", self.target.params,
                                       tcache_snapshot, accepted_feed)
            if self.draft_cheap:
                dcache = rollback(dres.cache, L + m - 1)
            else:
                dcache = self._advance("draft", self.draft.params,
                                       dcache_snapshot, accepted_feed)

            # ---- controller update + accounting
            self.controller.update(arm_per_pos, n_drafted, m)
            res.sessions.append(SessionStats(n_drafted, m, int(arm_per_pos[0])))
            if self.collect_traces:
                res.traces.append({
                    "signals": np.asarray(dres.signals[0]),
                    "entropies": np.asarray(dres.entropies[0]),
                    "n_drafted": n_drafted, "n_accepted": m,
                    "position_base": 0})
            res.modeled_cost += n_drafted * c_d + c_t + (n_in - 1) * c_d
            if eos_id is not None and eos_id in out:
                seq[:] = seq[:len(seq) - len(out) + out.index(eos_id) + 1]
                state["done"] = True
            if len(seq) + gamma + 2 >= self.max_len:
                state["done"] = True

        state["dcache"], state["tcache"] = dcache, tcache
        return state

    # -------------------------------------------------------- generate
    def generate(self, prompt: List[int], max_new_tokens: int,
                 eos_id: Optional[int] = None) -> GenResult:
        t0 = time.perf_counter()
        state = self.start_stream(prompt)
        res = state["res"]
        while not state["done"] and res.new_tokens < max_new_tokens:
            state = self.session_step(state, eos_id)
        res.wall_time_s = time.perf_counter() - t0
        return res


def autoregressive_baseline_cost(n_tokens: int, target: ModelBundle) -> float:
    """Modeled cost of plain target-only decoding."""
    return n_tokens * target.cost_per_token
