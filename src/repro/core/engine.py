"""Host-driven speculative-decoding generation engine.

Runs the draft/verify session loop around the jitted primitives in
``spec_decode.py``, maintains the cache invariants for both rollback
strategies (pointer rollback for attention/MLA caches, snapshot+recompute
for recurrent state), and reports the paper's metrics: accepted length m,
acceptance rate %, and speedup s (wall-clock and an analytic cost model —
CPU wall-clock is not TPU wall-clock, DESIGN.md §6).
"""
from __future__ import annotations

import contextlib
import functools
import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.cache import (POOL_LEAF_KEYS, BlockAllocator,
                                EncoderSegmentPool, PoolExhausted,
                                PrefixCache, paged_copy_block, paged_rollback,
                                rollback)
from repro.models.quant import quantize_params
from repro.models.sharding import use_mesh
from .controller import Controller, TapOutTreeSequence
from .rewards import (modeled_session_cost, moe_routed_frac,
                      precision_cost_factor)
from .spec_decode import (_probs, chunk_prefill_paged, draft_session,
                          draft_session_batched, draft_session_paged,
                          fresh_session_jits, fused_session_tick,
                          make_sharded_fused, make_sharded_sessions,
                          verify_session, verify_session_batched,
                          verify_session_paged)
from .tree import TreeSpec, verify_walk


def _on_mesh(fn):
    """Run an engine method with the engine's mesh active, so every program
    traced inside it resolves its ``constrain`` annotations against that
    mesh (a no-op for meshless engines)."""
    @functools.wraps(fn)
    def inner(self, *args, **kwargs):
        with self._mesh_ctx():
            return fn(self, *args, **kwargs)
    return inner


class _ShardingMixin:
    """Device-placement plumbing shared by every engine.

    ``mesh=None`` (the default) leaves everything exactly as before: one
    device, module-level jitted primitives, no placement.  With a mesh the
    engine places its params (serve-mode rules: weights resident, "model"
    tensor-parallel only — see ``launch/shardings.py``) and its caches at
    init, and every computation downstream of those committed arrays runs
    on the mesh's device set.  The bandit controller needs none of this:
    it is host-side O(arms) state fed by order-independent observation
    merges, so the SAME controller code serves 1 device or 512.
    """

    mesh = None
    backend_name = "single"

    def describe(self) -> dict:
        """Canonical description of this engine's deployment settings —
        the single schema benchmarks and ``SpecServer.throughput_stats``
        attach to every row they emit (docs/serving.md)."""
        d = {
            "backend": self.backend_name,
            "batch_size": int(getattr(self, "batch_size", 1)),
            "max_len": int(self.max_len),
            "gamma_max": int(self.gamma_max),
            "temperature": float(self.temperature),
            "greedy": bool(self.greedy),
            "kv_dtype": self.kv_dtype or "fp",
            "fused": bool(getattr(self, "fused", False)),
            "devices": (int(self.mesh.devices.size)
                        if self.mesh is not None else 1),
            "mesh_axes": ({k: int(v) for k, v in self.mesh.shape.items()}
                          if self.mesh is not None else None),
        }
        d["drafter"] = self._drafter_blob()
        rf = float(getattr(self, "_routed_frac", 0.0))
        if rf > 0.0:
            n = int(getattr(self, "_moe_sessions", 0))
            m = self.target.cfg.moe
            d["moe"] = {
                "routed_frac": rf,
                "top_k": int(m.top_k),
                "num_experts": int(m.num_experts),
                "sessions": n,
                "mean_routing_density": (float(self._moe_density_sum / n)
                                         if n else 1.0),
            }
        return d

    def _init_moe_accounting(self):
        """Routed-cost accounting state for MoE targets: ``_routed_frac``
        is the share of the target's active per-token parameters that are
        routed experts (0 for dense targets — every read is gated on it),
        the density sum/count feed ``describe()["moe"]``."""
        self._routed_frac = moe_routed_frac(self.target.cfg)
        self._moe_density_sum = 0.0
        self._moe_sessions = 0

    def _routing_density_rows(self, tcache) -> np.ndarray:
        """Per-lane routing density of the verify chunk just fed: the
        cache's ``moe_stats`` channel (mean distinct experts hit per routed
        layer) over ``top_k``.  One decode token gives exactly 1.0; a
        gamma-token verify PHYSICALLY streams up to gamma * top_k distinct
        experts' weights, so density > 1 raises the routed share of the
        modeled verify cost (``rewards.modeled_session_cost``) — the
        workload axis the bandit's cost-adjusted reward learns from."""
        k = max(int(self.target.cfg.moe.top_k), 1)
        return np.asarray(tcache["moe_stats"], np.float64) / k

    def _drafter_blob(self) -> dict:
        """Drafter identity, stamped into every describe()/bench row: which
        model drafts (name + kind) and — when the engine serves a
        heterogeneous ``DrafterPool`` — the full pool (names, kinds,
        relative costs, per-stream state bytes)."""
        cfg = self.draft.cfg
        blob = {"name": cfg.name,
                "kind": "ssd" if cfg.is_attention_free else "kv",
                "pool": None}
        pool = getattr(self, "drafters", None)
        if pool is not None:
            blob["name"] = pool.default
            blob["kind"] = pool.kind(pool.default)
            blob["pool"] = pool.describe(int(self.max_len),
                                         kv_dtype=self.kv_dtype)
        return blob

    def _mesh_ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        return use_mesh(self.mesh)

    def _meshless_fused(self, *, paged: bool, draft: "ModelBundle" = None,
                        dspec=None):
        """Bind this engine's statics onto the module-level fused-tick jit
        (meshless engines share its trace cache, exactly like the
        synchronous session primitives).  ``draft``/``dspec`` override the
        draft-side statics for drafter-pool engines: each drafter gets its
        own entry in the SAME module-level trace cache, so switching
        drafters between ticks after warmup never re-traces."""
        draft = draft or self.draft
        statics = dict(cfg_d=draft.cfg, cfg_t=self.target.cfg,
                       dspec=dspec or self.dspec, tspec=self.tspec,
                       arms=self.controller.arms, gamma_max=self.gamma_max,
                       temperature=self.temperature, greedy=self.greedy,
                       n_prompt_tokens=2, paged=paged)

        def tick(dparams, tparams, dcaches, tcaches, in_tokens, last_tokens,
                 arm_mat, lam, drngs, vrngs, active, lengths, dkeep, tkeep):
            return fused_session_tick(
                dparams, tparams, dcaches=dcaches, tcaches=tcaches,
                in_tokens=in_tokens, last_tokens=last_tokens,
                arm_mat=arm_mat, lam=lam, drngs=drngs, vrngs=vrngs,
                active=active, lengths=lengths, dkeep=dkeep, tkeep=tkeep,
                **statics)
        return tick

    def _place_bundles(self):
        """Shard draft/target params over the mesh (serve-mode rules);
        keeps the sharding pytrees for the session programs' in_shardings."""
        self._dparams_sh = self._tparams_sh = None
        if self.mesh is None:
            return
        from repro.launch.shardings import params_shardings
        self._dparams_sh = params_shardings(self.mesh, self.draft.params,
                                            mode="serve")
        self._tparams_sh = params_shardings(self.mesh, self.target.params,
                                            mode="serve")
        self.draft = ModelBundle(
            jax.device_put(self.draft.params, self._dparams_sh),
            self.draft.cfg, cost_per_token=self.draft.cost_per_token)
        self.target = ModelBundle(
            jax.device_put(self.target.params, self._tparams_sh),
            self.target.cfg, cost_per_token=self.target.cost_per_token)

    def _place_variant(self, bundle: "ModelBundle") -> "ModelBundle":
        """Shard an extra weight variant (e.g. an int8 draft copy)."""
        if self.mesh is None:
            return bundle
        from repro.launch.shardings import params_shardings
        sh = params_shardings(self.mesh, bundle.params, mode="serve")
        return ModelBundle(jax.device_put(bundle.params, sh), bundle.cfg,
                           cost_per_token=bundle.cost_per_token)

    def _place_cache(self, cache, *, paged: bool = False, slots: bool = False):
        """Place a cache pytree per the launch-layer rules (dense B=1,
        slot-stacked, or paged-pool layout).  The sharding pytree is
        memoized per layout — this runs on the serving hot path (admission,
        release, canonical re-pinning after lane writes) and an engine's
        cache structure never changes after init."""
        if self.mesh is None:
            return cache
        # treedef + leaf shapes in the key: one engine places draft AND
        # target caches (different structures/dims) through the same
        # layout flags, and resolve_spec decisions depend on shapes
        flat, treedef = jax.tree_util.tree_flatten(cache)
        key = (paged, slots, treedef, tuple(a.shape for a in flat))
        shardings = getattr(self, "_cache_sh", None)
        if shardings is None:
            shardings = self._cache_sh = {}
        if key not in shardings:
            from repro.launch.shardings import (cache_shardings,
                                                paged_cache_shardings,
                                                slot_cache_shardings)
            sh_fn = (paged_cache_shardings if paged
                     else slot_cache_shardings if slots else cache_shardings)
            shardings[key] = sh_fn(self.mesh, cache)
        return jax.device_put(cache, shardings[key])


@dataclass
class ModelBundle:
    params: dict
    cfg: object
    # relative cost of one forward token (roofline-style: active params)
    cost_per_token: float = 0.0

    def __post_init__(self):
        if not self.cost_per_token:
            self.cost_per_token = float(self.cfg.active_param_count())


def quantized_bundle(bundle: ModelBundle) -> ModelBundle:
    """An int8-weight copy of a bundle: params quantized once
    (``models/quant.py``), modeled per-token cost scaled by the int8
    precision factor (memory-bound decode streams ~half the bytes)."""
    return ModelBundle(quantize_params(bundle.params), bundle.cfg,
                       cost_per_token=bundle.cost_per_token
                       * precision_cost_factor("int8"))


@dataclass
class SessionStats:
    n_drafted: int
    n_accepted: int
    arm: int


@dataclass
class GenResult:
    tokens: List[int]
    prompt_len: int
    sessions: List[SessionStats] = field(default_factory=list)
    wall_time_s: float = 0.0
    modeled_cost: float = 0.0
    traces: List[dict] = field(default_factory=list)

    @property
    def new_tokens(self) -> int:
        return len(self.tokens) - self.prompt_len

    @property
    def total_drafted(self) -> int:
        return sum(s.n_drafted for s in self.sessions)

    @property
    def total_accepted(self) -> int:
        return sum(s.n_accepted for s in self.sessions)

    @property
    def accept_rate(self) -> float:
        d = self.total_drafted
        return self.total_accepted / d if d else 0.0

    @property
    def mean_accepted(self) -> float:
        n = len(self.sessions)
        return self.total_accepted / n if n else 0.0

    # canonical name shared with the serving/bench schema: accepted tokens
    # per verify pass (every session runs exactly one verify forward)
    accepted_per_verify = mean_accepted


class _StepMixin:
    """Shared cache-advance plumbing for the single-stream and batched
    engines (both expose .draft/.target bundles and .dspec/.tspec)."""

    def _jit_step(self, which: str, length: int, all_logits: bool = False):
        key = (which, length, all_logits)
        if key not in self._step_cache:
            bundle = self.draft if which == "draft" else self.target
            spec = self.dspec if which == "draft" else self.tspec

            @jax.jit
            def fn(params, tokens, cache):
                return T.step(params, bundle.cfg, tokens, cache, spec,
                              all_logits=all_logits)
            self._step_cache[key] = fn
        return self._step_cache[key]

    def _advance(self, which: str, params, cache, tokens: np.ndarray):
        """Feed ``tokens`` (1, L) through the model, return new cache."""
        if tokens.shape[1] == 0:
            return cache
        fn = self._jit_step(which, tokens.shape[1])
        _, cache = fn(params, jnp.asarray(tokens, jnp.int32), cache)
        return cache

    def _jit_step_for(self, tag: str, bundle: "ModelBundle", spec,
                      length: int):
        """Like ``_jit_step`` but for an arbitrary (tagged) bundle — the
        per-drafter catch-up feeds of the drafter-pool engine.  Keyed by
        (tag, length) in the same per-engine cache."""
        key = (tag, length, False)
        if key not in self._step_cache:
            @jax.jit
            def fn(params, tokens, cache):
                return T.step(params, bundle.cfg, tokens, cache, spec)
            self._step_cache[key] = fn
        return self._step_cache[key]

    def _advance_with(self, tag: str, bundle: "ModelBundle", spec, cache,
                      tokens: np.ndarray):
        """Feed ``tokens`` (1, L) through a tagged bundle's model."""
        if tokens.shape[1] == 0:
            return cache
        fn = self._jit_step_for(tag, bundle, spec, tokens.shape[1])
        _, cache = fn(bundle.params, jnp.asarray(tokens, jnp.int32), cache)
        return cache

    def jit_cache_sizes(self) -> dict:
        """Trace-cache entry counts of every program this engine's ticks
        can populate — the zero-retrace-after-warmup assertion surface
        (tests/test_drafters.py): warm the engine, snapshot, keep serving
        with drafter switches, assert unchanged."""
        def n(fn):
            try:
                return int(fn._cache_size())
            except Exception:
                return -1
        return {"fused_tick": n(fused_session_tick),
                "draft_batched": n(draft_session_batched),
                "verify_batched": n(verify_session_batched),
                "step_cache": len(self._step_cache)}


class SpecEngine(_StepMixin, _ShardingMixin):
    """Single-stream engine.  ``kv_dtype="int8"`` stores both models' KV
    caches quantized (``models/quant.py``); ``quant_draft=True`` swaps the
    draft bundle for an int8-weight copy with the precision-scaled modeled
    cost; ``mesh=`` places params and caches across devices
    (docs/sharding.md) — the batched/paged/tree engines take the same
    knobs."""

    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *, max_len: int = 2048,
                 temperature: float = 0.0, greedy: bool = True,
                 cache_dtype=jnp.float32, kv_dtype: Optional[str] = None,
                 quant_draft: bool = False, seed: int = 0, mesh=None):
        if quant_draft:
            draft = quantized_bundle(draft)
        self.draft, self.target = draft, target
        self.mesh = mesh
        self._place_bundles()
        self._draft_session, self._verify_session = (
            (draft_session, verify_session) if mesh is None
            else fresh_session_jits())
        self.controller = controller
        self.gamma_max = controller.gamma_max
        self.max_len = max_len
        self.temperature = temperature
        self.greedy = greedy
        self.cache_dtype = cache_dtype
        self.kv_dtype = kv_dtype
        self.rng = jax.random.PRNGKey(seed)
        self.collect_traces = False
        self._step_cache: Dict[tuple, callable] = {}
        _, self.dspec = T.init_cache(draft.cfg, 1, max_len, cache_dtype,
                                     kv_dtype=kv_dtype)
        _, self.tspec = T.init_cache(target.cfg, 1, max_len, cache_dtype,
                                     kv_dtype=kv_dtype)
        self.draft_cheap = self.dspec.cheap_rollback
        self.target_cheap = self.tspec.cheap_rollback
        self._init_moe_accounting()

    # -------------------------------------------------------- helpers
    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    # -------------------------------------------------------- streams
    @_on_mesh
    def start_stream(self, prompt: List[int], *, frame_embeds=None,
                     patch_embeds=None) -> dict:
        """Prefill a new generation stream; returns the stream state.

        Conditioning (target-side only — the draft stays a text-only
        decoder, which greedy speculative decoding keeps output-exact):

          * ``frame_embeds`` (T, frontend_dim) — enc-dec targets encode it
            once and cache the per-layer cross-KV inside ``tcache`` (the
            jitted sessions thread it untouched, so nothing downstream
            changes);
          * ``patch_embeds`` (P, vit_dim) — vision targets prepend P
            projected patch positions before the prompt, so every TARGET
            cache position is offset by ``toff = P`` from ``len(seq)``;
            the session's target rollbacks carry that offset.
        """
        assert len(prompt) >= 2, "need >= 2 prompt tokens"
        seq = list(prompt)
        res = GenResult(tokens=seq, prompt_len=len(prompt))
        dcache, _ = T.init_cache(self.draft.cfg, 1, self.max_len,
                                 self.cache_dtype, kv_dtype=self.kv_dtype)
        tcache, _ = T.init_cache(self.target.cfg, 1, self.max_len,
                                 self.cache_dtype, kv_dtype=self.kv_dtype)
        dcache = self._place_cache(dcache)
        tcache = self._place_cache(tcache)
        pre = np.asarray(seq[:-1], np.int32)[None]   # invariant pos = len-1
        dcache = self._advance("draft", self.draft.params, dcache, pre)
        toff = 0
        if frame_embeds is not None or patch_embeds is not None:
            fe = pe = None
            if frame_embeds is not None:
                fe = jnp.asarray(frame_embeds)
                fe = fe[None] if fe.ndim == 2 else fe
            if patch_embeds is not None:
                pe = jnp.asarray(patch_embeds)
                pe = pe[None] if pe.ndim == 2 else pe
                toff = int(pe.shape[1])
            assert len(prompt) + self.gamma_max + 2 + toff <= self.max_len, \
                "prompt + patches cannot fit a session within max_len"
            # one conditioned prefill feed (once per stream — traced per
            # prompt shape like the plain _advance path)
            _, tcache = T.step(self.target.params, self.target.cfg,
                               jnp.asarray(pre, jnp.int32), tcache,
                               self.tspec, frame_embeds=fe, patch_embeds=pe)
        else:
            tcache = self._advance("target", self.target.params, tcache, pre)
        return {"seq": seq, "res": res, "dcache": dcache, "tcache": tcache,
                "toff": toff, "done": False}

    @_on_mesh
    def session_step(self, state: dict, eos_id: Optional[int] = None) -> dict:
        """Run ONE draft/verify session on a stream (serving-layer unit)."""
        seq, res = state["seq"], state["res"]
        dcache, tcache = state["dcache"], state["tcache"]
        toff = int(state.get("toff", 0))     # target-only position offset
        c_d = self.draft.cost_per_token
        c_t = self.target.cost_per_token
        if True:
            L = len(seq)
            arm_per_pos = self.controller.begin()
            gamma = len(arm_per_pos)

            # ---- draft
            if self.draft_cheap:
                dcache_in = rollback(dcache, L - 2)
                in_toks = jnp.asarray([seq[-2:]], jnp.int32)
                n_in = 2
            else:
                dcache_snapshot = dcache
                dcache_in = dcache
                in_toks = jnp.asarray([seq[-1:]], jnp.int32)
                n_in = 1
            dres = self._draft_session(
                self.draft.params, self.draft.cfg, self.dspec, dcache_in,
                in_toks, jnp.asarray(arm_per_pos), jnp.float32(self.controller.lam),
                self._next_rng(), arms=self.controller.arms, gamma_max=gamma,
                temperature=self.temperature, n_prompt_tokens=n_in)
            n_drafted = int(dres.n_drafted[0])

            # ---- verify
            if not self.target_cheap:
                tcache_snapshot = tcache
            vres = self._verify_session(
                self.target.params, self.target.cfg, self.tspec, tcache,
                jnp.asarray([seq[-1:]], jnp.int32)[:, 0:1], dres.tokens,
                dres.n_drafted, dres.qprobs, self._next_rng(),
                gamma_max=gamma, temperature=self.temperature,
                greedy=self.greedy)
            m = int(vres.n_accepted[0])
            out = np.asarray(vres.out_tokens[0, :m + 1]).tolist()

            # ---- cache maintenance (invariant: pos = len(seq)-1)
            accepted_feed = np.asarray([seq[-1:] + out[:-1]], np.int32)  # (1, m+1)
            seq.extend(out)
            if self.target_cheap:
                tcache = rollback(vres.cache, L + m + toff)
            else:
                tcache = self._advance("target", self.target.params,
                                       tcache_snapshot, accepted_feed)
            if self.draft_cheap:
                dcache = rollback(dres.cache, L + m - 1)
            else:
                dcache = self._advance("draft", self.draft.params,
                                       dcache_snapshot, accepted_feed)

            # ---- controller update + accounting
            self.controller.update(arm_per_pos, n_drafted, m)
            res.sessions.append(SessionStats(n_drafted, m, int(arm_per_pos[0])))
            if self.collect_traces:
                res.traces.append({
                    "signals": np.asarray(dres.signals[0]),
                    "entropies": np.asarray(dres.entropies[0]),
                    "n_drafted": n_drafted, "n_accepted": m,
                    "position_base": 0})
            density = 1.0
            if self._routed_frac > 0.0:
                density = float(self._routing_density_rows(vres.cache)[0])
                self._moe_density_sum += density
                self._moe_sessions += 1
            res.modeled_cost += modeled_session_cost(
                n_drafted + n_in - 1, c_d, c_t,
                routed_frac=self._routed_frac, routing_density=density)
            if eos_id is not None and eos_id in out:
                seq[:] = seq[:len(seq) - len(out) + out.index(eos_id) + 1]
                state["done"] = True
            if len(seq) + gamma + 2 + toff >= self.max_len:
                state["done"] = True

        state["dcache"], state["tcache"] = dcache, tcache
        return state

    # -------------------------------------------------------- generate
    def generate(self, prompt: List[int], max_new_tokens: int,
                 eos_id: Optional[int] = None, *, frame_embeds=None,
                 patch_embeds=None) -> GenResult:
        t0 = time.perf_counter()
        state = self.start_stream(prompt, frame_embeds=frame_embeds,
                                  patch_embeds=patch_embeds)
        res = state["res"]
        while not state["done"] and res.new_tokens < max_new_tokens:
            state = self.session_step(state, eos_id)
        res.wall_time_s = time.perf_counter() - t0
        return res


def autoregressive_baseline_cost(n_tokens: int, target: ModelBundle) -> float:
    """Modeled cost of plain target-only decoding."""
    return n_tokens * target.cost_per_token


# ===================================================================== tree

@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _tree_forward(params, cfg, spec, cache, tokens, depths, mask, nodes):
    return T.tree_step(params, cfg, tokens, cache, spec, depths, mask, nodes)


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _tree_commit(cfg, spec, cache, nodes, path, n_commit):
    return T.commit_tree_path(cfg, cache, spec, nodes, path, n_commit)


class TreeSpecEngine(_StepMixin, _ShardingMixin):
    """Host-driven engine whose speculation step can be a TREE.

    The controller (``TapOutTreeSequence``) picks a speculation SHAPE per
    session: a chain + stop rule (the existing jitted chain primitives run
    unchanged) or a static ``TreeSpec`` topology.  A tree session:

      1. refeeds the sequence suffix through the draft model (the chain
         path's cache invariant), then expands the tree LEVEL BY LEVEL —
         each level is one jitted ``tree_step`` whose nodes attend the
         cache plus their carried ancestors under the ancestor mask; child
         tokens come from the parent's predictive distribution (top-k in
         greedy mode, i.i.d. samples in stochastic mode);
      2. verifies the whole tree in ONE target forward: the verify feed is
         ``[last committed token] + nodes`` (so the root distribution rides
         along exactly like the chain verifier's last-token feed);
      3. walks the LONGEST ACCEPTED PATH (``tree.verify_walk``) — greedy
         argmax matching, or SpecInfer-style recursive rejection with
         residual-distribution sampling at the divergence node;
      4. commits ONLY the accepted path: ``commit_tree_path`` scatters the
         path's K/V rows into the (dense or paged) cache and the usual
         O(1) pointer / length-truncation rollback does the rest.  Neither
         drafting nor verification ever writes an uncommitted row.

    Works on dense caches and (``paged=True``) on B=1 paged caches whose
    single stream owns the whole pool.  Requires attention/MLA-only stacks
    (recurrent state cannot fork per branch) with non-ring buffers.
    """

    backend_name = "tree"

    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: TapOutTreeSequence, *, max_len: int = 2048,
                 temperature: float = 0.0, greedy: bool = True,
                 cache_dtype=jnp.float32, kv_dtype: Optional[str] = None,
                 quant_draft: bool = False, seed: int = 0,
                 paged: bool = False, block_size: int = 64, mesh=None):
        if quant_draft:
            draft = quantized_bundle(draft)
        self.draft, self.target = draft, target
        self.mesh = mesh
        self._place_bundles()
        # precision arms (ShapeArm.precision == "int8") draft with a
        # quantized copy of the SAME draft weights — quantize once here,
        # the shape bandit then picks precision per session like any arm
        self._draft_variants: Dict[str, ModelBundle] = {}
        if (not quant_draft
                and any(s.precision == "int8" for s in controller.shapes)):
            self._draft_variants["int8"] = self._place_variant(
                quantized_bundle(self.draft))
        # per-engine jits when a mesh is bound (see fresh_session_jits)
        if mesh is None:
            self._tree_fwd, self._tree_cmt = _tree_forward, _tree_commit
            self._draft_chain, self._verify_chain = (
                (draft_session_paged, verify_session_paged) if paged
                else (draft_session, verify_session))
        else:
            self._tree_fwd = jax.jit(_tree_forward.__wrapped__,
                                     static_argnames=("cfg", "spec"))
            self._tree_cmt = jax.jit(_tree_commit.__wrapped__,
                                     static_argnames=("cfg", "spec"))
            self._draft_chain, self._verify_chain = fresh_session_jits(
                paged=paged)
        self.controller = controller
        self.gamma_max = controller.gamma_max
        self.max_len = max_len
        self.temperature = temperature
        self.greedy = greedy
        self.cache_dtype = cache_dtype
        self.kv_dtype = kv_dtype
        self.paged = paged
        self.block_size = block_size
        self.rng = jax.random.PRNGKey(seed)
        self._host_rng = np.random.default_rng(seed)
        self.collect_traces = False
        self._step_cache: Dict[tuple, callable] = {}
        if paged:
            _, self.dspec = T.init_paged_cache(
                draft.cfg, 1, max_len, block_size=block_size,
                pool_tokens=max_len, dtype=cache_dtype, kv_dtype=kv_dtype)
            _, self.tspec = T.init_paged_cache(
                target.cfg, 1, max_len, block_size=block_size,
                pool_tokens=max_len, dtype=cache_dtype, kv_dtype=kv_dtype)
        else:
            _, self.dspec = T.init_cache(draft.cfg, 1, max_len, cache_dtype,
                                         kv_dtype=kv_dtype)
            _, self.tspec = T.init_cache(target.cfg, 1, max_len, cache_dtype,
                                         kv_dtype=kv_dtype)
        for spec, cfg in ((self.dspec, draft.cfg), (self.tspec, target.cfg)):
            assert spec.cheap_rollback, \
                "tree speculation requires attn/mla-only stacks"
            assert all(not l.ring for l in spec.layers), \
                "tree speculation requires non-ring caches (max_len within " \
                "the full-cache budget)"
        self._max_overshoot = max(
            self.gamma_max,
            max((s.tree.max_depth + 1 for s in controller.shapes
                 if s.kind == "tree"), default=0))

    # -------------------------------------------------------- plumbing
    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    def _draft_bundle(self, shape) -> ModelBundle:
        """The draft weights a shape arm runs with (its precision axis)."""
        return self._draft_variants.get(shape.precision, self.draft)

    def _fresh_cache(self, which: str):
        bundle = self.draft if which == "draft" else self.target
        if self.paged:
            cache, spec = T.init_paged_cache(
                bundle.cfg, 1, self.max_len, block_size=self.block_size,
                pool_tokens=self.max_len, dtype=self.cache_dtype,
                kv_dtype=self.kv_dtype)
            # single stream owns the whole pool: identity block table
            tbl = np.arange(1, spec.max_blocks + 1, dtype=np.int32)[None]
            return self._place_cache({**cache, "tables": jnp.asarray(tbl)},
                                     paged=True)
        cache, _ = T.init_cache(bundle.cfg, 1, self.max_len, self.cache_dtype,
                                kv_dtype=self.kv_dtype)
        return self._place_cache(cache)

    def _rollback(self, cache, n: int):
        return paged_rollback(cache, [n]) if self.paged else rollback(cache, n)

    def _feed(self, which: str, cache, tokens: List[int],
              bundle: Optional[ModelBundle] = None):
        """Advance by ``tokens``, returning (last-token logits, cache).
        ``bundle`` overrides the weights (precision arms feed through their
        own draft copy); the jitted wrapper is shared — params are traced
        arguments, so a different pytree structure just retraces."""
        key = (which, "feed", len(tokens), self.paged)
        if key not in self._step_cache:
            cfg = (self.draft if which == "draft" else self.target).cfg
            spec = self.dspec if which == "draft" else self.tspec
            step = T.paged_step if self.paged else T.step

            @jax.jit
            def fn(params, toks, cache):
                return step(params, cfg, toks, cache, spec)
            self._step_cache[key] = fn
        if bundle is None:
            bundle = self.draft if which == "draft" else self.target
        return self._step_cache[key](bundle.params,
                                     jnp.asarray([tokens], jnp.int32), cache)

    def _prefill(self, which: str, cache, tokens: List[int],
                 chunk: int = 16):
        toks = list(tokens)
        n_chunks = len(toks) // chunk
        for i in range(n_chunks):
            _, cache = self._feed(which, cache, toks[i * chunk:(i + 1) * chunk])
        for j in range(n_chunks * chunk, len(toks)):
            _, cache = self._feed(which, cache, toks[j:j + 1])
        return cache

    # -------------------------------------------------------- streams
    @_on_mesh
    def start_stream(self, prompt: List[int]) -> dict:
        assert len(prompt) >= 2, "need >= 2 prompt tokens"
        assert len(prompt) + self._max_overshoot + 2 <= self.max_len
        seq = list(prompt)
        res = GenResult(tokens=seq, prompt_len=len(prompt))
        dcache = self._prefill("draft", self._fresh_cache("draft"), seq[:-1])
        tcache = self._prefill("target", self._fresh_cache("target"), seq[:-1])
        return {"seq": seq, "res": res, "dcache": dcache, "tcache": tcache,
                "done": False}

    # -------------------------------------------------------- sessions
    def _chain_session(self, state: dict, stop_idx: int,
                       draft: ModelBundle):
        """One chain draft/verify session (the existing jitted primitives,
        dense or paged-B=1, with the shape's stop rule broadcast; ``draft``
        carries the shape arm's precision — bf16 or int8 weights)."""
        seq = state["seq"]
        L = len(seq)
        g = self.gamma_max
        arm_per_pos = np.full((g,), stop_idx, np.int32)
        lam = jnp.float32(self.controller.lam)
        if self.paged:
            dcache_in = self._rollback(state["dcache"], L - 2)
            active = jnp.asarray([True])
            dres = self._draft_chain(
                draft.params, draft.cfg, self.dspec, dcache_in,
                jnp.asarray([seq[-2:]], jnp.int32), jnp.asarray(arm_per_pos[None]),
                lam, self._next_rng()[None], active,
                arms=self.controller.arms, gamma_max=g,
                temperature=self.temperature)
            vres = self._verify_chain(
                self.target.params, self.target.cfg, self.tspec,
                state["tcache"], jnp.asarray([seq[-1:]], jnp.int32),
                dres.tokens, dres.n_drafted, dres.qprobs,
                self._next_rng()[None], active, gamma_max=g,
                temperature=self.temperature, greedy=self.greedy)
        else:
            dcache_in = self._rollback(state["dcache"], L - 2)
            dres = self._draft_chain(
                draft.params, draft.cfg, self.dspec, dcache_in,
                jnp.asarray([seq[-2:]], jnp.int32), jnp.asarray(arm_per_pos),
                lam, self._next_rng(), arms=self.controller.arms, gamma_max=g,
                temperature=self.temperature)
            vres = self._verify_chain(
                self.target.params, self.target.cfg, self.tspec,
                state["tcache"], jnp.asarray([seq[-1:]], jnp.int32),
                dres.tokens, dres.n_drafted, dres.qprobs, self._next_rng(),
                gamma_max=g, temperature=self.temperature, greedy=self.greedy)
        n_drafted = int(dres.n_drafted[0])
        m = int(vres.n_accepted[0])
        out = np.asarray(vres.out_tokens[0, :m + 1]).tolist()
        state["dcache"] = self._rollback(dres.cache, L + m - 1)
        state["tcache"] = self._rollback(vres.cache, L + m)
        cost = modeled_session_cost(n_drafted + 1, draft.cost_per_token,
                                    self.target.cost_per_token)
        return n_drafted, m, out, cost

    def _tree_session(self, state: dict, tree: TreeSpec,
                      draft: ModelBundle):
        """One tree draft/verify session (see class docstring)."""
        seq = state["seq"]
        L = len(seq)
        cfg_d, cfg_t = draft.cfg, self.target.cfg
        Tn = tree.n_nodes
        temp = self.temperature
        greedy_draft = self.greedy or temp == 0.0

        # ---- draft: refeed suffix, then expand level by level
        dcache = self._rollback(state["dcache"], L - 2)
        lg, dcache = self._feed("draft", dcache, seq[-2:], bundle=draft)
        parent_dist = {-1: np.asarray(_probs(lg[0, -1], temp))}
        # greedy sibling RANKING uses raw logits: at temperature 0 the
        # sampling distribution's non-top-1 entries underflow to exactly
        # 0.0 and argsort would tie-break the tail arbitrarily, collapsing
        # every multi-branch tree to its top-1 path
        parent_rank = {-1: np.asarray(lg[0, -1], np.float32)}
        tokens = np.zeros(Tn, np.int64)
        qdist = np.zeros((Tn, cfg_d.vocab_size), np.float32)
        anc = tree.ancestor_mask
        nodes = T.init_tree_nodes(cfg_d, 1)
        fed = 0
        for level in tree.levels:
            for p in ({-1} if fed == 0 else
                      dict.fromkeys(tree.parents[i] for i in level)):
                dist = parent_dist[p]
                cands = tree.roots if p == -1 else tree.children[p]
                if greedy_draft:
                    picks = np.argsort(parent_rank[p])[::-1][:len(cands)]
                else:
                    picks = self._host_rng.choice(
                        dist.size, size=len(cands), p=dist / dist.sum())
                for node, tok in zip(cands, picks):
                    tokens[node] = int(tok)
                    qdist[node] = dist
            lvl = list(level)
            # draft pointer sits at L after the refeed, so a node's
            # position is pointer + its depth (roots at L, etc.)
            lg_lvl, nodes = self._tree_fwd(
                draft.params, cfg_d, self.dspec, dcache,
                jnp.asarray([tokens[lvl]], jnp.int32),
                jnp.asarray(tree.depths[lvl], jnp.int32),
                jnp.asarray(anc[np.ix_(lvl, range(fed + len(lvl)))]),
                nodes)
            fed += len(lvl)
            if fed < Tn:                 # leaves' dists are never expanded
                probs_lvl = np.asarray(_probs(lg_lvl[0], temp))
                lg_np = np.asarray(lg_lvl[0], np.float32)
                for j, node in enumerate(lvl):
                    parent_dist[node] = probs_lvl[j]
                    parent_rank[node] = lg_np[j]

        # ---- verify: [last token] + tree in ONE target pass
        vtokens = np.concatenate([[seq[-1]], tokens])
        lg_v, tnodes = self._tree_fwd(
            self.target.params, cfg_t, self.tspec, state["tcache"],
            jnp.asarray([vtokens], jnp.int32),
            jnp.asarray(tree.verify_depths, jnp.int32),
            jnp.asarray(tree.verify_mask), T.init_tree_nodes(cfg_t, 1))
        p_node = np.asarray(_probs(lg_v[0], temp))

        # ---- longest accepted path + residual sampling at divergence
        path, repl = verify_walk(tree, tokens, qdist, p_node,
                                 greedy=self.greedy, rng=self._host_rng)
        m = len(path)
        out = [int(tokens[i]) for i in path] + [int(repl)]

        # ---- commit ONLY the accepted path, O(1) rollback
        P_t = 1 + tree.max_depth
        vpath = np.zeros(P_t, np.int32)
        vpath[:m + 1] = [0] + [1 + i for i in path]
        tcache = self._tree_cmt(cfg_t, self.tspec, state["tcache"], tnodes,
                              jnp.asarray(vpath), m + 1)
        state["tcache"] = self._rollback(tcache, L + m)
        P_d = tree.max_depth
        dpath = np.zeros(P_d, np.int32)
        dpath[:m] = path
        dcache = self._tree_cmt(cfg_d, self.dspec, dcache, nodes,
                              jnp.asarray(dpath), m)
        state["dcache"] = self._rollback(dcache, L + m - 1)
        cost = modeled_session_cost(Tn + 1, draft.cost_per_token,
                                    self.target.cost_per_token)
        return Tn, m, out, cost

    @_on_mesh
    def session_step(self, state: dict, eos_id: Optional[int] = None) -> dict:
        """Run ONE shape-bandit session on a stream."""
        seq, res = state["seq"], state["res"]
        shape_idx = self.controller.begin_shape()
        shape = self.controller.shapes[shape_idx]
        dbundle = self._draft_bundle(shape)
        if shape.kind == "tree":
            n_drafted, m, out, cost = self._tree_session(state, shape.tree,
                                                         dbundle)
        else:
            n_drafted, m, out, cost = self._chain_session(
                state, self.controller.stop_arm_index(shape_idx), dbundle)
        seq.extend(out)
        self.controller.update_shape(shape_idx, n_drafted, m)
        res.sessions.append(SessionStats(n_drafted, m, shape_idx))
        res.modeled_cost += cost
        if eos_id is not None and eos_id in out:
            seq[:] = seq[:len(seq) - len(out) + out.index(eos_id) + 1]
            state["done"] = True
        if len(seq) + self._max_overshoot + 2 >= self.max_len:
            state["done"] = True
        return state

    # -------------------------------------------------------- generate
    def generate(self, prompt: List[int], max_new_tokens: int,
                 eos_id: Optional[int] = None) -> GenResult:
        t0 = time.perf_counter()
        state = self.start_stream(prompt)
        res = state["res"]
        while not state["done"] and res.new_tokens < max_new_tokens:
            state = self.session_step(state, eos_id)
        res.wall_time_s = time.perf_counter() - t0
        return res


class TreeSlotEngine(TreeSpecEngine):
    """Slot facade over the tree engine (``EngineSpec(backend="tree_slot")``).

    B per-slot stream states (each with its own single-stream cache pair)
    share ONE shape bandit, online across requests — the TapOut deployment
    setting with tree shapes in the arm pool.  A tick runs one session per
    active slot (a host loop over the jitted per-shape programs; a fused
    batched tree session is future work — topologies differ per slot, so
    it needs per-shape program pools like the chain engines').
    """

    backend_name = "tree_slot"

    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: TapOutTreeSequence, *, batch_size: int = 4,
                 **kw):
        super().__init__(draft, target, controller, **kw)
        self.batch_size = batch_size
        self.slots: List[Optional[dict]] = [None] * batch_size
        self._pending: Optional[dict] = None

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def open_stream(self, slot: int, prompt: List[int],
                    eos_id: Optional[int] = None) -> dict:
        assert self.slots[slot] is None, f"slot {slot} busy"
        st = self.start_stream(prompt)
        st["eos_id"] = eos_id
        self.slots[slot] = st
        return st

    def close_stream(self, slot: int) -> dict:
        st = self.slots[slot]
        assert st is not None
        self.slots[slot] = None
        return st

    def session_step_batch(self) -> List[int]:
        self.session_step_launch()
        return self.session_step_flush()

    # the tree tick is host-driven (per-shape jitted programs per slot), so
    # launch/flush degenerate to run-then-report — but exposing the same
    # two-phase protocol lets the server drive every backend identically
    def session_step_launch(self) -> bool:
        assert self._pending is None, "previous tick not flushed"
        acted: List[int] = []
        for s, st in enumerate(self.slots):
            if st is not None and not st["done"]:
                self.session_step(st, st.get("eos_id"))
                acted.append(s)
        if not acted:
            return False
        self._pending = {"acted": acted}
        return True

    def session_step_flush(self) -> List[int]:
        pending, self._pending = self._pending, None
        return pending["acted"] if pending else []


# ===================================================================== batched

def _tree_get_slot(tree, s: int):
    """Extract lane ``s`` from a slot-stacked cache pytree."""
    return jax.tree.map(lambda a: a[s], tree)


def _tree_set_slot(tree, s: int, lane):
    """Write lane ``s`` of a slot-stacked cache pytree (functional)."""
    return jax.tree.map(lambda big, one: big.at[s].set(one), tree, lane)


class BatchedSpecEngine(_StepMixin, _ShardingMixin):
    """Fixed-B slot engine: ONE jitted draft/verify program serves B streams.

    Per-slot B=1 caches are stacked on a leading slot axis, so every lane
    carries its own ``pos`` scalar and per-layer position arrays — streams
    at different sequence positions coexist in one program.  A tick runs one
    draft+verify session for every active slot at once; finished/empty
    slots ride along masked (outputs zeroed on device, cache lanes
    reconciled by the batched rollback below).

    Rollback after a tick:
      * pointer caches (attention/MLA): one vectorized write of the (B,)
        ``pos`` vector — stale K/V rows carry future positions and are
        masked by attention's ``kpos <= qpos`` rule (same invariant as the
        single-stream engine, now per lane);
      * recurrent caches (mamba2/rglru): restore the whole pre-tick
        snapshot (free in functional JAX), then re-advance each active lane
        by its accepted tokens (per-lane recompute — sequential state has
        no pointer to rewind).

    The batched session program compiles ONCE per (B, gamma_max); admission
    into a free slot never recompiles it (prefill uses chunked feeds of at
    most two shapes, see ``_prefill``).

    ``fused=True`` (the default, requires cheap-rollback caches on both
    models) additionally collapses the whole tick — input-side rollback,
    draft while-loop, verify, accept, output-side rollback — into ONE
    device program (``spec_decode.fused_session_tick``) and splits the tick
    into ``session_step_launch`` / ``session_step_flush`` so the serving
    loop can overlap tick t's device work with tick t-1's host accounting.
    The fused program runs the exact traced bodies of the synchronous
    primitives, so outcomes — and the bandit state they produce — are
    bit-identical to ``fused=False``.
    """

    backend_name = "batched"

    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *, batch_size: int = 4,
                 max_len: int = 2048, temperature: float = 0.0,
                 greedy: bool = True, cache_dtype=jnp.float32,
                 kv_dtype: Optional[str] = None, quant_draft: bool = False,
                 seed: int = 0, prefill_chunk: int = 16, fused: bool = True,
                 mesh=None, drafters=None):
        assert batch_size >= 1
        if drafters is not None:
            # heterogeneous pool: the pool's DEFAULT drafter becomes the
            # engine's draft bundle; the rest get per-drafter lanes below
            draft = drafters.bundle(drafters.default)
        if quant_draft:
            draft = quantized_bundle(draft)
        self.draft, self.target = draft, target
        self.mesh = mesh
        self._place_bundles()
        self.controller = controller
        self.gamma_max = controller.gamma_max
        self.batch_size = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.greedy = greedy
        self.cache_dtype = cache_dtype
        self.kv_dtype = kv_dtype
        self.prefill_chunk = prefill_chunk
        self.rng = jax.random.PRNGKey(seed)
        self.collect_traces = False
        self._step_cache: Dict[tuple, callable] = {}

        dc1, self.dspec = T.init_cache(draft.cfg, 1, max_len, cache_dtype,
                                       kv_dtype=kv_dtype)
        tc1, self.tspec = T.init_cache(target.cfg, 1, max_len, cache_dtype,
                                       kv_dtype=kv_dtype)
        self.draft_cheap = self.dspec.cheap_rollback
        self.target_cheap = self.tspec.cheap_rollback
        # fresh per-admission lanes live on the mesh device set too, so the
        # prefilled lane and the stacked caches it is written into agree
        self._fresh_dcache = self._place_cache(dc1)
        self._fresh_tcache = self._place_cache(tc1)
        stack = lambda c: jax.tree.map(
            lambda a: jnp.stack([a] * batch_size), c)
        # slot lanes shard over the ("pod","data") batch axes
        self.dcaches = self._place_cache(stack(dc1), slots=True)
        self.tcaches = self._place_cache(stack(tc1), slots=True)
        self._sharded_sessions = None
        if mesh is not None:
            from repro.launch.shardings import slot_cache_shardings
            self._sharded_sessions = make_sharded_sessions(
                mesh, cfg_d=self.draft.cfg, cfg_t=self.target.cfg,
                dspec=self.dspec, tspec=self.tspec,
                dparams_sh=self._dparams_sh, tparams_sh=self._tparams_sh,
                dcache_sh=slot_cache_shardings(mesh, self.dcaches),
                tcache_sh=slot_cache_shardings(mesh, self.tcaches),
                batch_size=batch_size, gamma_max=self.gamma_max,
                arms=controller.arms, temperature=temperature, greedy=greedy,
                n_prompt_tokens=2 if self.draft_cheap else 1, paged=False)

        # fused single-dispatch tick: needs O(1) pointer rollback on BOTH
        # models (recurrent state falls back to the two-dispatch tick with
        # host-side snapshot-recompute)
        self.fused = bool(fused and self.draft_cheap and self.target_cheap)
        self._fused_tick = None
        if self.fused:
            if mesh is None:
                self._fused_tick = self._meshless_fused(paged=False)
            else:
                from repro.launch.shardings import slot_cache_shardings
                self._fused_tick = make_sharded_fused(
                    mesh, cfg_d=self.draft.cfg, cfg_t=self.target.cfg,
                    dspec=self.dspec, tspec=self.tspec,
                    dparams_sh=self._dparams_sh, tparams_sh=self._tparams_sh,
                    dcache_sh=slot_cache_shardings(mesh, self.dcaches),
                    tcache_sh=slot_cache_shardings(mesh, self.tcaches),
                    batch_size=batch_size, gamma_max=self.gamma_max,
                    arms=controller.arms, temperature=temperature,
                    greedy=greedy, n_prompt_tokens=2, paged=False)

        B = batch_size
        self.slots: List[Optional[dict]] = [None] * B
        self._pending: Optional[dict] = None
        # host mirrors of each lane's cache "pos" (invariant: len(seq)-1
        # for target, len(seq)-2 for pointer-rollback draft caches; updated
        # IN PLACE so drafter-pool runtimes can alias them)
        self._dpos = np.zeros(B, np.int64)
        self._tpos = np.zeros(B, np.int64)

        # ---- heterogeneous drafter pool (drafter identity as an arm axis)
        self.drafters = drafters
        self._dr: Optional[Dict[str, dict]] = None
        if drafters is not None:
            self._init_drafter_pool(fused)

    # ---------------------------------------------------- drafter pool
    def _init_drafter_pool(self, fused_flag: bool) -> None:
        """One runtime per candidate drafter: placed weights, a fresh B=1
        lane, slot-stacked caches, a host pos mirror, and EITHER a fused
        tick (cheap-rollback drafters) or the per-drafter statics for the
        synchronous two-dispatch tick (recurrent SSD state).  All jitted
        programs are per-drafter entries in the SAME module-level trace
        caches, so the host bandit can switch drafters between ticks with
        zero re-traces after warmup."""
        pool, ctrl, B = self.drafters, self.controller, self.batch_size
        assert hasattr(ctrl, "begin_shape") and hasattr(ctrl, "shapes"), \
            "drafter-pool serving needs a shape controller (TapOutTreeSequence)"
        names = set(pool.names)
        for sh in ctrl.shapes:
            assert sh.kind == "chain", \
                f"drafter-pool serving drafts chains, got {sh.name}"
            assert (sh.drafter or pool.default) in names, sh.drafter
        self._dr = {}
        for d in pool:
            if d.name == pool.default:
                rt = {"name": d.name, "bundle": self.draft,
                      "spec": self.dspec, "cheap": self.draft_cheap,
                      "fresh": self._fresh_dcache, "caches": self.dcaches,
                      "pos": self._dpos, "sh": self._dparams_sh}
            else:
                bundle, sh = d.bundle, None
                if self.mesh is not None:
                    from repro.launch.shardings import params_shardings
                    sh = params_shardings(self.mesh, bundle.params,
                                          mode="serve")
                    bundle = ModelBundle(jax.device_put(bundle.params, sh),
                                         bundle.cfg,
                                         cost_per_token=bundle.cost_per_token)
                dc1, spec = T.init_cache(bundle.cfg, 1, self.max_len,
                                         self.cache_dtype,
                                         kv_dtype=self.kv_dtype)
                stack = lambda c: jax.tree.map(
                    lambda a: jnp.stack([a] * B), c)
                rt = {"name": d.name, "bundle": bundle, "spec": spec,
                      "cheap": spec.cheap_rollback,
                      "fresh": self._place_cache(dc1),
                      "caches": self._place_cache(stack(dc1), slots=True),
                      "pos": np.zeros(B, np.int64), "sh": sh}
            rt["fused"] = bool(fused_flag and rt["cheap"] and
                               self.target_cheap)
            rt["tick"] = rt["sessions"] = None
            if rt["fused"]:
                if self.mesh is None:
                    rt["tick"] = (self._fused_tick
                                  if rt["name"] == pool.default and self.fused
                                  else self._meshless_fused(
                                      paged=False, draft=rt["bundle"],
                                      dspec=rt["spec"]))
                else:
                    from repro.launch.shardings import slot_cache_shardings
                    rt["tick"] = make_sharded_fused(
                        self.mesh, cfg_d=rt["bundle"].cfg,
                        cfg_t=self.target.cfg, dspec=rt["spec"],
                        tspec=self.tspec, dparams_sh=rt["sh"],
                        tparams_sh=self._tparams_sh,
                        dcache_sh=slot_cache_shardings(self.mesh,
                                                       rt["caches"]),
                        tcache_sh=slot_cache_shardings(self.mesh,
                                                       self.tcaches),
                        batch_size=B, gamma_max=self.gamma_max,
                        arms=ctrl.arms, temperature=self.temperature,
                        greedy=self.greedy, n_prompt_tokens=2, paged=False)
            elif self.mesh is not None:
                from repro.launch.shardings import slot_cache_shardings
                rt["sessions"] = make_sharded_sessions(
                    self.mesh, cfg_d=rt["bundle"].cfg, cfg_t=self.target.cfg,
                    dspec=rt["spec"], tspec=self.tspec, dparams_sh=rt["sh"],
                    tparams_sh=self._tparams_sh,
                    dcache_sh=slot_cache_shardings(self.mesh, rt["caches"]),
                    tcache_sh=slot_cache_shardings(self.mesh, self.tcaches),
                    batch_size=B, gamma_max=self.gamma_max, arms=ctrl.arms,
                    temperature=self.temperature, greedy=self.greedy,
                    n_prompt_tokens=2 if rt["cheap"] else 1, paged=False)
            self._dr[d.name] = rt

    def _set_dr_caches(self, name: str, caches) -> None:
        """Adopt a drafter's post-tick/post-catch-up stacked caches; the
        default drafter's runtime and ``self.dcaches`` stay one object."""
        self._dr[name]["caches"] = caches
        if name == self.drafters.default:
            self.dcaches = caches

    def _sync_drafter_lanes(self, rt: dict, act_idx) -> None:
        """Lazy catch-up: before a drafter ticks, feed each active lane the
        tokens it missed while OTHER drafters were drafting (its cache
        consumed ``pos`` tokens; a cheap-rollback drafter needs len(seq)-2,
        a recurrent one len(seq)-1).  Feeds go through the canonical
        ``_chunk_schedule`` windows — {prefill_chunk, 1} shapes only — so
        catch-up compiles nothing new after warmup."""
        need = {}
        for s in act_idx:
            n = len(self.slots[s]["seq"]) - (2 if rt["cheap"] else 1)
            if int(rt["pos"][s]) < n:
                need[s] = n
        if not need:
            return
        tag = f"draft:{rt['name']}"
        lanes = []
        for s in range(self.batch_size):
            lane = _tree_get_slot(rt["caches"], s)
            if s in need:
                q = int(rt["pos"][s])
                toks = np.asarray(self.slots[s]["seq"][q:need[s]],
                                  np.int32)[None]
                for lo, hi in _chunk_schedule(toks.shape[1],
                                              self.prefill_chunk):
                    lane = self._advance_with(tag, rt["bundle"], rt["spec"],
                                              lane, toks[:, lo:hi])
                rt["pos"][s] = need[s]
            lanes.append(lane)
        self._set_dr_caches(rt["name"], self._place_cache(
            jax.tree.map(lambda *xs: jnp.stack(xs), *lanes), slots=True))

    # -------------------------------------------------------- helpers
    def _prefill(self, which: str, params, cache, tokens: List[int]):
        """Advance a fresh B=1 cache by ``tokens`` using chunked feeds, so
        prefill compiles at most two shapes (chunk + single) instead of one
        program per prompt length."""
        toks = np.asarray(tokens, np.int32)[None]
        C = self.prefill_chunk
        n_chunks = toks.shape[1] // C
        for i in range(n_chunks):
            cache = self._advance(which, params, cache, toks[:, i * C:(i + 1) * C])
        for j in range(n_chunks * C, toks.shape[1]):
            cache = self._advance(which, params, cache, toks[:, j:j + 1])
        return cache

    def _next_rng(self, n: int = 1):
        keys = jax.random.split(self.rng, n + 1)
        self.rng = keys[0]
        return keys[1:]

    # -------------------------------------------------------- slots
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_mask(self) -> np.ndarray:
        return np.array([s is not None and not s["done"] for s in self.slots])

    @_on_mesh
    def open_stream(self, slot: int, prompt: List[int],
                    eos_id: Optional[int] = None) -> dict:
        """Prefill ``prompt`` into a free slot; the stream participates in
        every subsequent ``session_step_batch`` until closed."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        assert len(prompt) >= 2, "need >= 2 prompt tokens"
        seq = list(prompt)
        pre = seq[:-1]                       # invariant: pos = len(seq) - 1
        dcache = self._prefill("draft", self.draft.params,
                               self._fresh_dcache, pre)
        tcache = self._prefill("target", self.target.params,
                               self._fresh_tcache, pre)
        # re-pin the canonical slot shardings: the eager lane write lets
        # GSPMD propagate whatever layout it likes, and the sharded session
        # program's in_shardings require the canonical one
        self.dcaches = self._place_cache(
            _tree_set_slot(self.dcaches, slot, dcache), slots=True)
        self.tcaches = self._place_cache(
            _tree_set_slot(self.tcaches, slot, tcache), slots=True)
        self._dpos[slot] = len(pre)
        self._tpos[slot] = len(pre)
        if self._dr is not None:
            # the default drafter's runtime adopts the prefilled lane; every
            # OTHER drafter's lane resets to a fresh cache (recurrent SSD
            # state MUST restart from zero) and catches up lazily before
            # its first tick on this stream
            self._dr[self.drafters.default]["caches"] = self.dcaches
            for name, rt in self._dr.items():
                if name == self.drafters.default:
                    continue
                rt["caches"] = self._place_cache(
                    _tree_set_slot(rt["caches"], slot, rt["fresh"]),
                    slots=True)
                rt["pos"][slot] = 0
        st = {"seq": seq, "res": GenResult(tokens=seq, prompt_len=len(prompt)),
              "done": False, "eos_id": eos_id}
        self.slots[slot] = st
        return st

    def close_stream(self, slot: int) -> dict:
        """Release a slot (its cache lane is dead until the next admission)."""
        st = self.slots[slot]
        assert st is not None
        self.slots[slot] = None
        self._dpos[slot] = 0
        self._tpos[slot] = 0
        if self._dr is not None:
            for rt in self._dr.values():
                rt["pos"][slot] = 0
        return st

    # -------------------------------------------------------- tick
    def session_step_batch(self) -> List[int]:
        """Run one draft/verify session for every active slot in one
        batched program (one synchronous tick: launch + flush back to
        back).  Returns the slots that were active this tick."""
        self.session_step_launch()
        return self.session_step_flush()

    @_on_mesh
    def session_step_launch(self) -> bool:
        """Dispatch one tick WITHOUT reading its outcomes back.

        Fused path: the only host work is input assembly and the bandit's
        arm draw (``begin_batch``); the single device program is launched
        asynchronously and its ``FusedTick`` outcome buffer stays
        device-resident until ``session_step_flush``.  The serving loop
        flushes tick t-1 only after admitting for tick t, so the bandit
        consumes outcomes one step behind — its begin/update call sequence
        is exactly the synchronous path's, keeping its state bit-identical.
        Non-fused engines run the classic two-dispatch tick here and merely
        stash the acted list for flush.  Returns True iff a tick ran."""
        assert self._pending is None, "previous tick not flushed"
        B, g = self.batch_size, self.gamma_max
        active = self.active_mask()
        act_idx = np.flatnonzero(active)
        if act_idx.size == 0:
            return False
        if self._dr is not None:
            return self._launch_drafter_tick(active, act_idx)
        if not self.fused:
            self._pending = {"acted": self._session_step_sync()}
            return True

        L = np.array([len(self.slots[s]["seq"]) if self.slots[s] else 0
                      for s in range(B)], np.int64)
        arm_mat = np.zeros((B, g), np.int32)
        arm_mat[act_idx] = self.controller.begin_batch(act_idx.size)
        in_toks = np.zeros((B, 2), np.int32)
        last_toks = np.zeros((B, 1), np.int32)
        for s in act_idx:
            seq = self.slots[s]["seq"]
            in_toks[s] = seq[-2:]
            last_toks[s, 0] = seq[-1]
        keys = self._next_rng(2 * B)
        ft = self._fused_tick(
            self.draft.params, self.target.params, self.dcaches,
            self.tcaches, jnp.asarray(in_toks), jnp.asarray(last_toks),
            jnp.asarray(arm_mat), jnp.float32(self.controller.lam),
            keys[:B], keys[B:], jnp.asarray(active),
            jnp.asarray(L, jnp.int32), jnp.asarray(self._dpos, jnp.int32),
            jnp.asarray(self._tpos, jnp.int32))
        # caches come back already rolled back — adopt them immediately so
        # admissions between ticks write into post-tick lanes
        self.dcaches, self.tcaches = ft.dcache, ft.tcache
        self._pending = {"act_idx": act_idx, "active": active,
                         "arm_mat": arm_mat, "L": L, "ft": ft}
        return True

    def _launch_drafter_tick(self, active, act_idx) -> bool:
        """One tick of the heterogeneous-drafter engine: the host
        meta-bandit picks ONE (drafter, stop-rule) arm for the whole batch
        (``begin_shape``), the chosen drafter's lanes catch up on tokens
        accepted while other drafters ran, then its pre-built fused tick
        (cheap-rollback drafters) or synchronous two-dispatch tick
        (recurrent SSD) launches — no re-trace, just a different cached
        program."""
        B, g = self.batch_size, self.gamma_max
        ctrl = self.controller
        shape_idx = int(ctrl.begin_shape())
        rt = self._dr[ctrl.drafter_for(shape_idx) or self.drafters.default]
        self._sync_drafter_lanes(rt, act_idx)
        arm_mat = np.zeros((B, g), np.int32)
        arm_mat[act_idx] = ctrl.stop_arm_index(shape_idx)
        if not rt["fused"]:
            acted = self._session_step_sync(rt=rt, shape_idx=shape_idx,
                                            arm_mat=arm_mat)
            self._pending = {"acted": acted}
            return True
        L = np.array([len(self.slots[s]["seq"]) if self.slots[s] else 0
                      for s in range(B)], np.int64)
        in_toks = np.zeros((B, 2), np.int32)
        last_toks = np.zeros((B, 1), np.int32)
        for s in act_idx:
            seq = self.slots[s]["seq"]
            in_toks[s] = seq[-2:]
            last_toks[s, 0] = seq[-1]
        keys = self._next_rng(2 * B)
        ft = rt["tick"](
            rt["bundle"].params, self.target.params, rt["caches"],
            self.tcaches, jnp.asarray(in_toks), jnp.asarray(last_toks),
            jnp.asarray(arm_mat), jnp.float32(ctrl.lam),
            keys[:B], keys[B:], jnp.asarray(active),
            jnp.asarray(L, jnp.int32), jnp.asarray(rt["pos"], jnp.int32),
            jnp.asarray(self._tpos, jnp.int32))
        self._set_dr_caches(rt["name"], ft.dcache)
        self.tcaches = ft.tcache
        self._pending = {"act_idx": act_idx, "active": active,
                         "arm_mat": arm_mat, "L": L, "ft": ft,
                         "shape_idx": shape_idx, "drafter": rt["name"]}
        return True

    @_on_mesh
    def session_step_flush(self) -> List[int]:
        """Read the pending tick's device-resident outcomes, do per-stream
        accounting (sequence extension, stats, EOS/budget termination) and
        feed the bandit (``update_batch``).  Returns the acted slots; [] if
        no tick is pending."""
        pending, self._pending = self._pending, None
        if pending is None:
            return []
        if "acted" in pending:              # non-fused tick already complete
            return pending["acted"]
        active, act_idx = pending["active"], pending["act_idx"]
        arm_mat, L, ft = pending["arm_mat"], pending["L"], pending["ft"]
        drafter = pending.get("drafter")
        g = self.gamma_max
        c_d = (self._dr[drafter]["bundle"].cost_per_token if drafter
               else self.draft.cost_per_token)
        c_t = self.target.cost_per_token
        nd = np.asarray(ft.n_drafted)
        m = np.asarray(ft.n_accepted)
        out_all = np.asarray(ft.out_tokens)
        if self.collect_traces:
            sig_all = np.asarray(ft.signals)
            ent_all = np.asarray(ft.entropies)
        for s in act_idx:
            st = self.slots[s]
            seq, res = st["seq"], st["res"]
            out = out_all[s, :m[s] + 1].tolist()
            seq.extend(out)
            # drafter ticks record the META-arm (shape_idx); plain ticks
            # record the stop-rule arm as before
            arm = (int(pending["shape_idx"]) if drafter
                   else int(arm_mat[s, 0]))
            res.sessions.append(SessionStats(int(nd[s]), int(m[s]), arm))
            res.modeled_cost += modeled_session_cost(int(nd[s]) + 1, c_d, c_t)
            if self.collect_traces:
                res.traces.append({
                    "signals": sig_all[s], "entropies": ent_all[s],
                    "n_drafted": int(nd[s]), "n_accepted": int(m[s]),
                    "position_base": 0})
            eos = st["eos_id"]
            if eos is not None and eos in out:
                seq[:] = seq[:len(seq) - len(out) + out.index(eos) + 1]
                st["done"] = True
            if len(seq) + g + 2 >= self.max_len:
                st["done"] = True
        # host mirrors follow the on-device output-side rollback (in place:
        # drafter-pool runtimes alias these arrays)
        self._tpos[:] = np.where(active, L + m, self._tpos)
        if drafter:
            rt = self._dr[drafter]
            rt["pos"][:] = np.where(active, L + m - 1, rt["pos"])
            self.controller.update_shape_batch(pending["shape_idx"],
                                               nd[act_idx], m[act_idx])
        else:
            self._dpos[:] = np.where(active, L + m - 1, self._dpos)
            self.controller.update_batch(arm_mat[act_idx], nd[act_idx],
                                         m[act_idx])
        return act_idx.tolist()

    def _session_step_sync(self, rt: Optional[dict] = None,
                           shape_idx: Optional[int] = None,
                           arm_mat: Optional[np.ndarray] = None) -> List[int]:
        """The classic two-dispatch tick (snapshot-recompute rollback for
        recurrent stacks lives here — fusion requires cheap rollback).

        With ``rt`` (a drafter-pool runtime) the draft side runs that
        drafter's bundle/spec/caches instead of the engine defaults, the
        stop-rule row matrix is supplied by the caller (one meta-arm for the
        whole tick), and the bandit is fed through
        ``update_shape_batch(shape_idx, ...)`` — this is how the recurrent
        SSD drafter serves inside the drafter-pool engine."""
        B, g = self.batch_size, self.gamma_max
        active = self.active_mask()
        act_idx = np.flatnonzero(active)
        if act_idx.size == 0:
            return []
        dbundle = rt["bundle"] if rt else self.draft
        dspec = rt["spec"] if rt else self.dspec
        dcheap = rt["cheap"] if rt else self.draft_cheap
        dcaches_cur = rt["caches"] if rt else self.dcaches
        dpos_arr = rt["pos"] if rt else self._dpos
        sessions = rt["sessions"] if rt else self._sharded_sessions
        c_d = dbundle.cost_per_token
        c_t = self.target.cost_per_token
        L = np.array([len(self.slots[s]["seq"]) if self.slots[s] else 0
                      for s in range(B)], np.int64)

        # ---- controller: per-stream arm rows (inactive rows are arm 0)
        if arm_mat is None:
            arm_mat = np.zeros((B, g), np.int32)
            arm_mat[act_idx] = self.controller.begin_batch(act_idx.size)

        # ---- assemble per-stream inputs
        n_in = 2 if dcheap else 1
        in_toks = np.zeros((B, n_in), np.int32)
        last_toks = np.zeros((B, 1), np.int32)
        for s in act_idx:
            seq = self.slots[s]["seq"]
            in_toks[s] = seq[-n_in:]
            last_toks[s, 0] = seq[-1]

        if dcheap:
            dpos_in = np.where(active, L - 2, dpos_arr)
            dcaches_in = {**dcaches_cur,
                          "pos": jnp.asarray(dpos_in, jnp.int32)}
            dsnap = None
        else:
            dsnap = dcaches_cur
            dcaches_in = dcaches_cur
        tsnap = None if self.target_cheap else self.tcaches

        keys = self._next_rng(2 * B)
        active_dev = jnp.asarray(active)

        if sessions is not None:
            draft_fn, verify_fn = sessions
            dres = draft_fn(dbundle.params, dcaches_in,
                            jnp.asarray(in_toks), jnp.asarray(arm_mat),
                            jnp.float32(self.controller.lam), keys[:B],
                            active_dev)
            vres = verify_fn(self.target.params, self.tcaches,
                             jnp.asarray(last_toks), dres.tokens,
                             dres.n_drafted, dres.qprobs, keys[B:],
                             active_dev)
        else:
            dres = draft_session_batched(
                dbundle.params, dbundle.cfg, dspec, dcaches_in,
                jnp.asarray(in_toks), arm_mat, jnp.float32(self.controller.lam),
                keys[:B], active_dev, arms=self.controller.arms, gamma_max=g,
                temperature=self.temperature, n_prompt_tokens=n_in)
            vres = verify_session_batched(
                self.target.params, self.target.cfg, self.tspec, self.tcaches,
                jnp.asarray(last_toks), dres.tokens, dres.n_drafted,
                dres.qprobs, keys[B:], active_dev, gamma_max=g,
                temperature=self.temperature, greedy=self.greedy)

        nd = np.asarray(dres.n_drafted)
        m = np.asarray(vres.n_accepted)
        out_all = np.asarray(vres.out_tokens)
        if self.collect_traces:
            sig_all = np.asarray(dres.signals)
            ent_all = np.asarray(dres.entropies)

        # ---- per-stream output assembly + accounting
        feeds = {}
        for s in act_idx:
            st = self.slots[s]
            seq, res = st["seq"], st["res"]
            out = out_all[s, :m[s] + 1].tolist()
            feeds[s] = np.asarray([seq[-1:] + out[:-1]], np.int32)
            seq.extend(out)
            arm = int(shape_idx) if rt else int(arm_mat[s, 0])
            res.sessions.append(SessionStats(int(nd[s]), int(m[s]), arm))
            res.modeled_cost += modeled_session_cost(
                int(nd[s]) + n_in - 1, c_d, c_t)
            if self.collect_traces:
                res.traces.append({
                    "signals": sig_all[s], "entropies": ent_all[s],
                    "n_drafted": int(nd[s]), "n_accepted": int(m[s]),
                    "position_base": 0})
            eos = st["eos_id"]
            if eos is not None and eos in out:
                seq[:] = seq[:len(seq) - len(out) + out.index(eos) + 1]
                st["done"] = True
            if len(seq) + g + 2 >= self.max_len:
                st["done"] = True

        # ---- batched cache maintenance
        def readvance(which, params, snap):
            # snapshot rollback: inactive lanes keep the pre-tick snapshot,
            # active lanes are re-advanced by their accepted tokens, and the
            # batch is restacked ONCE (not one full-tree copy per lane).
            # Drafter-pool re-advances go through the canonical chunk
            # schedule — {prefill_chunk, 1} feed shapes only — so a pool
            # drafter's whole serving surface compiles a FIXED set of
            # programs (the zero-retrace-after-warmup guarantee).
            lanes = []
            for s in range(B):
                lane = _tree_get_slot(snap, s)
                if active[s]:
                    if rt and which == "draft":
                        tag = f"draft:{rt['name']}"
                        for lo, hi in _chunk_schedule(feeds[s].shape[1],
                                                      self.prefill_chunk):
                            lane = self._advance_with(
                                tag, dbundle, dspec, lane, feeds[s][:, lo:hi])
                    else:
                        lane = self._advance(which, params, lane, feeds[s])
                lanes.append(lane)
            return jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)

        if self.target_cheap:
            self._tpos[:] = np.where(active, L + m, self._tpos)
            self.tcaches = rollback(vres.cache, self._tpos)
        else:
            self.tcaches = self._place_cache(
                readvance("target", self.target.params, tsnap), slots=True)
            self._tpos[:] = np.where(active, L + m, self._tpos)
        if dcheap:
            dpos_arr[:] = np.where(active, L + m - 1, dpos_arr)
            new_dcaches = rollback(dres.cache, dpos_arr)
        else:
            new_dcaches = self._place_cache(
                readvance("draft", dbundle.params, dsnap), slots=True)
            dpos_arr[:] = np.where(active, L + m, dpos_arr)
        if rt:
            self._set_dr_caches(rt["name"], new_dcaches)
        else:
            self.dcaches = new_dcaches

        # ---- one order-independent batched bandit update for the tick
        if rt:
            self.controller.update_shape_batch(shape_idx, nd[act_idx],
                                               m[act_idx])
        else:
            self.controller.update_batch(arm_mat[act_idx], nd[act_idx],
                                         m[act_idx])
        return act_idx.tolist()


# ===================================================================== paged

_POOL_KEYS = POOL_LEAF_KEYS


def _path_keys(path):
    return [getattr(p, "key", None) for p in path]


def _chunk_schedule(n_tokens: int, chunk: int) -> List[tuple]:
    """``(lo, hi)`` feed windows of a prefill: whole ``chunk``-token
    windows first, then singles for the unaligned tail.  ONE canonical
    schedule shared by monolithic and per-tick chunked prefill — same
    windows at the same offsets means the same compiled programs see the
    same operands, so the two paths stay bit-identical."""
    n_whole = n_tokens // chunk
    sched = [(i * chunk, (i + 1) * chunk) for i in range(n_whole)]
    sched += [(j, j + 1) for j in range(n_whole * chunk, n_tokens)]
    return sched


class PagedSpecEngine(_ShardingMixin):
    """Paged slot engine: B streams share global KV block pools.

    Where ``BatchedSpecEngine`` stacks one dense ``max_len`` cache per slot
    (memory = B x max_len x layers whether or not a stream uses it), this
    engine owns ONE block pool per attention layer plus per-stream block
    tables and lengths (``models/cache.py``).  Consequences:

      * pool memory is sized by ``pool_tokens`` — independent of both B and
        ``max_len`` — so concurrency is no longer capped by the dense
        worst-case allocation;
      * rollback after a tick is ONE per-stream length truncation for every
        attention/MLA layer at once (``paged_rollback``) — no per-kind
        special cases (recurrent layers keep snapshot-recompute, which the
        paged layout leaves untouched);
      * admission reserves physical blocks for a request's worst case
        (prompt + budget + draft overshoot) up front, so a running stream
        can never hit pool exhaustion mid-flight; ``can_admit`` lets the
        scheduler backpressure instead of admitting.

    The batched draft/verify programs are BATCH-NATIVE (not vmapped — the
    shared pool forbids per-lane functional writes) and compile once per
    (B, gamma_max); admission/release only change table/length DATA, never
    shapes, so a request joining the running batch never recompiles.
    Masked lanes write into the reserved trash block 0.

    ``fused=True`` (default, cheap-rollback stacks only) collapses the tick
    into one device program with the launch/flush split — identical
    semantics to ``BatchedSpecEngine``'s, with per-lane LENGTH truncation
    standing in for the dense pointer rollback.
    """

    backend_name = "paged"

    def __init__(self, draft: ModelBundle, target: ModelBundle,
                 controller: Controller, *, batch_size: int = 4,
                 max_len: int = 2048, block_size: int = 64,
                 pool_tokens: Optional[int] = None,
                 temperature: float = 0.0, greedy: bool = True,
                 cache_dtype=jnp.float32, kv_dtype: Optional[str] = None,
                 quant_draft: bool = False, seed: int = 0,
                 prefill_chunk: int = 16, fused: bool = True,
                 prefix_cache: bool = False, mesh=None):
        assert batch_size >= 1
        if quant_draft:
            draft = quantized_bundle(draft)
        self.draft, self.target = draft, target
        self.mesh = mesh
        self._place_bundles()
        self.controller = controller
        self.gamma_max = controller.gamma_max
        self.batch_size = batch_size
        self.max_len = max_len
        self.block_size = block_size
        self.pool_tokens = pool_tokens or batch_size * max_len
        self.temperature = temperature
        self.greedy = greedy
        self.cache_dtype = cache_dtype
        self.kv_dtype = kv_dtype
        self.prefill_chunk = prefill_chunk
        self.rng = jax.random.PRNGKey(seed)
        self.collect_traces = False
        self._step_cache: Dict[tuple, callable] = {}

        B = batch_size
        self.dcache, self.dspec = T.init_paged_cache(
            draft.cfg, B, max_len, block_size=block_size,
            pool_tokens=self.pool_tokens, dtype=cache_dtype,
            kv_dtype=kv_dtype)
        self.tcache, self.tspec = T.init_paged_cache(
            target.cfg, B, max_len, block_size=block_size,
            pool_tokens=self.pool_tokens, dtype=cache_dtype,
            kv_dtype=kv_dtype, enc_segments=B + 1)
        # enc-dec targets: one host-side refcounted directory over the
        # shared encoder segment pools in tcache["cross"] — admission with
        # an already-seen encoding adopts its segment (zero encoder
        # compute, zero extra bytes), mirroring a prefix-cache hit
        self.enc_pool: Optional[EncoderSegmentPool] = (
            EncoderSegmentPool(B + 1) if target.cfg.is_encdec else None)
        # pools shard KV heads over "model" (whole block axis per shard —
        # any table may point anywhere); tables/lengths ride the lane axes
        self.dcache = self._place_cache(self.dcache, paged=True)
        self.tcache = self._place_cache(self.tcache, paged=True)
        self.draft_cheap = self.dspec.cheap_rollback
        self.target_cheap = self.tspec.cheap_rollback
        self.dalloc = BlockAllocator(self.dspec.num_blocks,
                                     self.dspec.max_blocks, B)
        self.talloc = BlockAllocator(self.tspec.num_blocks,
                                     self.tspec.max_blocks, B)
        # prefix-sharing admission (docs/prefix_sharing.md): hashed
        # block-aligned prompt chunks -> physical block runs in BOTH pools.
        # Adoption rewires tables/lengths, so it needs the attention/MLA-only
        # stacks whose per-stream state IS the pool (recurrent conv/ssm state
        # is integrated per stream and cannot be adopted from a block run).
        self.prefix_cache: Optional[PrefixCache] = None
        if prefix_cache:
            if not (self.draft_cheap and self.target_cheap):
                raise ValueError(
                    "prefix_cache=True needs attention/MLA-only stacks; "
                    "recurrent per-stream state cannot be block-shared")
            self.prefix_cache = PrefixCache(block_size,
                                            (self.dalloc, self.talloc))
        self.prefill_tokens_computed = 0
        self.prefill_tokens_skipped = 0
        self.cow_copies = 0
        self.preemptions = 0
        self.resumes = 0
        self._sharded_sessions = None
        if mesh is not None:
            from repro.launch.shardings import paged_cache_shardings
            self._sharded_sessions = make_sharded_sessions(
                mesh, cfg_d=self.draft.cfg, cfg_t=self.target.cfg,
                dspec=self.dspec, tspec=self.tspec,
                dparams_sh=self._dparams_sh, tparams_sh=self._tparams_sh,
                dcache_sh=paged_cache_shardings(mesh, self.dcache),
                tcache_sh=paged_cache_shardings(mesh, self.tcache),
                batch_size=batch_size, gamma_max=self.gamma_max,
                arms=controller.arms, temperature=temperature, greedy=greedy,
                n_prompt_tokens=2 if self.draft_cheap else 1, paged=True)

        self.fused = bool(fused and self.draft_cheap and self.target_cheap)
        self._fused_tick = None
        if self.fused:
            if mesh is None:
                self._fused_tick = self._meshless_fused(paged=True)
            else:
                from repro.launch.shardings import paged_cache_shardings
                self._fused_tick = make_sharded_fused(
                    mesh, cfg_d=self.draft.cfg, cfg_t=self.target.cfg,
                    dspec=self.dspec, tspec=self.tspec,
                    dparams_sh=self._dparams_sh, tparams_sh=self._tparams_sh,
                    dcache_sh=paged_cache_shardings(mesh, self.dcache),
                    tcache_sh=paged_cache_shardings(mesh, self.tcache),
                    batch_size=batch_size, gamma_max=self.gamma_max,
                    arms=controller.arms, temperature=temperature,
                    greedy=greedy, n_prompt_tokens=2, paged=True)

        self.slots: List[Optional[dict]] = [None] * B
        self._pending: Optional[dict] = None
        self._dlen = np.zeros(B, np.int64)   # host mirrors of device lengths
        self._tlen = np.zeros(B, np.int64)
        # per-slot TARGET position offset: P prepended patch positions for
        # vision-conditioned streams (lengths invariant becomes
        # len(seq) - 1 + toff).  Any nonzero offset forces the sync tick —
        # the fused program serves both models' rollbacks from ONE shared
        # lengths vector, which an asymmetric offset would break.
        self._toff = np.zeros(B, np.int64)
        self._init_moe_accounting()

    # -------------------------------------------------------- plumbing
    def _next_rng(self, n: int = 1):
        keys = jax.random.split(self.rng, n + 1)
        self.rng = keys[0]
        return keys[1:]

    def _jit_paged_step(self, which: str):
        # one wrapper per model; jax.jit specializes it per token shape
        if which not in self._step_cache:
            bundle = self.draft if which == "draft" else self.target
            spec = self.dspec if which == "draft" else self.tspec

            @jax.jit
            def fn(params, tokens, cache):
                return T.paged_step(params, bundle.cfg, tokens, cache, spec)
            self._step_cache[which] = fn
        return self._step_cache[which]

    def _lane_view(self, cache, slot: int):
        """Single-lane view: pools stay global, per-stream leaves sliced.
        Encoder segment pools ride whole (shared, indexed by the lane's
        ``cross_seg`` row) so a lane prefill is conditioned exactly like
        the batch-native tick; ``moe_stats`` is sliced per stream."""
        def f(path, a):
            keys = _path_keys(path)
            if keys[-1] in _POOL_KEYS:
                return a
            ax = 1 if keys[0] == "stack" else 0
            return jax.lax.slice_in_dim(a, slot, slot + 1, axis=ax)
        layers = jax.tree_util.tree_map_with_path(f, cache["layers"])
        lane = {"lengths": cache["lengths"][slot:slot + 1],
                "tables": cache["tables"][slot:slot + 1], "layers": layers}
        if "cross" in cache:
            lane["cross"] = cache["cross"]
            lane["cross_seg"] = cache["cross_seg"][slot:slot + 1]
        if "moe_stats" in cache:
            lane["moe_stats"] = cache["moe_stats"][slot:slot + 1]
        return lane

    def _merge_lane(self, cache, lane, slot: int):
        """Fold a lane view back: pools replace wholesale (the lane program
        updated them in place), per-stream leaves write lane ``slot``."""
        def f(path, big, one):
            keys = _path_keys(path)
            if keys[-1] in _POOL_KEYS:
                return one
            ax = 1 if keys[0] == "stack" else 0
            return jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), slot, axis=ax)
        layers = jax.tree_util.tree_map_with_path(f, cache["layers"],
                                                  lane["layers"])
        return {**cache,
                "lengths": cache["lengths"].at[slot].set(lane["lengths"][0]),
                "layers": layers}

    def _advance_lane(self, which: str, cache, slot: int,
                      tokens: np.ndarray):
        """Feed ``tokens`` (1, L) through lane ``slot`` against the pool."""
        if tokens.shape[1] == 0:
            return cache
        bundle = self.draft if which == "draft" else self.target
        fn = self._jit_paged_step(which)
        lane = self._lane_view(cache, slot)
        _, lane = fn(bundle.params, jnp.asarray(tokens, jnp.int32), lane)
        return self._merge_lane(cache, lane, slot)

    def _reset_lane_state(self, cache, slot: int):
        """Zero lane ``slot``'s PER-STREAM leaves (recurrent conv/ssm/rec
        state).  Pools need no reset — a reused slot's stale rows are dead
        under the ``p < length`` mask — but recurrent state is integrated,
        not indexed, so a reused slot would otherwise prefill on top of the
        previous stream's final hidden state."""
        def f(path, a):
            keys = _path_keys(path)
            if keys[-1] in _POOL_KEYS:
                return a
            ax = 1 if keys[0] == "stack" else 0
            zeros = jnp.zeros_like(jax.lax.slice_in_dim(a, slot, slot + 1,
                                                        axis=ax))
            return jax.lax.dynamic_update_slice_in_dim(a, zeros, slot, axis=ax)
        return {**cache, "layers": jax.tree_util.tree_map_with_path(
            f, cache["layers"])}

    def _chunk_feed_lane(self, which: str, cache, slot: int,
                         tokens: np.ndarray, n_valid: int):
        """One resumable chunk-prefill step on lane ``slot``: feed a (1, C)
        buffer through ``chunk_prefill_paged`` (positions come from the
        lane's live length, so it resumes anywhere) and fold the lane back
        into the pool."""
        bundle = self.draft if which == "draft" else self.target
        spec = self.dspec if which == "draft" else self.tspec
        lane = self._lane_view(cache, slot)
        lane = chunk_prefill_paged(bundle.params, bundle.cfg, spec, lane,
                                   jnp.asarray(tokens, jnp.int32), n_valid)
        return self._merge_lane(cache, lane, slot)

    def _prefill_lane(self, which: str, cache, slot: int, tokens: List[int]):
        """Monolithic prefill = the FULL chunk schedule run back to back.
        Routing it through the same ``chunk_prefill_paged`` program (and
        the same whole-chunks-then-singles schedule) that ``prefill_step``
        uses makes chunked and monolithic prefill bit-identical by
        construction — there is only one prefill program."""
        toks = np.asarray(tokens, np.int32)[None]
        for lo, hi in _chunk_schedule(toks.shape[1], self.prefill_chunk):
            cache = self._chunk_feed_lane(which, cache, slot,
                                          toks[:, lo:hi], hi - lo)
        return cache

    def _prefill_vlm_lane(self, slot: int, tokens: List[int], patch_embeds):
        """Conditioned target prefill: ONE feed of the projected patches +
        the whole prompt through the lane (positions come from the lane's
        zeroed length, so patches land at 0..P-1 and text at P.. with the
        right RoPE — same layout as the dense conditioned reference)."""
        lane = self._lane_view(self.tcache, slot)
        toks = jnp.asarray(np.asarray(tokens, np.int32)[None])
        _, lane = T.paged_step(self.target.params, self.target.cfg, toks,
                               lane, self.tspec,
                               patch_embeds=jnp.asarray(patch_embeds))
        return self._place_cache(self._merge_lane(self.tcache, lane, slot),
                                 paged=True)

    def _enc_seg_bytes(self) -> int:
        """Bytes ONE encoder segment occupies across every cross-KV pool."""
        cp = self.tcache["cross"]
        total = 0
        for c in cp["prefix"] + cp["tail"]:
            for a in jax.tree_util.tree_leaves(c):
                total += int(np.prod(a.shape[1:])) * a.dtype.itemsize
        if cp["stack"] is not None:
            for a in jax.tree_util.tree_leaves(cp["stack"]):
                total += int(a.shape[0] * np.prod(a.shape[2:])) * a.dtype.itemsize
        return total

    def _adopt_encoder_segment(self, slot: int, frame_embeds) -> int:
        """Admission half of encoder conditioning: digest the raw frames,
        adopt the cached segment on a hit (refcount bump — no encoder
        forward, no new pool rows), else encode ONCE into a free segment.
        Either way the slot's ``cross_seg`` row points at it afterwards."""
        fe = np.asarray(frame_embeds, np.float32)
        if fe.ndim == 2:
            fe = fe[None]
        seg, is_new = self.enc_pool.acquire(EncoderSegmentPool.digest(fe),
                                            self._enc_seg_bytes())
        if is_new:
            cross_lane = T.encode_cross_segment(self.target.params,
                                                self.target.cfg,
                                                jnp.asarray(fe))
            self.tcache = T.write_cross_segment(self.tcache, cross_lane, seg)
        self.tcache = self._place_cache(
            {**self.tcache,
             "cross_seg": self.tcache["cross_seg"].at[slot].set(seg)},
            paged=True)
        return seg

    # -------------------------------------------------------- slots
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_mask(self) -> np.ndarray:
        """Slots that decode THIS tick.  A slot still mid-chunked-prefill
        occupies its lane and blocks but rides the tick masked (its lane's
        garbage feed lands in its own reserved pages past the length
        mirror, dead under the tick's rollback and overwritten by the next
        real prefill chunk) until ``prefill_step`` finishes the prompt."""
        return np.array([s is not None and not s["done"]
                         and not s.get("prefilling") for s in self.slots])

    def prefilling_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.get("prefilling")]

    def reserve_blocks_for(self, reserve_tokens: int) -> int:
        """Physical blocks a request with this worst-case length needs."""
        need = min(reserve_tokens, self.max_len)
        return self.dalloc.blocks_for(need, self.block_size)

    def _adoptable(self, prompt: List[int], touch: bool = False):
        """(n_adopt, runs, n_cow): the longest cached chunk run inside the
        prompt's prefill region [0, P-1), and whether adopting it forces a
        copy-on-write of the draft's frontier block.

        The draft refeeds from position P-2, so an adopted block containing
        P-2 (only possible when the run ends EXACTLY at P-1, i.e. ``bs``
        divides P-1) must be privatized before the first tick; the target
        writes from P-1, which by construction lies past every adopted
        block, so it never needs one."""
        if self.prefix_cache is None or len(prompt) < 2:
            return 0, None, 0
        n, runs = self.prefix_cache.match(prompt, limit_tokens=len(prompt) - 1,
                                          touch=touch)
        n_cow = 1 if n and (len(prompt) - 2) // self.block_size < n else 0
        return n, runs, n_cow

    def can_admit(self, reserve_tokens: int,
                  prompt: Optional[List[int]] = None) -> bool:
        """Feasibility probe for the scheduler: with ``prompt`` given, a
        prefix-cache hit only needs the NON-SHARED suffix (plus at most one
        COW block), and evictable cached chunks count as reclaimable."""
        need = self.reserve_blocks_for(reserve_tokens)
        if not self.free_slots():
            return False
        n_adopt, _, n_cow = self._adoptable(prompt) if prompt else (0, None, 0)
        evictable = (self.prefix_cache.evictable_chunks()
                     if self.prefix_cache is not None else 0)
        # the adopted run is refcount==1 until admission pins it, so it is
        # counted inside ``evictable_chunks`` — subtract it (floored at 0)
        # or capacity is overstated by up to ``n_adopt`` blocks per pool
        evictable = max(evictable - n_adopt, 0)
        need_new = max(need - n_adopt, 0) + n_cow
        return all(need_new <= len(a.free) + evictable
                   for a in (self.dalloc, self.talloc))

    @_on_mesh
    def open_stream(self, slot: int, prompt: List[int],
                    eos_id: Optional[int] = None,
                    reserve_tokens: Optional[int] = None,
                    resume_from: Optional[GenResult] = None, *,
                    frame_embeds=None, patch_embeds=None) -> dict:
        """Admit a stream: reserve blocks, prefill the prompt into its pages.

        ``reserve_tokens`` is the worst-case sequence length this request
        can reach (prompt + new-token budget + gamma slack); default is
        ``max_len`` (dense-equivalent reservation).  Raises
        ``PoolExhausted`` when the pool cannot cover it — callers should
        check ``can_admit`` first and backpressure.

        With a ``PrefixCache``, admission first matches the prompt's
        block-aligned chunks: adopted blocks are SHARED (table row aliases,
        refcount bumps, zero prefill compute), only the non-shared suffix
        is reserved privately, the draft's frontier block is copied-on-write
        if the adopted run reaches it, and after prefill the stream's own
        full blocks below its write frontier are registered for the next
        stream to adopt.

        ``resume_from`` re-opens a PREEMPTED stream from the handle
        ``preempt_stream`` returned: pass the frozen sequence as
        ``prompt`` and the frozen ``res`` here — accounting continues on
        the same ``GenResult``, and the blocks ``preempt_stream``
        registered make the re-prefill a prefix-cache adoption.

        Conditioning (target-side; draft stays a text-only decoder):
        ``frame_embeds`` (T, frontend_dim) for enc-dec targets lands as a
        SHARED, refcounted encoder segment — admission with an
        already-cached encoding adopts the segment exactly like a
        prefix-cache hit (zero encoder compute, zero extra pool bytes);
        ``patch_embeds`` (P, vit_dim) for vision targets prepends P patch
        positions, offsetting the target lane's lengths by P.  Conditioned
        streams skip prefix-cache adoption/registration (their KV depends
        on the conditioning, not only on the token prefix)."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        assert len(prompt) >= 2, "need >= 2 prompt tokens"
        cond = frame_embeds is not None or patch_embeds is not None
        toff = 0
        if patch_embeds is not None:
            patch_embeds = np.asarray(patch_embeds)
            if patch_embeds.ndim == 2:
                patch_embeds = patch_embeds[None]
            toff = int(patch_embeds.shape[1])
            assert self.target_cheap, \
                "patch conditioning needs an attention/MLA-only target"
            if reserve_tokens is not None:
                reserve_tokens += toff       # patches occupy pool positions
        assert len(prompt) + self.gamma_max + 2 + toff <= self.max_len, \
            "prompt cannot fit a single session within max_len"
        pre = prompt[:-1]                    # invariant: length = len(seq) - 1
        adopted = self._admit_blocks(slot, prompt, reserve_tokens,
                                     use_prefix=not cond)
        rest = pre[adopted:]
        self.prefill_tokens_skipped += adopted
        self.prefill_tokens_computed += len(rest)
        enc_seg = None
        if frame_embeds is not None:
            assert self.enc_pool is not None, \
                "frame_embeds needs an enc-dec target"
            enc_seg = self._adopt_encoder_segment(slot, frame_embeds)
        self.dcache = self._place_cache(
            self._prefill_lane("draft", self.dcache, slot, rest), paged=True)
        if patch_embeds is not None:
            self.tcache = self._prefill_vlm_lane(slot, rest, patch_embeds)
        else:
            self.tcache = self._place_cache(
                self._prefill_lane("target", self.tcache, slot, rest),
                paged=True)
        self._dlen[slot] = len(pre)
        self._tlen[slot] = len(pre) + toff
        self._toff[slot] = toff
        st = self._new_stream_state(slot, prompt, eos_id, resume_from)
        st["cond"] = cond
        st["enc_seg"] = enc_seg
        self._register_prefix(slot)
        return st

    @_on_mesh
    def open_stream_chunked(self, slot: int, prompt: List[int],
                            eos_id: Optional[int] = None,
                            reserve_tokens: Optional[int] = None,
                            resume_from: Optional[GenResult] = None) -> dict:
        """``open_stream`` that RESERVES but does not prefill: blocks (and
        any prefix-cache adoption) happen now, the prompt's non-shared
        suffix is fed later in bounded per-tick chunks via
        ``prefill_step``.  Until the prompt is fully fed the slot is
        occupied but inactive (``active_mask`` excludes it), so in-flight
        decode ticks never stall behind a long admission prefill."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        assert len(prompt) >= 2, "need >= 2 prompt tokens"
        assert len(prompt) + self.gamma_max + 2 <= self.max_len, \
            "prompt cannot fit a single session within max_len"
        pre = prompt[:-1]
        adopted = self._admit_blocks(slot, prompt, reserve_tokens)
        self.prefill_tokens_skipped += adopted
        self._dlen[slot] = adopted
        self._tlen[slot] = adopted
        st = self._new_stream_state(slot, prompt, eos_id, resume_from)
        if adopted >= len(pre):              # full prefix hit: nothing to feed
            self._dlen[slot] = len(pre)
            self._tlen[slot] = len(pre)
            self.dcache = {**self.dcache, "lengths":
                           self.dcache["lengths"].at[slot].set(len(pre))}
            self.tcache = {**self.tcache, "lengths":
                           self.tcache["lengths"].at[slot].set(len(pre))}
            self._register_prefix(slot)
            return st
        st["prefilling"] = True
        st["prefill_rest"] = pre[adopted:]
        st["prefill_pos"] = 0
        return st

    @_on_mesh
    def prefill_step(self, slot: int,
                     max_tokens: Optional[int] = None) -> int:
        """Feed up to ``max_tokens`` more prompt tokens into a slot opened
        by ``open_stream_chunked`` (at least one schedule window makes
        progress even when the budget is smaller).  Follows the SAME
        whole-chunks-then-singles schedule as monolithic prefill, so a
        prompt fed over many ticks lands bit-identical KV.  Returns the
        tokens fed; on the last chunk the slot flips active and registers
        its prefix-cache blocks."""
        st = self.slots[slot]
        assert st is not None and st.get("prefilling"), \
            f"slot {slot} is not mid-prefill"
        rest, pos = st["prefill_rest"], st["prefill_pos"]
        budget = len(rest) - pos if max_tokens is None else max_tokens
        fed = 0
        for lo, hi in _chunk_schedule(len(rest), self.prefill_chunk):
            if hi <= pos:                    # fed in an earlier call
                continue
            if fed and fed + (hi - lo) > budget:
                break
            toks = np.asarray(rest[lo:hi], np.int32)[None]
            self.dcache = self._chunk_feed_lane("draft", self.dcache, slot,
                                                toks, hi - lo)
            self.tcache = self._chunk_feed_lane("target", self.tcache, slot,
                                                toks, hi - lo)
            fed += hi - lo
            pos = hi
        st["prefill_pos"] = pos
        self._dlen[slot] += fed
        self._tlen[slot] += fed
        self.prefill_tokens_computed += fed
        self.dcache = self._place_cache(self.dcache, paged=True)
        self.tcache = self._place_cache(self.tcache, paged=True)
        if pos >= len(rest):
            st["prefilling"] = False
            del st["prefill_rest"], st["prefill_pos"]
            self._register_prefix(slot)
        return fed

    def preempt_stream(self, slot: int) -> dict:
        """Evict a running (or mid-prefill) stream and return a frozen
        handle for later resume.  O(1) per block: the stream's full blocks
        below its write frontier are registered in the prefix cache FIRST
        (refcount keeps them warm across the release), so resuming via
        ``open_stream(frozen["seq"], resume_from=frozen["res"])`` adopts
        the KV computed so far instead of recomputing it — at most the
        sub-block frontier tail is re-prefilled.  The pending tick must be
        flushed first (preemption between flush and launch)."""
        assert self._pending is None, "flush the pending tick before preempt"
        st = self.slots[slot]
        assert st is not None, f"slot {slot} empty"
        assert not st.get("cond"), \
            "conditioned streams cannot be preempted (the resume handle " \
            "carries tokens only, not the conditioning)"
        self._register_prefix(slot)
        self.preemptions += 1
        frozen = self.close_stream(slot)
        return {"seq": list(frozen["seq"]), "res": frozen["res"],
                "eos_id": frozen["eos_id"]}

    def _new_stream_state(self, slot: int, prompt: List[int],
                          eos_id: Optional[int],
                          resume_from: Optional[GenResult]) -> dict:
        seq = list(prompt)
        if resume_from is not None:
            res = resume_from
            res.tokens = seq                 # res tracks the live seq again
            self.resumes += 1
        else:
            res = GenResult(tokens=seq, prompt_len=len(prompt))
        st = {"seq": seq, "res": res, "done": False, "eos_id": eos_id}
        self.slots[slot] = st
        return st

    def _register_prefix(self, slot: int) -> None:
        """Register ``slot``'s full blocks strictly below its draft write
        frontier (positions the stream can never rewrite, so the cached KV
        stays bit-exact for the blocks' whole cache lifetime).  At rest
        the frontier is ``len(seq) - 2``; mid-prefill it is the prefill
        position, whichever is lower."""
        if self.prefix_cache is None or self.slots[slot].get("cond"):
            return
        seq = self.slots[slot]["seq"]
        upto = min(int(self._dlen[slot]), len(seq) - 2)
        n_reg = upto // self.block_size
        if n_reg > 0:
            self.prefix_cache.insert(
                seq, n_reg,
                (self.dalloc.owned[slot], self.talloc.owned[slot]))

    def _admit_blocks(self, slot: int, prompt: List[int],
                      reserve_tokens: Optional[int], *,
                      use_prefix: bool = True) -> int:
        """Block-reservation half of admission: adopt what the prefix
        cache holds, evict/allocate the rest, point the slot's tables at
        the run, privatize the draft's COW frontier.  Returns the adopted
        token count (device lengths are set to it; the caller prefills
        ``prompt[adopted:-1]``).  ``use_prefix=False`` (conditioned
        streams) skips adoption — their KV is not a pure token function."""
        need = self.reserve_blocks_for(reserve_tokens or self.max_len)
        seq = list(prompt)
        n_adopt, runs, n_cow = (self._adoptable(prompt, touch=True)
                                if use_prefix else (0, None, 0))
        need = max(need, n_adopt)
        need_new = need - n_adopt + n_cow
        # Pin the adopted run BEFORE any eviction: until ``share`` runs the
        # matched chunks are refcount==1 (cache-owned only), so a
        # deficit-driven evict could free the very blocks being adopted.
        # The pin also takes them out of ``evictable_chunks`` below, so the
        # feasibility check cannot count on reclaiming them.
        if n_adopt:
            for alloc, run in zip((self.dalloc, self.talloc), runs):
                for b in run[:n_adopt]:
                    alloc.addref(int(b))
        try:
            deficit = max(need_new - len(self.dalloc.free),
                          need_new - len(self.talloc.free))
            if deficit > 0:
                evictable = (self.prefix_cache.evictable_chunks()
                             if self.prefix_cache is not None else 0)
                if deficit > evictable:
                    # doomed admission: backpressure WITHOUT flushing warm
                    # prefixes the request cannot use anyway
                    raise PoolExhausted(
                        f"{need_new} blocks unavailable for admission "
                        f"({deficit - evictable} short after eviction)")
                self.prefix_cache.evict(deficit)
            if not (self.dalloc.can_allocate(need_new)
                    and self.talloc.can_allocate(need_new)):
                raise PoolExhausted(
                    f"{need_new} blocks unavailable for admission")
            if n_adopt:
                self.dalloc.share(slot, runs[0][:n_adopt])
                self.talloc.share(slot, runs[1][:n_adopt])
                self.dalloc.extend(slot, need - n_adopt)
                self.talloc.extend(slot, need - n_adopt)
            else:
                self.dalloc.allocate(slot, need)
                self.talloc.allocate(slot, need)
        finally:
            # drop the admission pin: the cache ref (and, on success, the
            # stream's ``share`` ref) keep the blocks alive
            if n_adopt:
                for alloc, run in zip((self.dalloc, self.talloc), runs):
                    for b in run[:n_adopt]:
                        alloc.decref(int(b))
        adopted = n_adopt * self.block_size
        self.dcache = {**self.dcache,
                       "tables": jnp.asarray(self.dalloc.tables),
                       "lengths": self.dcache["lengths"].at[slot].set(adopted)}
        self.tcache = {**self.tcache,
                       "tables": jnp.asarray(self.talloc.tables),
                       "lengths": self.tcache["lengths"].at[slot].set(adopted)}
        if not self.draft_cheap:
            self.dcache = self._reset_lane_state(self.dcache, slot)
        if not self.target_cheap:
            self.tcache = self._reset_lane_state(self.tcache, slot)
        if n_adopt:
            # copy-on-first-divergent-write: privatize any adopted block the
            # stream will write into (draft refeeds from P-2, target from
            # P-1 — at most the draft's one frontier block, see _adoptable)
            self.dcache = self._cow_frontier("draft", slot, len(seq) - 2)
            self.tcache = self._cow_frontier("target", slot, len(seq) - 1)
        return adopted

    def _cow_frontier(self, which: str, slot: int, first_write_pos: int):
        """Privatize every non-writable block of ``slot`` that overlaps the
        write range ``[first_write_pos, ...)``: allocate a fresh block, copy
        the shared block's pool rows (all leaves, int8 scales included),
        repoint the table row, drop the shared reference."""
        alloc = self.dalloc if which == "draft" else self.talloc
        cache = self.dcache if which == "draft" else self.tcache
        copied = False
        start = max(first_write_pos, 0) // self.block_size
        for idx in range(start, len(alloc.owned[slot])):
            if not alloc.writable(slot, idx):
                src, dst = alloc.cow(slot, idx)
                cache = paged_copy_block(cache, src, dst)
                self.cow_copies += 1
                copied = True
        if copied:
            cache = {**cache, "tables": jnp.asarray(alloc.tables)}
        return cache

    def _assert_cow_safety(self) -> None:
        """Every active lane's write range THIS TICK (draft from L-2,
        target from L-1, at most gamma_max tokens ahead) must sit in
        sole-owner, non-immutable blocks — speculative writes and rollback
        can then never touch a block another stream or the cache still
        references.  Only the tick's write window is checked (a handful of
        blocks per lane, not the whole reservation): blocks past it are
        fresh private extends that nothing can alias before the frontier
        reaches them, and checking them every launch made this O(slots x
        owned_blocks) host work in the serving hot path."""
        bs = self.block_size
        for s in np.flatnonzero(self.active_mask()):
            L = len(self.slots[int(s)]["seq"])
            hi = (L + self.gamma_max) // bs       # last block written this tick
            for alloc, first in ((self.dalloc, L - 2), (self.talloc, L - 1)):
                owned = alloc.owned[int(s)]
                for idx in range(max(first, 0) // bs,
                                 min(len(owned), hi + 1)):
                    assert alloc.writable(int(s), idx), (
                        f"slot {s}: write-frontier block {owned[idx]} "
                        f"(logical {idx}) is shared/immutable — COW missed")

    def close_stream(self, slot: int) -> dict:
        """Release a slot: blocks return to the pool, its table row points
        at the trash block again (and any adopted encoder segment drops a
        reference — last release frees the segment for reuse)."""
        st = self.slots[slot]
        assert st is not None
        self.slots[slot] = None
        self.dalloc.release(slot)
        self.talloc.release(slot)
        self._dlen[slot] = 0
        self._tlen[slot] = 0
        self._toff[slot] = 0
        tcache = {**self.tcache, "tables": jnp.asarray(self.talloc.tables),
                  "lengths": self.tcache["lengths"].at[slot].set(0)}
        if st.get("enc_seg"):
            self.enc_pool.release(int(st["enc_seg"]))
            tcache["cross_seg"] = tcache["cross_seg"].at[slot].set(0)
        self.dcache = self._place_cache(
            {**self.dcache, "tables": jnp.asarray(self.dalloc.tables),
             "lengths": self.dcache["lengths"].at[slot].set(0)}, paged=True)
        self.tcache = self._place_cache(tcache, paged=True)
        return st

    # -------------------------------------------------------- tick
    def session_step_batch(self) -> List[int]:
        """One batched draft/verify session across every active slot
        (one synchronous tick: launch + flush back to back)."""
        self.session_step_launch()
        return self.session_step_flush()

    @_on_mesh
    def session_step_launch(self) -> bool:
        """Dispatch one tick without reading its outcomes back (see
        ``BatchedSpecEngine.session_step_launch`` — identical protocol,
        with per-lane length mirrors instead of pointer mirrors)."""
        assert self._pending is None, "previous tick not flushed"
        B, g = self.batch_size, self.gamma_max
        active = self.active_mask()
        act_idx = np.flatnonzero(active)
        if act_idx.size == 0:
            return False
        if __debug__ and self.prefix_cache is not None:
            self._assert_cow_safety()
        if not self.fused or self._toff.any():
            # offset streams (vision-conditioned lanes) take the sync tick:
            # the fused program rolls BOTH models back from one shared
            # lengths vector, which an asymmetric target offset would break
            self._pending = {"acted": self._session_step_sync()}
            return True

        L = np.array([len(self.slots[s]["seq"]) if self.slots[s] else 0
                      for s in range(B)], np.int64)
        arm_mat = np.zeros((B, g), np.int32)
        arm_mat[act_idx] = self.controller.begin_batch(act_idx.size)
        in_toks = np.zeros((B, 2), np.int32)
        last_toks = np.zeros((B, 1), np.int32)
        for s in act_idx:
            seq = self.slots[s]["seq"]
            in_toks[s] = seq[-2:]
            last_toks[s, 0] = seq[-1]
        keys = self._next_rng(2 * B)
        ft = self._fused_tick(
            self.draft.params, self.target.params, self.dcache, self.tcache,
            jnp.asarray(in_toks), jnp.asarray(last_toks),
            jnp.asarray(arm_mat), jnp.float32(self.controller.lam),
            keys[:B], keys[B:], jnp.asarray(active),
            jnp.asarray(L, jnp.int32), jnp.asarray(self._dlen, jnp.int32),
            jnp.asarray(self._tlen, jnp.int32))
        self.dcache, self.tcache = ft.dcache, ft.tcache
        self._pending = {"act_idx": act_idx, "active": active,
                         "arm_mat": arm_mat, "L": L, "ft": ft}
        return True

    @_on_mesh
    def session_step_flush(self) -> List[int]:
        """Host accounting for the pending tick + the bandit update."""
        pending, self._pending = self._pending, None
        if pending is None:
            return []
        if "acted" in pending:
            return pending["acted"]
        active, act_idx = pending["active"], pending["act_idx"]
        arm_mat, L, ft = pending["arm_mat"], pending["L"], pending["ft"]
        g = self.gamma_max
        c_d = self.draft.cost_per_token
        c_t = self.target.cost_per_token
        nd = np.asarray(ft.n_drafted)
        m = np.asarray(ft.n_accepted)
        out_all = np.asarray(ft.out_tokens)
        dens = (self._routing_density_rows(self.tcache)
                if self._routed_frac > 0.0 else None)
        if self.collect_traces:
            sig_all = np.asarray(ft.signals)
            ent_all = np.asarray(ft.entropies)
        for s in act_idx:
            st = self.slots[s]
            seq, res = st["seq"], st["res"]
            out = out_all[s, :m[s] + 1].tolist()
            seq.extend(out)
            res.sessions.append(SessionStats(int(nd[s]), int(m[s]),
                                             int(arm_mat[s, 0])))
            density = 1.0
            if dens is not None:
                density = float(dens[s])
                self._moe_density_sum += density
                self._moe_sessions += 1
            res.modeled_cost += modeled_session_cost(
                int(nd[s]) + 1, c_d, c_t, routed_frac=self._routed_frac,
                routing_density=density)
            if self.collect_traces:
                res.traces.append({
                    "signals": sig_all[s], "entropies": ent_all[s],
                    "n_drafted": int(nd[s]), "n_accepted": int(m[s]),
                    "position_base": 0})
            eos = st["eos_id"]
            if eos is not None and eos in out:
                seq[:] = seq[:len(seq) - len(out) + out.index(eos) + 1]
                st["done"] = True
            if len(seq) + g + 2 >= self.max_len:
                st["done"] = True
        self._tlen = np.where(active, L + m, self._tlen)
        self._dlen = np.where(active, L + m - 1, self._dlen)
        self.controller.update_batch(arm_mat[act_idx], nd[act_idx], m[act_idx])
        return act_idx.tolist()

    def _session_step_sync(self) -> List[int]:
        """The classic two-dispatch tick (recurrent stacks only)."""
        B, g = self.batch_size, self.gamma_max
        active = self.active_mask()
        act_idx = np.flatnonzero(active)
        if act_idx.size == 0:
            return []
        c_d = self.draft.cost_per_token
        c_t = self.target.cost_per_token
        L = np.array([len(self.slots[s]["seq"]) if self.slots[s] else 0
                      for s in range(B)], np.int64)

        arm_mat = np.zeros((B, g), np.int32)
        arm_mat[act_idx] = self.controller.begin_batch(act_idx.size)

        n_in = 2 if self.draft_cheap else 1
        in_toks = np.zeros((B, n_in), np.int32)
        last_toks = np.zeros((B, 1), np.int32)
        for s in act_idx:
            seq = self.slots[s]["seq"]
            in_toks[s] = seq[-n_in:]
            last_toks[s, 0] = seq[-1]

        if self.draft_cheap:
            # O(1) paged rollback INTO the session: truncate each active
            # lane to L-2 and refeed the last two tokens (same invariant
            # as the dense pointer-rollback path)
            dlen_in = np.where(active, L - 2, self._dlen)
            dcache_in = paged_rollback(self.dcache, dlen_in)
            dsnap = None
        else:
            dsnap = self.dcache
            dcache_in = self.dcache
        tsnap = None if self.target_cheap else self.tcache

        keys = self._next_rng(2 * B)
        active_dev = jnp.asarray(active)

        if self._sharded_sessions is not None:
            draft_fn, verify_fn = self._sharded_sessions
            dres = draft_fn(self.draft.params, dcache_in,
                            jnp.asarray(in_toks), jnp.asarray(arm_mat),
                            jnp.float32(self.controller.lam), keys[:B],
                            active_dev)
            vres = verify_fn(self.target.params, self.tcache,
                             jnp.asarray(last_toks), dres.tokens,
                             dres.n_drafted, dres.qprobs, keys[B:],
                             active_dev)
        else:
            dres = draft_session_paged(
                self.draft.params, self.draft.cfg, self.dspec, dcache_in,
                jnp.asarray(in_toks), jnp.asarray(arm_mat),
                jnp.float32(self.controller.lam), keys[:B], active_dev,
                arms=self.controller.arms, gamma_max=g,
                temperature=self.temperature, n_prompt_tokens=n_in)
            vres = verify_session_paged(
                self.target.params, self.target.cfg, self.tspec, self.tcache,
                jnp.asarray(last_toks), dres.tokens, dres.n_drafted,
                dres.qprobs, keys[B:], active_dev, gamma_max=g,
                temperature=self.temperature, greedy=self.greedy)

        nd = np.asarray(dres.n_drafted)
        m = np.asarray(vres.n_accepted)
        out_all = np.asarray(vres.out_tokens)
        dens = (self._routing_density_rows(vres.cache)
                if self._routed_frac > 0.0 else None)
        if self.collect_traces:
            sig_all = np.asarray(dres.signals)
            ent_all = np.asarray(dres.entropies)

        feeds = {}
        for s in act_idx:
            st = self.slots[s]
            seq, res = st["seq"], st["res"]
            out = out_all[s, :m[s] + 1].tolist()
            feeds[s] = np.asarray([seq[-1:] + out[:-1]], np.int32)
            seq.extend(out)
            res.sessions.append(SessionStats(int(nd[s]), int(m[s]),
                                             int(arm_mat[s, 0])))
            density = 1.0
            if dens is not None:
                density = float(dens[s])
                self._moe_density_sum += density
                self._moe_sessions += 1
            res.modeled_cost += modeled_session_cost(
                int(nd[s]) + n_in - 1, c_d, c_t,
                routed_frac=self._routed_frac, routing_density=density)
            if self.collect_traces:
                res.traces.append({
                    "signals": sig_all[s], "entropies": ent_all[s],
                    "n_drafted": int(nd[s]), "n_accepted": int(m[s]),
                    "position_base": 0})
            eos = st["eos_id"]
            if eos is not None and eos in out:
                seq[:] = seq[:len(seq) - len(out) + out.index(eos) + 1]
                st["done"] = True
            if len(seq) + g + 2 + int(self._toff[s]) >= self.max_len:
                st["done"] = True

        # ---- rollback: ONE length truncation per model (all layer kinds);
        # the target's truncation carries each lane's position offset
        if self.target_cheap:
            self._tlen = np.where(active, L + m + self._toff, self._tlen)
            self.tcache = paged_rollback(vres.cache, self._tlen)
        else:
            self.tcache = self._place_cache(
                self._readvance("target", tsnap, active, feeds), paged=True)
            self._tlen = np.where(active, L + m, self._tlen)
        if self.draft_cheap:
            self._dlen = np.where(active, L + m - 1, self._dlen)
            self.dcache = paged_rollback(dres.cache, self._dlen)
        else:
            self.dcache = self._place_cache(
                self._readvance("draft", dsnap, active, feeds), paged=True)
            self._dlen = np.where(active, L + m, self._dlen)

        self.controller.update_batch(arm_mat[act_idx], nd[act_idx], m[act_idx])
        return act_idx.tolist()

    def _readvance(self, which: str, snap, active, feeds):
        """Snapshot-recompute for recurrent state: restore the pre-tick
        cache, re-feed each active lane's accepted tokens.  (The refeed
        also rewrites those lanes' pool rows — with identical values, since
        positions and tokens are identical.)"""
        cache = snap
        for s in np.flatnonzero(active):
            cache = self._advance_lane(which, cache, int(s), feeds[int(s)])
        return cache

    # -------------------------------------------------------- stats
    def pool_stats(self) -> dict:
        def pool_bytes(cache, per_shard=False):
            total = 0
            def f(path, a):
                nonlocal total
                if _path_keys(path)[-1] in _POOL_KEYS:
                    n = a.size
                    if per_shard:
                        n = int(np.prod(a.sharding.shard_shape(a.shape)))
                    total += n * a.dtype.itemsize
                return a
            jax.tree_util.tree_map_with_path(f, cache["layers"])
            return total
        stats = {
            "block_size": self.block_size,
            "pool_tokens": self.pool_tokens,
            "num_blocks": self.dspec.num_blocks,
            "cache_pool_bytes": pool_bytes(self.dcache) + pool_bytes(self.tcache),
            "blocks_in_use": self.dalloc.blocks_in_use + self.talloc.blocks_in_use,
            "peak_blocks_in_use": (self.dalloc.peak_in_use
                                   + self.talloc.peak_in_use),
            "shared_blocks_in_use": (
                self.dalloc.sharing_stats()["shared_blocks"]
                + self.talloc.sharing_stats()["shared_blocks"]),
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "cow_copies": self.cow_copies,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
        }
        if self.prefix_cache is not None:
            stats["prefix_cache"] = self.prefix_cache.stats()
        if self.enc_pool is not None:
            stats["encoder_segments"] = self.enc_pool.stats()
        if self.mesh is not None:
            # per-shard residency: the "model"-sharded pools split their
            # bytes across tensor-parallel shards; block accounting is
            # global (one host-side allocator feeds every shard's tables)
            stats["mesh_devices"] = int(self.mesh.devices.size)
            stats["mesh_axes"] = {k: int(v)
                                  for k, v in self.mesh.shape.items()}
            stats["cache_pool_bytes_per_shard"] = (
                pool_bytes(self.dcache, per_shard=True)
                + pool_bytes(self.tcache, per_shard=True))
        return stats

    def describe(self) -> dict:
        d = super().describe()
        d["pool"] = self.pool_stats()
        return d


# ===================================================================== spec

BACKENDS = ("auto", "single", "batched", "paged", "tree", "tree_slot")


@dataclass(frozen=True)
class EngineSpec:
    """One declarative description of a speculative-serving deployment.

    ``make_engine(draft, target, controller, spec)`` — and
    ``SpecServer(..., spec=...)`` — turn a spec into the right engine, so
    the five engine constructors stop being public API surface.  Fields
    are grouped by what they control; every backend ignores the fields
    that don't apply to it (docs/serving.md has the migration table from
    the old per-engine kwargs).

    * ``backend`` — "single" | "batched" | "paged" | "tree" | "tree_slot",
      or "auto": "paged" when ``pool_tokens`` is set, else "batched" when
      ``batch_size > 1``, else "single".
    * ``batch_size`` — slot count for the slot engines (the old
      ``max_concurrency`` server kwarg).
    * ``fused`` — single-dispatch ragged tick for the batched/paged
      backends (auto-disabled on recurrent stacks).
    * ``tree_paged`` — back the tree backends with B=1 paged pools.
    * precision: ``cache_dtype`` / ``kv_dtype`` ("int8" KV caches) /
      ``quant_draft`` (int8 draft weights).
    * ``prefix_cache`` — paged backend only: refcounted copy-on-write
      prefix sharing with a hashed prefill cache (docs/prefix_sharing.md).
      Streams admitted with an already-cached prompt prefix alias the
      cached blocks instead of re-prefilling them.
    * placement: ``mesh`` (docs/sharding.md).
    * ``drafters`` — a ``core.drafters.DrafterPool``: heterogeneous
      drafter serving on the batched backend (drafter identity as a bandit
      arm, docs/drafters.md).  The pool's default drafter replaces the
      positional ``draft`` bundle; the controller must be a shape
      controller over (drafter x stop-rule) arms.
    """
    backend: str = "auto"
    batch_size: int = 4
    max_len: int = 2048
    temperature: float = 0.0
    greedy: bool = True
    cache_dtype: object = jnp.float32
    kv_dtype: Optional[str] = None
    quant_draft: bool = False
    seed: int = 0
    prefill_chunk: int = 16
    block_size: int = 64
    pool_tokens: Optional[int] = None
    prefix_cache: bool = False
    tree_paged: bool = False
    fused: bool = True
    mesh: object = None
    drafters: object = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")

    def resolve_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        if self.drafters is not None:
            return "batched"
        if self.pool_tokens is not None:
            return "paged"
        return "batched" if self.batch_size > 1 else "single"


def engine_spec_from_legacy(*, max_len: int = 2048,
                            max_concurrency: int = 8,
                            temperature: float = 0.0, greedy: bool = True,
                            seed: int = 0, paged: bool = False,
                            block_size: int = 64,
                            pool_tokens: Optional[int] = None,
                            tree: bool = False,
                            kv_dtype: Optional[str] = None,
                            quant_draft: bool = False,
                            mesh=None) -> EngineSpec:
    """Map the pre-spec ``SpecServer`` keyword surface onto an
    ``EngineSpec`` (the deprecation shim's translation table)."""
    if tree:
        assert not paged, "tree serving uses per-slot dense caches"
        backend = "tree_slot"
    elif paged:
        backend = "paged"
    else:
        backend = "batched"
    return EngineSpec(backend=backend, batch_size=max_concurrency,
                      max_len=max_len, temperature=temperature,
                      greedy=greedy, seed=seed, block_size=block_size,
                      pool_tokens=pool_tokens, kv_dtype=kv_dtype,
                      quant_draft=quant_draft, mesh=mesh)


def make_engine(draft: ModelBundle, target: ModelBundle,
                controller: Controller, spec: Optional[EngineSpec] = None,
                **fields):
    """THE engine factory: build the backend ``spec`` describes.

    ``make_engine(d, t, c, spec)`` or — convenience — field overrides
    directly: ``make_engine(d, t, c, backend="paged", pool_tokens=4096)``
    (with both, the overrides win via ``dataclasses.replace``)."""
    if spec is None:
        spec = EngineSpec(**fields)
    elif fields:
        spec = replace(spec, **fields)
    backend = spec.resolve_backend()
    if spec.drafters is not None and backend != "batched":
        raise ValueError(
            "drafter pools are a batched-backend feature (got "
            f"backend={backend!r})")
    common = dict(max_len=spec.max_len, temperature=spec.temperature,
                  greedy=spec.greedy, cache_dtype=spec.cache_dtype,
                  kv_dtype=spec.kv_dtype, quant_draft=spec.quant_draft,
                  seed=spec.seed, mesh=spec.mesh)
    if backend == "single":
        return SpecEngine(draft, target, controller, **common)
    if backend == "batched":
        return BatchedSpecEngine(draft, target, controller,
                                 batch_size=spec.batch_size,
                                 prefill_chunk=spec.prefill_chunk,
                                 fused=spec.fused,
                                 drafters=spec.drafters, **common)
    if backend == "paged":
        return PagedSpecEngine(draft, target, controller,
                               batch_size=spec.batch_size,
                               block_size=spec.block_size,
                               pool_tokens=spec.pool_tokens,
                               prefill_chunk=spec.prefill_chunk,
                               fused=spec.fused,
                               prefix_cache=spec.prefix_cache, **common)
    assert isinstance(controller, TapOutTreeSequence), \
        f"{backend} backend needs a TapOutTreeSequence controller"
    if backend == "tree":
        return TreeSpecEngine(draft, target, controller,
                              paged=spec.tree_paged,
                              block_size=spec.block_size, **common)
    return TreeSlotEngine(draft, target, controller,
                          batch_size=spec.batch_size,
                          paged=spec.tree_paged,
                          block_size=spec.block_size, **common)
