"""Tree speculation: static draft-tree topologies + longest-accepted-path
verification.

A ``TreeSpec`` encodes a speculation tree as a parent-index array in level
(BFS) order: node ``i`` hangs off ``parents[i]`` (``-1`` = child of the last
committed token).  Every derived quantity the engines and kernels need is
precomputed once per topology and cached on the (frozen, hashable) spec:

  * ``depths``        — node depth (root = 0); node position = pos0 + depth.
  * ``levels``        — node-index tuples per depth (contiguous, in node
                        order, because specs are level-ordered).
  * ``ancestor_mask`` — (T, T) bool, ``mask[i, j]`` iff node ``j`` is ``i``
                        itself or an ancestor of ``i``.  This is the
                        attention visibility rule INSIDE the tree (siblings
                        share RoPE positions, so positional causal masking
                        cannot separate them — the explicit mask can).
  * ``verify_mask`` / ``verify_depths`` — the (1+T)-node extension that
                        prepends the last committed token as node 0 (an
                        ancestor of everything), so one target forward
                        yields the root distribution AND every node's
                        distribution — the tree analog of the chain
                        verifier's ``[last_token] + drafted`` feed.

Verification (``verify_walk``) picks the LONGEST ACCEPTED PATH from the
root:  greedy mode accepts the unique child matching the target argmax at
each step (so greedy tree decoding reproduces target-only greedy decoding
exactly, as chain speculation does); stochastic mode runs SpecInfer-style
recursive rejection over the sibling set — accept child ``c`` with prob
``min(1, p(x_c)/q(x_c))``, else deduct ``q`` from the residual and try the
next sibling — and samples the replacement token at the divergence node
from the final residual, so the output distribution equals the target
model's when siblings are drawn i.i.d. from the draft distribution (which
``TreeSpecEngine`` does in stochastic mode; greedy mode uses top-k).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class TreeSpec:
    """Static speculation-tree topology (hashable -> jit static arg)."""

    parents: Tuple[int, ...]   # parents[i] in [-1, i); level (BFS) order
    name: str = "tree"

    def __post_init__(self):
        assert len(self.parents) >= 1, "empty tree"
        depths: List[int] = []
        for i, p in enumerate(self.parents):
            assert -1 <= p < i, f"parents[{i}]={p} must be in [-1, {i})"
            d = 0 if p == -1 else depths[p] + 1
            # level order: depths non-decreasing in node order, so each
            # level occupies a contiguous node-index range
            assert not depths or d >= depths[-1], "not level-ordered"
            depths.append(d)

    # ------------------------------------------------------------ derived
    @property
    def n_nodes(self) -> int:
        return len(self.parents)

    @functools.cached_property
    def depths(self) -> np.ndarray:
        """(T,) int32 node depths (roots = 0)."""
        d = np.zeros(self.n_nodes, np.int32)
        for i, p in enumerate(self.parents):
            d[i] = 0 if p == -1 else d[p] + 1
        return d

    @property
    def max_depth(self) -> int:
        """Longest root-to-leaf path length in TOKENS (depth+1)."""
        return int(self.depths.max()) + 1

    @functools.cached_property
    def levels(self) -> Tuple[Tuple[int, ...], ...]:
        """Node indices per depth; contiguous ranges for level-ordered specs."""
        out: List[List[int]] = [[] for _ in range(self.max_depth)]
        for i, d in enumerate(self.depths):
            out[int(d)].append(i)
        return tuple(tuple(l) for l in out)

    @functools.cached_property
    def children(self) -> Tuple[Tuple[int, ...], ...]:
        """children[i] = nodes whose parent is i (sibling order = node order)."""
        out: List[List[int]] = [[] for _ in range(self.n_nodes)]
        for i, p in enumerate(self.parents):
            if p >= 0:
                out[p].append(i)
        return tuple(tuple(c) for c in out)

    @property
    def roots(self) -> Tuple[int, ...]:
        return tuple(i for i, p in enumerate(self.parents) if p == -1)

    @functools.cached_property
    def ancestor_mask(self) -> np.ndarray:
        """(T, T) bool: mask[i, j] iff j == i or j is an ancestor of i."""
        T = self.n_nodes
        m = np.eye(T, dtype=bool)
        for i, p in enumerate(self.parents):
            if p >= 0:
                m[i] |= m[p]
        return m

    # ----------------------------------------------- verify extension
    @functools.cached_property
    def verify_depths(self) -> np.ndarray:
        """(1+T,) depths with the last committed token prepended at depth 0
        (tree nodes shift to depth+1); node position = (pos0 - 1) + depth."""
        return np.concatenate([[0], self.depths + 1]).astype(np.int32)

    @functools.cached_property
    def verify_mask(self) -> np.ndarray:
        """(1+T, 1+T) ancestor mask of the verify feed: node 0 (the last
        committed token) is an ancestor of every tree node."""
        T = self.n_nodes
        m = np.zeros((T + 1, T + 1), dtype=bool)
        m[:, 0] = True
        m[1:, 1:] = self.ancestor_mask
        return m

    # ------------------------------------------------------------ misc
    def __str__(self) -> str:
        return f"TreeSpec({self.name}, T={self.n_nodes}, D={self.max_depth})"


# ------------------------------------------------------------- templates

@functools.lru_cache(maxsize=None)
def chain(depth: int) -> TreeSpec:
    """Linear chain of ``depth`` nodes — the degenerate tree whose greedy
    run is token-identical to the chain engine at static gamma = depth."""
    assert depth >= 1
    return TreeSpec(tuple(range(-1, depth - 1)), name=f"chain{depth}")


@functools.lru_cache(maxsize=None)
def from_branching(branching: Tuple[int, ...], name: Optional[str] = None) -> TreeSpec:
    """branching[d] children per level-(d-1) node (branching[0] roots)."""
    assert len(branching) >= 1 and all(b >= 1 for b in branching)
    parents: List[int] = [-1] * branching[0]
    prev = list(range(branching[0]))
    for b in branching[1:]:
        cur = []
        for p in prev:
            for _ in range(b):
                cur.append(len(parents))
                parents.append(p)
        prev = cur
    nm = name or "b" + "x".join(str(b) for b in branching)
    return TreeSpec(tuple(parents), name=nm)


def binary(depth: int) -> TreeSpec:
    """Full binary tree: 2^(d+1) - 2 nodes at depth d levels."""
    return from_branching((2,) * depth, name=f"binary{depth}")


def wide(k: int, depth: int) -> TreeSpec:
    """k independent chains of length ``depth`` (top-k at the root only)."""
    return from_branching((k,) + (1,) * (depth - 1), name=f"wide{k}x{depth}")


# ------------------------------------------------------- verification

def _norm_residual(p: np.ndarray, q: np.ndarray) -> np.ndarray:
    """norm(max(p - q, 0)); falls back to p when the residual vanishes."""
    r = np.maximum(p - q, 0.0)
    s = r.sum()
    return r / s if s > 1e-20 else p


def verify_walk(spec: TreeSpec, tokens: np.ndarray, q_node: np.ndarray,
                p_node: np.ndarray, *, greedy: bool = True,
                rng: Optional[np.random.Generator] = None
                ) -> Tuple[List[int], int]:
    """Longest-accepted-path verification (host side).

    tokens: (T,) drafted token per node.
    q_node: (T, V) the DRAFT distribution each node's token was drawn from
      (its parent's predictive distribution).
    p_node: (1+T, V) TARGET distributions of the verify feed — p_node[0]
      is the root distribution (at the last committed token), p_node[1+i]
      the distribution at tree node i.

    Returns (path, replacement): ``path`` the accepted node indices root ->
    leaf (possibly empty) and ``replacement`` the token appended after the
    path — target argmax / residual sample at the divergence node, or the
    bonus token when a full root-to-leaf path is accepted.
    """
    path: List[int] = []
    parent = -1
    p = p_node[0]
    while True:
        cands = spec.roots if parent == -1 else spec.children[parent]
        accepted = None
        if greedy:
            t_star = int(np.argmax(p))
            for c in cands:
                if int(tokens[c]) == t_star:
                    accepted = c
                    break
            if accepted is None:
                return path, t_star
        else:
            assert rng is not None, "stochastic walk needs an RNG"
            for c in cands:
                q = q_node[c]
                t = int(tokens[c])
                if rng.uniform() < min(1.0, float(p[t]) / max(float(q[t]), 1e-20)):
                    accepted = c
                    break
                p = _norm_residual(p, q)
            if accepted is None:
                return path, int(rng.choice(p.size, p=p / p.sum()))
        path.append(accepted)
        p = p_node[1 + accepted]
        parent = accepted
        if not spec.children[accepted]:        # full path accepted: bonus
            if greedy:
                return path, int(np.argmax(p))
            return path, int(rng.choice(p.size, p=p / p.sum()))


def ancestor_mask_oracle(parents: Sequence[int]) -> np.ndarray:
    """Transitive-closure reference for ``TreeSpec.ancestor_mask`` (used by
    the hypothesis property test): boolean matrix power of the (child ->
    parent) edge relation, OR-ed with identity."""
    T = len(parents)
    edge = np.zeros((T, T), dtype=np.int64)
    for i, p in enumerate(parents):
        if p >= 0:
            edge[i, p] = 1
    closure = np.eye(T, dtype=np.int64)
    reach = np.eye(T, dtype=np.int64)
    for _ in range(T):
        reach = np.minimum(reach @ edge, 1)
        if not reach.any():
            break
        closure |= reach
    return closure.astype(bool)
