from .arms import Arm, arm_by_name, default_pool, multi_threshold_pool
from .bandits import make_bandit, BanditBank
from .controller import (Controller, FixedArm, StaticGamma, TapOutSequence,
                         TapOutToken, make_controller)
from .engine import (BatchedSpecEngine, GenResult, ModelBundle,
                     PagedSpecEngine, SpecEngine)
from .rewards import r_blend, r_simple
from .spec_decode import (draft_session, draft_session_batched,
                          draft_session_paged, verify_session,
                          verify_session_batched, verify_session_paged)
