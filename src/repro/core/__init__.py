"""Public API of the speculative-decoding core.

Stable names the docs (``docs/index.md``) point at: arm pools and shape
arms (``arms``), bandit algorithms (``bandits``), controllers
(``controller``), generation engines (``engine``), reward/cost models
(``rewards``), the jitted draft/verify primitives (``spec_decode``),
static tree topologies (``tree``) and the heterogeneous drafter pool
(``drafters``).
"""
from .arms import (Arm, ShapeArm, arm_by_name, chain_shape, default_pool,
                   default_drafter_pool, default_shape_pool, drafter_shape,
                   multi_threshold_pool, quantized_shape, shape_cost_factor,
                   tree_shape)
from .bandits import make_bandit, BanditBank
from .controller import (Controller, FixedArm, FixedShape, StaticGamma,
                         TapOutSequence, TapOutToken, TapOutTreeSequence,
                         make_controller)
from .engine import (BatchedSpecEngine, EngineSpec, GenResult, ModelBundle,
                     PagedSpecEngine, SpecEngine, TreeSlotEngine,
                     TreeSpecEngine, engine_spec_from_legacy, make_engine,
                     quantized_bundle)
from .drafters import (Drafter, DrafterPool, default_drafters, eagle_bundle,
                       eagle_head_config, eagle_head_logits,
                       eagle_logit_params, init_eagle_head, load_eagle_head,
                       save_eagle_head, ssd_draft_bundle, ssd_draft_config,
                       train_eagle_head)
from .rewards import (drafter_state_bytes, kv_state_bytes,
                      modeled_session_cost, precision_cost_factor, r_blend,
                      r_cost_adjusted, r_simple, ssm_state_bytes)
from .spec_decode import (draft_session, draft_session_batched,
                          draft_session_paged, verify_session,
                          verify_session_batched, verify_session_paged)
from .tree import TreeSpec, binary, chain, from_branching, wide

__all__ = [
    # arms & shapes
    "Arm", "ShapeArm", "arm_by_name", "chain_shape", "default_pool",
    "default_drafter_pool", "default_shape_pool", "drafter_shape",
    "multi_threshold_pool", "quantized_shape", "shape_cost_factor",
    "tree_shape",
    # drafter pool
    "Drafter", "DrafterPool", "default_drafters", "eagle_bundle",
    "eagle_head_config", "eagle_head_logits", "eagle_logit_params",
    "init_eagle_head", "load_eagle_head", "save_eagle_head",
    "ssd_draft_bundle", "ssd_draft_config", "train_eagle_head",
    # bandits
    "make_bandit", "BanditBank",
    # controllers
    "Controller", "FixedArm", "FixedShape", "StaticGamma", "TapOutSequence",
    "TapOutToken", "TapOutTreeSequence", "make_controller",
    # engines
    "BatchedSpecEngine", "EngineSpec", "GenResult", "ModelBundle",
    "PagedSpecEngine", "SpecEngine", "TreeSlotEngine", "TreeSpecEngine",
    "engine_spec_from_legacy", "make_engine", "quantized_bundle",
    # rewards / cost model
    "drafter_state_bytes", "kv_state_bytes", "modeled_session_cost",
    "precision_cost_factor", "r_blend", "r_cost_adjusted", "r_simple",
    "ssm_state_bytes",
    # jitted primitives
    "draft_session", "draft_session_batched", "draft_session_paged",
    "verify_session", "verify_session_batched", "verify_session_paged",
    # trees
    "TreeSpec", "binary", "chain", "from_branching", "wide",
]
