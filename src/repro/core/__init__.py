from .arms import (Arm, ShapeArm, arm_by_name, chain_shape, default_pool,
                   default_shape_pool, multi_threshold_pool, tree_shape)
from .bandits import make_bandit, BanditBank
from .controller import (Controller, FixedArm, FixedShape, StaticGamma,
                         TapOutSequence, TapOutToken, TapOutTreeSequence,
                         make_controller)
from .engine import (BatchedSpecEngine, GenResult, ModelBundle,
                     PagedSpecEngine, SpecEngine, TreeSpecEngine)
from .rewards import r_blend, r_simple
from .spec_decode import (draft_session, draft_session_batched,
                          draft_session_paged, verify_session,
                          verify_session_batched, verify_session_paged)
from .tree import TreeSpec, binary, chain, from_branching, wide
