"""TapOut arm pool: parameter- and training-free dynamic speculation rules.

Each arm is a JAX-traceable function ``fn(sig) -> stop (bool scalar/array)``
evaluated inside the jitted drafting while-loop via ``lax.switch``.  The
signal dict is computed once per drafted token from the draft distribution.

Paper Table 1 (thresholds are FIXED, not tuned — that is the point):

  Max-Confidence    p(top1) < 0.8
  SVIP              sqrt(H(p)) > 0.6
  AdaEDL            1 - sqrt(g_coef * H(p)) < lambda_t    (lambda_t online)
  SVIP-Difference   sqrt(H_t) - sqrt(H_{t-1}) > 0.2
  Logit-Margin      p(top1) - p(top2) <= 0.2
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

# ------------------------------------------------------------ signals


def signals_from_probs(probs, prev_sqrt_entropy, lam, pos):
    """probs: (..., V) draft distribution for the token just drafted."""
    p = probs.astype(jnp.float32)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0), axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    return {
        "entropy": ent,
        "sqrt_entropy": jnp.sqrt(jnp.maximum(ent, 0.0)),
        "prev_sqrt_entropy": prev_sqrt_entropy,
        "top1": top2[..., 0],
        "top2": top2[..., 1],
        "lam": lam,
        "pos": pos,
    }


SIGNAL_VECTOR_DIM = 6


def signal_vector(sig) -> jnp.ndarray:
    """(..., 6) numeric feature view of the signal dict (classifier input
    for SpecDec++ and the per-position trace the engine can record)."""
    pos = jnp.asarray(sig["pos"], jnp.float32)
    parts = [sig["entropy"], sig["sqrt_entropy"], sig["top1"], sig["top2"],
             sig["top1"] - sig["top2"],
             jnp.broadcast_to(pos / 32.0, jnp.shape(sig["entropy"]))]
    return jnp.stack([jnp.asarray(x, jnp.float32) for x in parts], axis=-1)


# ------------------------------------------------------------ arms

@dataclass(frozen=True)
class Arm:
    name: str
    fn: Callable        # sig -> stop bool
    # NOTE: None (not nan) for threshold-free arms — nan breaks dataclass
    # __eq__ and would defeat the jit static-arg cache.
    threshold: Optional[float] = None


@functools.lru_cache(maxsize=None)
def _max_confidence(h: float):
    return lambda sig: sig["top1"] < h


@functools.lru_cache(maxsize=None)
def _svip(h: float):
    return lambda sig: sig["sqrt_entropy"] > h


@functools.lru_cache(maxsize=None)
def _adaedl(g_coef: float):
    return lambda sig: (1.0 - jnp.sqrt(jnp.maximum(
        g_coef * sig["entropy"], 0.0))) < sig["lam"]


@functools.lru_cache(maxsize=None)
def _svip_difference(h: float):
    return lambda sig: (sig["sqrt_entropy"] - sig["prev_sqrt_entropy"]) > h


@functools.lru_cache(maxsize=None)
def _logit_margin(h: float):
    return lambda sig: (sig["top1"] - sig["top2"]) <= h


# AdaEDL online-threshold hyperparameters (its own paper's defaults; these
# are part of the AdaEDL *rule*, not tuned per-dataset).
ADAEDL_DEFAULTS = dict(g_coef=1.0, lam_init=0.4, beta1=0.9, beta2=0.9,
                       eps=0.02, alpha_target=0.8)


@functools.lru_cache(maxsize=None)
def _default_pool_cached():
    return (
        Arm("max_confidence", _max_confidence(0.8), 0.8),
        Arm("svip", _svip(0.6), 0.6),
        Arm("adaedl", _adaedl(ADAEDL_DEFAULTS["g_coef"])),
        Arm("svip_difference", _svip_difference(0.2), 0.2),
        Arm("logit_margin", _logit_margin(0.2), 0.2),
    )


def default_pool() -> List[Arm]:
    """The paper's 5-arm pool with Table-1 thresholds (singleton arms so
    jit static-arg caches hit across controller instances)."""
    return list(_default_pool_cached())


@functools.lru_cache(maxsize=None)
def _multi_pool_cached():
    pool = []
    for h in (0.6, 0.8, 0.9):
        pool.append(Arm(f"max_confidence_{h}", _max_confidence(h), h))
    for h in (0.2, 0.4, 0.6):
        pool.append(Arm(f"svip_{h}", _svip(h), h))
    pool.append(Arm("adaedl", _adaedl(ADAEDL_DEFAULTS["g_coef"])))
    for h in (0.1, 0.2, 0.3):
        pool.append(Arm(f"svip_difference_{h}", _svip_difference(h), h))
    for h in (0.1, 0.2, 0.3):
        pool.append(Arm(f"logit_margin_{h}", _logit_margin(h), h))
    return tuple(pool)


def multi_threshold_pool() -> List[Arm]:
    """Appendix A.2 ablation: several thresholds per heuristic (worse)."""
    return list(_multi_pool_cached())


def pool_from_thresholds(th: Dict[str, float]) -> List[Arm]:
    """Build the 5-arm pool with explicit thresholds (used with the
    scale-free quantile calibration — see DESIGN.md §6: signal quantiles on
    a few calibration drafts, NO performance feedback, so the pool remains
    tuning-free in the paper's sense). Thresholds are rounded so the
    lru-cached arm makers (and therefore jit static-arg caches) hit."""
    r = lambda x: round(float(x), 4)
    return [
        Arm("max_confidence", _max_confidence(r(th["max_confidence"])), r(th["max_confidence"])),
        Arm("svip", _svip(r(th["svip"])), r(th["svip"])),
        Arm("adaedl", _adaedl(ADAEDL_DEFAULTS["g_coef"])),
        Arm("svip_difference", _svip_difference(r(th["svip_difference"])), r(th["svip_difference"])),
        Arm("logit_margin", _logit_margin(r(th["logit_margin"])), r(th["logit_margin"])),
    ]


@functools.lru_cache(maxsize=None)
def arm_by_name(name: str, threshold: float = None) -> Arm:
    """Single heuristic (for the tuned-baseline comparisons)."""
    makers = {
        "max_confidence": _max_confidence,
        "svip": _svip,
        "svip_difference": _svip_difference,
        "logit_margin": _logit_margin,
    }
    if name == "adaedl":
        return Arm("adaedl", _adaedl(ADAEDL_DEFAULTS["g_coef"]))
    defaults = {"max_confidence": 0.8, "svip": 0.6, "svip_difference": 0.2,
                "logit_margin": 0.2}
    h = defaults[name] if threshold is None else threshold
    return Arm(name, makers[name](h), h)


# ------------------------------------------------------------ shape arms

@dataclass(frozen=True)
class ShapeArm:
    """A SPECULATION-SHAPE arm for the tree meta-bandit: either a linear
    chain governed by one of the parameter-free stop rules above, or a
    static draft-tree topology (``core.tree.TreeSpec``) — at a DRAFT
    PRECISION (``bf16`` or ``int8`` weights, ``models/quant.py``).  The
    TapOut meta-algorithm is unchanged — shape and precision are just
    arm dimensions chosen from observed reward, no hand-tuned thresholds
    added; precision additionally scales the arm's modeled cost
    (``core.rewards.precision_cost_factor``)."""
    name: str
    kind: str                      # "chain" | "tree"
    stop: Optional[Arm] = None     # chain: dynamic stop rule
    tree: Optional[object] = None  # tree: TreeSpec (hashable)
    precision: str = "bf16"        # draft weight precision: "bf16" | "int8"
    # DRAFTER identity axis: which model drafts.  "" = the engine's default
    # draft bundle (every pre-pool shape arm), otherwise a name resolved by
    # the engine's DrafterPool (core/drafters.py).  ``drafter_cost`` is the
    # drafter's modeled per-token draft cost RELATIVE to the pool default
    # (e.g. an EAGLE head reusing the target's embeddings is far cheaper
    # than a standalone small transformer).
    drafter: str = ""
    drafter_cost: float = 1.0

    def __post_init__(self):
        assert (self.kind == "chain") == (self.stop is not None)
        assert (self.kind == "tree") == (self.tree is not None)
        assert self.precision in ("bf16", "int8"), self.precision
        assert self.drafter_cost > 0.0, self.drafter_cost


def chain_shape(stop: Arm) -> ShapeArm:
    return ShapeArm(f"chain_{stop.name}", "chain", stop=stop)


def tree_shape(tree) -> ShapeArm:
    return ShapeArm(f"tree_{tree.name}", "tree", tree=tree)


def quantized_shape(shape: ShapeArm) -> ShapeArm:
    """The int8-draft variant of a shape arm (same stop rule / topology,
    cheaper modeled cost)."""
    import dataclasses
    assert shape.precision == "bf16", f"{shape.name} already quantized"
    return dataclasses.replace(shape, name=f"{shape.name}_int8",
                               precision="int8")


def drafter_shape(shape: ShapeArm, drafter: str,
                  cost: float = 1.0) -> ShapeArm:
    """Bind a shape arm to a named drafter from a ``DrafterPool`` — the
    (drafter, shape) cross that makes drafter identity an arm dimension.
    ``cost`` is the drafter's per-token draft cost relative to the pool
    default (rounded so equal-cost pools produce identical, jit-static
    hashable arms)."""
    import dataclasses
    assert not shape.drafter, f"{shape.name} already bound to a drafter"
    return dataclasses.replace(shape, name=f"{shape.name}@{drafter}",
                               drafter=drafter,
                               drafter_cost=round(float(cost), 6))


def shape_cost_factor(shape: ShapeArm, gamma_max: int = 0) -> float:
    """Relative modeled DRAFT cost of a shape arm: the precision factor,
    times the drafter's relative cost, times the tree's node count relative
    to ``gamma_max`` for tree arms — a tree drafting 2x gamma_max nodes per
    session costs ~2x a full chain, and the cost-adjusted reward must see
    that, not just the precision axis.  (Chains draft a DYNAMIC number of
    tokens <= gamma_max; their per-session cost is the baseline 1.0 — the
    stop rule's thrift already shows up in the observed reward.)"""
    from .rewards import precision_cost_factor
    factor = precision_cost_factor(shape.precision) * shape.drafter_cost
    if shape.kind == "tree" and gamma_max:
        factor *= shape.tree.n_nodes / gamma_max
    return factor


def default_shape_pool(gamma_max: int = 8,
                       quantized: bool = False) -> List[ShapeArm]:
    """Chain arms (the paper pool's rules, unchanged) + tree topologies
    sized so no tree drafts more than ~2x gamma_max nodes.
    ``quantized=True`` additionally offers every chain rule at int8 draft
    precision (the memory-bound cost axis) — engines then hold one
    quantized copy of the draft weights next to the bf16 copy."""
    from . import tree as _t
    chains = [chain_shape(a) for a in default_pool()]
    shapes = list(chains)
    trees = [_t.binary(3), _t.wide(4, max(2, min(4, gamma_max // 2))),
             _t.from_branching((4, 2, 1))]
    shapes += [tree_shape(t) for t in trees if t.n_nodes <= 2 * gamma_max + 8]
    if quantized:
        shapes += [quantized_shape(s) for s in chains]
    return shapes


# Modeled relative per-token draft costs for the standard heterogeneous
# pool when no DrafterPool supplies measured ones: the default KV drafter
# is the 1.0 baseline; an EAGLE-style head is one transformer block plus a
# reused LM head; a tiny Mamba2/SSD draft sits in between (no KV reads but
# a full, if small, model).
DEFAULT_DRAFTER_COSTS = (("kv", 1.0), ("eagle", 0.3), ("ssd", 0.6))


def default_drafter_pool(gamma_max: int = 8,
                         drafters=DEFAULT_DRAFTER_COSTS) -> List[ShapeArm]:
    """The heterogeneous-drafter arm pool: the paper's 5 chain stop rules
    CROSSED with N candidate drafters, so the TapOut meta-bandit picks
    (drafter, stop rule) jointly from observed reward.  ``drafters`` is a
    sequence of ``(name, relative_cost)`` pairs (or a dict) — pass
    ``DrafterPool.shape_pool()`` arguments for measured costs.  Chains
    only: drafter switching rides the batched chain engine's fused tick."""
    if isinstance(drafters, dict):
        drafters = tuple(drafters.items())
    chains = [chain_shape(a) for a in default_pool()]
    return [drafter_shape(c, name, cost)
            for name, cost in drafters for c in chains]


def update_adaedl_lambda(lam: float, accept_rate_ema: float, n_acc: int,
                         n_drafted: int, *, beta1=None, beta2=None, eps=None,
                         alpha_target=None) -> Tuple[float, float]:
    """AdaEDL's post-draft threshold update (Appendix A.1).

    Returns (new_lambda, new_accept_rate_ema)."""
    d = ADAEDL_DEFAULTS
    beta1 = d["beta1"] if beta1 is None else beta1
    beta2 = d["beta2"] if beta2 is None else beta2
    eps = d["eps"] if eps is None else eps
    alpha_target = d["alpha_target"] if alpha_target is None else alpha_target
    r = n_acc / max(n_drafted, 1)
    ema = beta1 * accept_rate_ema + (1 - beta1) * r
    sign = 1.0 if (alpha_target - r) > 0 else (-1.0 if (alpha_target - r) < 0 else 0.0)
    lam = beta2 * lam + (1 - beta2) * (lam + eps * sign)
    return float(min(max(lam, 0.0), 1.0)), float(ema)
