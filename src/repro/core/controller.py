"""Dynamic-speculation controllers: TapOut (bandit) + every baseline.

A controller owns (a) the arm pool handed to the jitted draft loop and
(b) the host-side policy state (bandit values, AdaEDL lambda).  The engine
asks ``begin()`` for per-position arm indices before each drafting session
and reports ``update(...)`` after verification.

Batched serving: ``begin_batch(n)`` returns an (n, gamma_max) arm matrix
(one row per concurrent stream) and ``update_batch(arm_mat, n_drafted,
n_accepted)`` consumes the tick's n observations at once.  Updates are
order-independent across the streams of a tick (the bandit merges the
observation multiset against its pre-tick state), so slot index carries no
information and the online policy is reproducible under scheduler
reordering.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .arms import (ADAEDL_DEFAULTS, Arm, ShapeArm, arm_by_name,
                   default_pool, default_shape_pool, multi_threshold_pool,
                   update_adaedl_lambda)
from .bandits import Bandit, BanditBank, make_bandit
from .rewards import REWARDS


import functools


@functools.lru_cache(maxsize=None)
def never_stop_arm() -> Arm:
    return Arm("never_stop", lambda sig: (sig["top1"] < -1.0))


class Controller:
    """Base controller; subclasses override select/observe."""

    name = "base"

    def __init__(self, arms: List[Arm], gamma_max: int, seed: int = 0):
        self.arms = tuple(arms)
        self.gamma_max = gamma_max
        self.lam = ADAEDL_DEFAULTS["lam_init"]
        self._accept_ema = ADAEDL_DEFAULTS["alpha_target"]
        self.history: List[dict] = []

    # -- engine API ---------------------------------------------------
    def begin(self) -> np.ndarray:
        raise NotImplementedError

    def update(self, arm_per_pos: np.ndarray, n_drafted: int,
               n_accepted: int) -> None:
        self.lam, self._accept_ema = update_adaedl_lambda(
            self.lam, self._accept_ema, n_accepted, n_drafted)
        self._observe(arm_per_pos, n_drafted, n_accepted)
        self.history.append({"n_drafted": n_drafted, "n_accepted": n_accepted,
                             "arm_values": self.arm_values})

    # -- batched engine API -------------------------------------------
    def begin_batch(self, n: int) -> np.ndarray:
        """(n, gamma_max) arm indices, one row per concurrent stream."""
        return np.stack([self.begin() for _ in range(n)])

    def update_batch(self, arm_mat: np.ndarray, n_drafted: np.ndarray,
                     n_accepted: np.ndarray) -> None:
        """Consume one tick's n observations (order-independent).

        AdaEDL's lambda sees the tick's pooled accept rate (one EMA step per
        tick, not per stream — the threshold is a tick-rate quantity)."""
        arm_mat = np.asarray(arm_mat)
        nd = np.asarray(n_drafted, np.int64)
        na = np.asarray(n_accepted, np.int64)
        self.lam, self._accept_ema = update_adaedl_lambda(
            self.lam, self._accept_ema, int(na.sum()), int(nd.sum()))
        self._observe_batch(arm_mat, nd, na)
        self.history.append({"n_drafted": int(nd.sum()),
                             "n_accepted": int(na.sum()),
                             "batch": int(nd.size),
                             "arm_values": self.arm_values})

    def _observe(self, arm_per_pos, n_drafted, n_accepted) -> None:
        pass

    def _observe_batch(self, arm_mat, n_drafted, n_accepted) -> None:
        for i in range(n_drafted.size):
            self._observe(arm_mat[i], int(n_drafted[i]), int(n_accepted[i]))

    @property
    def arm_values(self) -> Optional[np.ndarray]:
        return None


class TapOutSequence(Controller):
    """Sequence-level TapOut: one arm per drafting session."""

    def __init__(self, gamma_max: int, bandit: str = "ucb1",
                 reward: str = "blend", pool: Optional[List[Arm]] = None,
                 seed: int = 0, alpha: float = 0.5):
        super().__init__(pool or default_pool(), gamma_max, seed)
        self.name = f"tapout_seq_{bandit}_{reward}"
        if bandit in ("ts", "ts_gaussian"):
            bandit = "ts_gaussian"   # continuous reward -> Gaussian posterior
        self.bandit = make_bandit(bandit, len(self.arms), seed)
        self.reward_fn = REWARDS[reward]
        self.alpha = alpha
        self._current = 0

    def begin(self) -> np.ndarray:
        self._current = self.bandit.select()
        return np.full((self.gamma_max,), self._current, np.int32)

    def begin_batch(self, n: int) -> np.ndarray:
        picks = self.bandit.select_batch(n)
        return np.broadcast_to(picks[:, None].astype(np.int32),
                               (n, self.gamma_max)).copy()

    def _reward(self, n_accepted: int, n_drafted: int) -> float:
        if self.reward_fn is REWARDS["blend"]:
            return self.reward_fn(n_accepted, n_drafted, self.gamma_max,
                                  self.alpha)
        return self.reward_fn(n_accepted, n_drafted, self.gamma_max)

    def _observe(self, arm_per_pos, n_drafted, n_accepted):
        self.bandit.update(int(arm_per_pos[0]),
                           self._reward(n_accepted, n_drafted))

    def _observe_batch(self, arm_mat, n_drafted, n_accepted):
        rewards = np.array([self._reward(int(a), int(d))
                            for a, d in zip(n_accepted, n_drafted)])
        self.bandit.update_batch(arm_mat[:, 0], rewards)

    @property
    def arm_values(self) -> np.ndarray:
        return self.bandit.arm_values


class TapOutToken(Controller):
    """Token-level TapOut: one bandit per draft position, binary rewards."""

    def __init__(self, gamma_max: int, bandit: str = "ucb1",
                 pool: Optional[List[Arm]] = None, seed: int = 0):
        super().__init__(pool or default_pool(), gamma_max, seed)
        self.name = f"tapout_token_{bandit}"
        if bandit in ("ts", "ts_beta"):
            bandit = "ts_beta"       # binary reward -> Beta-Bernoulli
        n = len(self.arms)
        self.bank = BanditBank(gamma_max,
                               lambda s: make_bandit(bandit, n, s), seed)
        self._assignment = np.zeros((gamma_max,), np.int32)

    def begin(self) -> np.ndarray:
        self._assignment = self.bank.select_all()
        return self._assignment

    def begin_batch(self, n: int) -> np.ndarray:
        return self.bank.select_all_batch(n).astype(np.int32)

    def _observe(self, arm_per_pos, n_drafted, n_accepted):
        for i in range(int(n_drafted)):
            self.bank.update(i, int(arm_per_pos[i]),
                             1.0 if i < n_accepted else 0.0)

    def _observe_batch(self, arm_mat, n_drafted, n_accepted):
        for i in range(self.gamma_max):
            mask = n_drafted > i
            if not mask.any():
                continue
            self.bank.update_batch(i, arm_mat[mask, i],
                                   (n_accepted[mask] > i).astype(np.float64))

    @property
    def arm_values(self) -> np.ndarray:
        return self.bank.arm_values


class TapOutTreeSequence(Controller):
    """Sequence-level TapOut over SPECULATION SHAPES: the meta-bandit's
    arms are (chain x stop-rule) AND static tree topologies, so chain-vs-
    tree — and which tree — is learned online from observed reward, with
    no new thresholds (the TapOut principle extended to the *shape* of a
    speculation step).

    The engine asks ``begin_shape()`` before a session and reports
    ``update_shape(shape_idx, n_drafted, n_accepted)`` after verification;
    chain shapes reuse the inherited chain-controller surface (``begin`` /
    ``update``) so the drafting program's arm pool stays the deduplicated
    stop-rule tuple.  Default reward is ``simple`` = m / gamma_max — the
    accepted-tokens-per-verify-pass objective both shapes compete on
    (``blend`` would penalize trees for their per-node acceptance rate,
    which is low by construction).
    """

    def __init__(self, gamma_max: int, bandit: str = "ucb1",
                 reward: str = "simple",
                 shapes: Optional[List[ShapeArm]] = None, seed: int = 0,
                 alpha: float = 0.5):
        shapes = list(shapes or default_shape_pool(gamma_max))
        # deduplicated stop-rule pool for the jitted chain drafting program
        stops: List[Arm] = []
        for s in shapes:
            if s.kind == "chain" and s.stop not in stops:
                stops.append(s.stop)
        super().__init__(stops or [never_stop_arm()], gamma_max, seed)
        self.shapes = tuple(shapes)
        self.name = f"tapout_tree_{bandit}_{reward}"
        if bandit in ("ts", "ts_gaussian"):
            bandit = "ts_gaussian"
        self.bandit = make_bandit(bandit, len(self.shapes), seed)
        self.reward_fn = REWARDS[reward]
        self.alpha = alpha
        self._current = 0

    def stop_arm_index(self, shape_idx: int) -> int:
        """Index of a chain shape's stop rule within ``self.arms``."""
        return self.arms.index(self.shapes[shape_idx].stop)

    # -- engine API ---------------------------------------------------
    def begin_shape(self) -> int:
        self._current = int(self.bandit.select())
        return self._current

    def _reward(self, n_accepted: int, n_drafted: int,
                shape_idx: Optional[int] = None) -> float:
        if self.reward_fn is REWARDS["blend"]:
            return self.reward_fn(n_accepted, n_drafted, self.gamma_max,
                                  self.alpha)
        if self.reward_fn is REWARDS["cost"] and shape_idx is not None:
            # cost as an arm axis (precision AND tree node count): divide by
            # the arm's modeled draft cost relative to the pool's CHEAPEST
            # arm (rel >= 1) — r_cost_adjusted then stays in [0, 1] with no
            # clipping, so cheap arms never saturate
            from .arms import shape_cost_factor
            g = self.gamma_max
            rel = (shape_cost_factor(self.shapes[shape_idx], g)
                   / min(shape_cost_factor(s, g) for s in self.shapes))
            return self.reward_fn(n_accepted, n_drafted, g, rel)
        return REWARDS["simple"](n_accepted, n_drafted, self.gamma_max) \
            if self.reward_fn is REWARDS["cost"] \
            else self.reward_fn(n_accepted, n_drafted, self.gamma_max)

    def update_shape(self, shape_idx: int, n_drafted: int,
                     n_accepted: int) -> None:
        # AdaEDL's lambda tracks a CHAIN accept rate; a tree session's
        # per-node rate (m / n_nodes) is low by construction and would
        # drag the EMA — and therefore the adaedl chain arm's stop
        # threshold — as a function of how often tree arms are pulled
        if self.shapes[shape_idx].kind == "chain":
            self.lam, self._accept_ema = update_adaedl_lambda(
                self.lam, self._accept_ema, n_accepted, n_drafted)
        self.bandit.update(shape_idx,
                           self._reward(n_accepted, n_drafted, shape_idx))
        self.history.append({"n_drafted": n_drafted, "n_accepted": n_accepted,
                             "shape": self.shapes[shape_idx].name,
                             "drafter": self.shapes[shape_idx].drafter,
                             "arm_values": self.arm_values})

    def update_shape_batch(self, shape_idx: int, n_drafted, n_accepted) -> None:
        """One batched tick's observations for ONE shape arm — the
        drafter-pool engine picks a single (drafter, stop-rule) arm per
        tick so all lanes share a drafter, then reports every lane's
        (n_drafted, n_accepted) here.  Order-independent across lanes: the
        bandit merges the reward multiset against its pre-tick state
        (``Bandit.update_batch``), and AdaEDL's lambda sees the pooled
        accept rate (one EMA step per tick, as in ``update_batch``)."""
        nd = np.asarray(n_drafted, np.int64)
        na = np.asarray(n_accepted, np.int64)
        if self.shapes[shape_idx].kind == "chain":
            self.lam, self._accept_ema = update_adaedl_lambda(
                self.lam, self._accept_ema, int(na.sum()), int(nd.sum()))
        rewards = np.array([self._reward(int(a), int(d), shape_idx)
                            for a, d in zip(na, nd)])
        self.bandit.update_batch(
            np.full((nd.size,), shape_idx, np.int64), rewards)
        self.history.append({"n_drafted": int(nd.sum()),
                             "n_accepted": int(na.sum()),
                             "batch": int(nd.size),
                             "shape": self.shapes[shape_idx].name,
                             "drafter": self.shapes[shape_idx].drafter,
                             "arm_values": self.arm_values})

    # -- drafter-axis accessors (drafter-pool serving and stats) -------
    def drafter_for(self, shape_idx: int) -> str:
        """Name of the drafter bound to a shape arm ("" = engine default)."""
        return self.shapes[shape_idx].drafter

    @property
    def drafter_names(self) -> List[str]:
        """Distinct drafter names in pool order (first occurrence)."""
        seen: List[str] = []
        for s in self.shapes:
            if s.drafter not in seen:
                seen.append(s.drafter)
        return seen

    @property
    def drafter_pulls(self) -> dict:
        """Pull counts summed over the shape arms of each drafter — the
        drafter-axis marginal of the meta-bandit's counts."""
        counts = self.bandit.counts
        pulls: dict = {}
        for i, s in enumerate(self.shapes):
            pulls[s.drafter] = pulls.get(s.drafter, 0) + int(counts[i])
        return pulls

    # chain-controller surface (unused by the tree engine, kept total)
    def begin(self) -> np.ndarray:
        return np.zeros((self.gamma_max,), np.int32)

    @property
    def arm_values(self) -> np.ndarray:
        return self.bandit.arm_values

    @property
    def shape_pulls(self) -> np.ndarray:
        return self.bandit.counts.copy()


class FixedShape(TapOutTreeSequence):
    """A single speculation shape (chain-vs-tree per-shape baselines)."""

    def __init__(self, gamma_max: int, shape: ShapeArm, seed: int = 0):
        super().__init__(gamma_max, "ucb1", "simple", [shape], seed)
        self.name = f"fixed_shape_{shape.name}"

    def begin_shape(self) -> int:
        return 0


class FixedArm(Controller):
    """A single (possibly tuned) heuristic — the paper's baselines."""

    def __init__(self, gamma_max: int, arm_name: str,
                 threshold: Optional[float] = None, seed: int = 0):
        arm = arm_by_name(arm_name, threshold)
        super().__init__([arm], gamma_max, seed)
        self.name = f"fixed_{arm.name}"

    def begin(self) -> np.ndarray:
        return np.zeros((self.gamma_max,), np.int32)


class StaticGamma(Controller):
    """Vanilla speculative decoding: always draft exactly gamma tokens."""

    def __init__(self, gamma: int = 6, seed: int = 0):
        super().__init__([never_stop_arm()], gamma, seed)
        self.name = f"static_{gamma}"

    def begin(self) -> np.ndarray:
        return np.zeros((self.gamma_max,), np.int32)


def make_controller(kind: str, gamma_max: int, seed: int = 0, **kw) -> Controller:
    if kind == "static":
        return StaticGamma(kw.get("gamma", 6), seed)
    if kind.startswith("fixed_"):
        return FixedArm(gamma_max, kind[len("fixed_"):],
                        kw.get("threshold"), seed)
    if kind == "tapout_seq_ucb1":
        return TapOutSequence(gamma_max, "ucb1", kw.get("reward", "blend"),
                              kw.get("pool"), seed)
    if kind == "tapout_seq_ucb_tuned":
        return TapOutSequence(gamma_max, "ucb_tuned", kw.get("reward", "blend"),
                              kw.get("pool"), seed)
    if kind == "tapout_seq_ts":
        return TapOutSequence(gamma_max, "ts_gaussian", kw.get("reward", "blend"),
                              kw.get("pool"), seed)
    if kind == "tapout_seq_exp3":
        return TapOutSequence(gamma_max, "exp3", kw.get("reward", "blend"),
                              kw.get("pool"), seed)
    if kind == "tapout_token_ucb1":
        return TapOutToken(gamma_max, "ucb1", kw.get("pool"), seed)
    if kind == "tapout_token_ts":
        return TapOutToken(gamma_max, "ts_beta", kw.get("pool"), seed)
    if kind == "tapout_tree_ucb1":
        return TapOutTreeSequence(gamma_max, "ucb1",
                                  kw.get("reward", "simple"),
                                  kw.get("shapes"), seed)
    if kind == "tapout_tree_exp3":
        return TapOutTreeSequence(gamma_max, "exp3",
                                  kw.get("reward", "simple"),
                                  kw.get("shapes"), seed)
    if kind == "tapout_tree_cost":
        # cost-adjusted reward over a shape pool that includes int8-draft
        # precision arms (see core/arms.default_shape_pool(quantized=True))
        from .arms import default_shape_pool
        shapes = kw.get("shapes") or default_shape_pool(gamma_max,
                                                        quantized=True)
        return TapOutTreeSequence(gamma_max, "ucb1", "cost", shapes, seed)
    if kind in ("tapout_drafter_ucb1", "tapout_drafter_exp3",
                "tapout_drafter_cost"):
        # drafter identity as an arm dimension: (drafter x stop-rule) chain
        # arms (core/arms.default_drafter_pool, or a DrafterPool's
        # shape_pool() passed via kw["shapes"] for measured costs)
        from .arms import default_drafter_pool
        shapes = kw.get("shapes") or default_drafter_pool(gamma_max)
        bandit = "exp3" if kind.endswith("_exp3") else "ucb1"
        reward = "cost" if kind.endswith("_cost") else kw.get("reward",
                                                             "simple")
        c = TapOutTreeSequence(gamma_max, bandit, reward, shapes, seed)
        c.name = kind
        return c
    raise ValueError(kind)
