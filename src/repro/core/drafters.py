"""Heterogeneous drafter pool: WHO drafts becomes a bandit arm dimension.

TapOut's meta-bandit already chooses speculation *shape* and *precision*;
this module adds the bigger lever — *which drafter* ("Not-a-Bandit" frames
drafter selection as the no-regret problem, BanditSpec as the bandit
hyperparameter setting).  A ``DrafterPool`` owns N candidate draft models:

  * ``kv``    — a standalone small transformer (the classic draft), whose
                per-stream cost is a KV cache LINEAR in context length;
  * ``eagle`` — an EAGLE-style self-drafting head: ONE extra transformer
                block trained against the target's hidden states, reusing
                the target's embeddings and LM head (``training/``: chunked
                CE loss + AdamW + checkpointing);
  * ``ssd``   — a Mamba2/SSD recurrent draft (``models/ssm.py``) whose
                per-stream state is O(1) in context length, making an
                extra drafter nearly free at long contexts.

The pool exposes per-drafter modeled costs (``core/rewards.py`` state-bytes
helpers + ``ModelBundle.cost_per_token``) and builds the crossed
(drafter x stop-rule) arm pool (``core/arms.default_drafter_pool``) that
``core/controller.TapOutTreeSequence`` selects from.  The batched engine
(``core/engine.py``) keeps one jitted session per drafter and lets the host
bandit pick which to launch each tick — switching drafters never re-traces.

EAGLE head, faithfully-simplified: the head is trained to map the target's
post-final-norm hidden state at position t to the token at t+1 (a
Medusa-head-0 / EAGLE-without-feature-recycling objective — the full EAGLE
recycles its own predicted features autoregressively).  At serve time the
trained block + norm are assembled into a standard 1-layer ``ModelBundle``
over token embeddings, so the head rides every existing engine path
(dense, paged, fused tick) unchanged; docs/drafters.md discusses the
approximation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.blocks import block_train
from repro.models.common import rms_norm
from repro.models.config import ModelConfig, SSMConfig
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.losses import chunked_ce_loss
from repro.training.optimizer import OptConfig, adamw_update, init_opt_state
from .arms import ShapeArm, default_drafter_pool
from .engine import ModelBundle
from .rewards import drafter_state_bytes

DRAFTER_KINDS = ("kv", "eagle", "ssd")


@dataclasses.dataclass
class Drafter:
    """One candidate drafter: a serveable ``ModelBundle`` plus the kind tag
    the cost model and bench rows key on."""
    name: str
    bundle: ModelBundle
    kind: str

    def __post_init__(self):
        assert self.kind in DRAFTER_KINDS, self.kind


class DrafterPool:
    """Ordered collection of candidate drafters; the FIRST is the default
    (the engine's ``draft`` bundle).  Deliberately a plain class with
    identity hash: it holds device arrays, and it must be safe to store in
    the frozen ``EngineSpec`` without defeating anything jit-static."""

    def __init__(self, drafters: Sequence[Drafter]):
        drafters = list(drafters)
        assert drafters, "empty drafter pool"
        names = [d.name for d in drafters]
        assert len(set(names)) == len(names), f"duplicate drafter names: {names}"
        self._drafters: Tuple[Drafter, ...] = tuple(drafters)
        self._by_name: Dict[str, Drafter] = {d.name: d for d in drafters}

    def __len__(self) -> int:
        return len(self._drafters)

    def __iter__(self) -> Iterator[Drafter]:
        return iter(self._drafters)

    @property
    def default(self) -> str:
        return self._drafters[0].name

    @property
    def names(self) -> List[str]:
        return [d.name for d in self._drafters]

    def get(self, name: str) -> Drafter:
        """Resolve a drafter by name ("" = the pool default)."""
        return self._by_name[name or self.default]

    def bundle(self, name: str) -> ModelBundle:
        return self.get(name).bundle

    def kind(self, name: str) -> str:
        return self.get(name).kind

    def cost_factor(self, name: str) -> float:
        """Modeled per-token draft cost relative to the pool default
        (rounded so equal pools yield identical hashable shape arms)."""
        base = self._drafters[0].bundle.cost_per_token
        return round(self.get(name).bundle.cost_per_token / max(base, 1e-9), 6)

    def state_bytes(self, name: str, seq_len: int, kv_dtype=None) -> int:
        """Per-stream decode-resident draft-state bytes at context length
        ``seq_len`` — linear in L for kv/eagle drafters, O(1) for ssd."""
        return drafter_state_bytes(self.get(name).bundle.cfg, seq_len,
                                   kv_dtype)

    def shape_pool(self, gamma_max: int = 8) -> List[ShapeArm]:
        """The crossed (drafter x stop-rule) arm pool with this pool's
        measured relative costs."""
        return default_drafter_pool(
            gamma_max, tuple((d.name, self.cost_factor(d.name))
                             for d in self._drafters))

    def describe(self, seq_len: int = 1024, kv_dtype=None) -> dict:
        """JSON-safe identity blob for ``engine.describe()`` / bench rows."""
        return {
            "names": self.names,
            "default": self.default,
            "kinds": {d.name: d.kind for d in self._drafters},
            "cost_factors": {d.name: self.cost_factor(d.name)
                             for d in self._drafters},
            "state_bytes": {d.name: self.state_bytes(d.name, seq_len,
                                                     kv_dtype)
                            for d in self._drafters},
            "state_bytes_at_len": int(seq_len),
        }


# ------------------------------------------------------------ EAGLE head

def eagle_head_config(target_cfg: ModelConfig) -> ModelConfig:
    """The head's 1-layer dense config: same width/heads/vocab as the
    target so the block consumes target hidden states during training and
    the target's embeddings/LM head serve as its logit layer."""
    assert not target_cfg.is_attention_free, \
        "EAGLE head needs an attention target"
    return target_cfg.replace(name=f"{target_cfg.name}-eagle",
                              arch_type="dense", num_layers=1,
                              block_pattern=("attn",), moe=None, mla=None,
                              ssm=None, rglru=None, encdec=None, vision=None,
                              scan_layers=False)


def init_eagle_head(target_cfg: ModelConfig, key):
    """Fresh trainable head params: one transformer block + final norm
    (everything else — embeddings, LM head — is frozen target weights)."""
    head_cfg = eagle_head_config(target_cfg)
    tpl = T.init_params(head_cfg, key)
    head = {"block": tpl["layers"]["prefix"][0],
            "final_norm": tpl["final_norm"]}
    return head_cfg, head


def eagle_logit_params(target_params) -> dict:
    """The frozen logit layer the head reuses from the target."""
    p = {"embed": target_params["embed"]}
    if "lm_head" in target_params:
        p["lm_head"] = target_params["lm_head"]
    return p


def eagle_head_hidden(head, head_cfg: ModelConfig, hidden):
    """Apply the head block + norm to (B, S, d) hidden states."""
    positions = jnp.arange(hidden.shape[1], dtype=jnp.int32)
    h, _ = block_train(head["block"], head_cfg, 0, hidden, positions)
    return rms_norm(h, head["final_norm"], head_cfg.rms_eps)


def eagle_head_logits(head, head_cfg: ModelConfig, logit_params, hidden):
    """Head logits over (B, S, d) hidden states (checkpoint-roundtrip and
    eval surface; training uses the chunked-CE path below)."""
    return T.logits_fn(logit_params, head_cfg,
                       eagle_head_hidden(head, head_cfg, hidden))


def eagle_head_loss(head, logit_params, head_cfg: ModelConfig, hidden,
                    labels, *, chunk: int = 256):
    """Chunked CE of the head's predictions against next tokens, given the
    TARGET's hidden states as input (the EAGLE training signal)."""
    h = eagle_head_hidden(head, head_cfg, hidden)
    return chunked_ce_loss(logit_params, head_cfg, h, labels, chunk=chunk)


def train_eagle_head(target: ModelBundle, batches, *, steps: int,
                     opt_cfg: Optional[OptConfig] = None, seed: int = 0,
                     ce_chunk: int = 256) -> dict:
    """Train an EAGLE-style head against ``target``'s hidden states.

    ``batches`` yields (tokens, labels) int32 arrays of shape (B, S) —
    e.g. ``data.synthetic.SyntheticCorpus.training_batches``.  The target
    is frozen: each step runs the target's full-sequence ``forward_hidden``
    (no grad), then one AdamW step on the head (``training/optimizer.py``)
    against the chunked-CE loss (``training/losses.py``).

    Returns {"head", "head_cfg", "history"} — ``history`` is one
    {"step", "loss"} dict per step for loss-curve artifacts."""
    opt_cfg = opt_cfg or OptConfig(lr=1e-3, warmup_steps=min(5, steps),
                                   total_steps=steps)
    head_cfg, head = init_eagle_head(target.cfg, jax.random.PRNGKey(seed))
    logit_params = eagle_logit_params(target.params)
    opt_state = init_opt_state(head)

    @jax.jit
    def hidden_fn(tparams, tokens):
        h, _ = T.forward_hidden(tparams, target.cfg, tokens, remat=False)
        return h

    @jax.jit
    def train_step(head, opt_state, logit_params, hidden, labels):
        loss, grads = jax.value_and_grad(eagle_head_loss)(
            head, logit_params, head_cfg, hidden, labels, chunk=ce_chunk)
        head, opt_state, _ = adamw_update(opt_cfg, head, grads, opt_state)
        return head, opt_state, loss

    history = []
    it = iter(batches)
    for step in range(steps):
        x, y = next(it)
        hidden = hidden_fn(target.params, jnp.asarray(x, jnp.int32))
        head, opt_state, loss = train_step(head, opt_state, logit_params,
                                           hidden, jnp.asarray(y, jnp.int32))
        history.append({"step": step, "loss": float(loss)})
    return {"head": head, "head_cfg": head_cfg, "history": history}


def eagle_bundle(target: ModelBundle, head,
                 head_cfg: Optional[ModelConfig] = None) -> ModelBundle:
    """Assemble the trained head into a standard 1-layer ``ModelBundle``:
    target embeddings -> trained block -> trained norm -> target LM head.
    Serving feeds token EMBEDDINGS where training saw target hidden states
    (the no-feature-recycling approximation) — but the result is an
    ordinary transformer the engines serve with zero special cases."""
    head_cfg = head_cfg or eagle_head_config(target.cfg)
    params = {"embed": target.params["embed"],
              "final_norm": head["final_norm"],
              "layers": {"prefix": [head["block"]], "tail": [],
                         "stack": None}}
    if "lm_head" in target.params:
        params["lm_head"] = target.params["lm_head"]
    # modeled cost = HEAD-ONLY parameters: the embeddings and LM head are
    # the target's own weights, resident and amortized regardless of the
    # drafter choice, so the head's marginal per-token cost is just its
    # trained block + norm
    head_params = int(sum(np.size(x) for x in jax.tree.leaves(head)))
    return ModelBundle(params, head_cfg, cost_per_token=float(head_params))


def save_eagle_head(path: str, head, head_cfg: ModelConfig,
                    history=None) -> None:
    """Persist the trainable head (``training/checkpoint.py`` npz format)."""
    meta = {"head_cfg_name": head_cfg.name, "vocab": head_cfg.vocab_size,
            "d_model": head_cfg.d_model}
    if history:
        meta["final_loss"] = history[-1]["loss"]
    save_checkpoint(path, head, meta)


def load_eagle_head(path: str, target_cfg: ModelConfig):
    """Load a trained head against a fresh template (bit-exact roundtrip)."""
    head_cfg, template = init_eagle_head(target_cfg, jax.random.PRNGKey(0))
    return head_cfg, load_checkpoint(path, template)


# ------------------------------------------------------------ SSD drafter

def ssd_draft_config(target_cfg: ModelConfig, *, d_model: int = 0,
                     num_layers: int = 2, d_state: int = 16,
                     head_dim: int = 16, d_conv: int = 4,
                     chunk_size: int = 16) -> ModelConfig:
    """A tiny Mamba2/SSD draft over the target's vocabulary.  Per-stream
    decode state is a fixed conv window + (heads, head_dim, d_state) ssm
    state — O(1) in context length (``core.rewards.ssm_state_bytes``)."""
    d = d_model or max(32, target_cfg.d_model // 2)
    assert (2 * d) % head_dim == 0, (d, head_dim)
    return ModelConfig(
        name=f"{target_cfg.name}-ssd-draft", arch_type="ssm",
        num_layers=num_layers, d_model=d, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=target_cfg.vocab_size, tie_embeddings=True,
        block_pattern=("mamba2",),
        ssm=SSMConfig(d_state=d_state, d_conv=d_conv, expand=2,
                      head_dim=head_dim, ngroups=1, chunk_size=chunk_size),
        source="tiny in-repo SSD draft (mamba2 conventions)")


def ssd_draft_bundle(target_cfg: ModelConfig, seed: int = 0,
                     **cfg_kw) -> ModelBundle:
    cfg = ssd_draft_config(target_cfg, **cfg_kw)
    return ModelBundle(T.init_params(cfg, jax.random.PRNGKey(seed)), cfg)


# ------------------------------------------------------------ assembly

def default_drafters(draft: ModelBundle, target: ModelBundle, *,
                     eagle_head=None, ssd: Optional[ModelBundle] = None,
                     seed: int = 0) -> DrafterPool:
    """The standard 3-drafter pool: the given KV draft (default), an
    EAGLE-style head (``eagle_head`` = trained head params, else a fresh
    random-init head so the pool is constructible without training), and a
    Mamba2/SSD recurrent draft."""
    if eagle_head is None:
        _, eagle_head = init_eagle_head(target.cfg,
                                        jax.random.PRNGKey(seed + 1))
    return DrafterPool([
        Drafter("kv", draft, "kv"),
        Drafter("eagle", eagle_bundle(target, eagle_head), "eagle"),
        Drafter("ssd", ssd or ssd_draft_bundle(target.cfg, seed), "ssd"),
    ])
