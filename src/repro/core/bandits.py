"""Multi-armed bandit algorithms (host-side, O(arms) state).

The bandit state lives on the host: it is a handful of floats updated once
per verification call, so keeping it out of the jitted device program costs
nothing and keeps the policies interpretable (arm values are plain numpy).

Implemented: UCB1, UCB-Tuned (Auer et al. 2002), Thompson Sampling with
Beta-Bernoulli (token-level binary rewards) and Gaussian (sequence-level
continuous rewards) posteriors, plus epsilon-greedy as an extra baseline.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np


class Bandit:
    """Base: incremental mean/variance tracking per arm."""

    def __init__(self, n_arms: int, seed: int = 0):
        self.n_arms = n_arms
        self.counts = np.zeros(n_arms, np.int64)
        self.means = np.zeros(n_arms, np.float64)
        self.m2 = np.zeros(n_arms, np.float64)     # sum of squared deviations
        self.t = 0
        self.rng = np.random.default_rng(seed)

    def select(self) -> int:
        raise NotImplementedError

    def update(self, arm: int, reward: float) -> None:
        self.t += 1
        self.counts[arm] += 1
        d = reward - self.means[arm]
        self.means[arm] += d / self.counts[arm]
        self.m2[arm] += d * (reward - self.means[arm])

    def variance(self, arm: int) -> float:
        if self.counts[arm] < 2:
            return 0.25
        return self.m2[arm] / self.counts[arm]

    @property
    def arm_values(self) -> np.ndarray:
        return self.means.copy()

    def state_dict(self) -> dict:
        return {"counts": self.counts.copy(), "means": self.means.copy(),
                "m2": self.m2.copy(), "t": self.t}


class UCB1(Bandit):
    def select(self) -> int:
        for a in range(self.n_arms):       # play each arm once first
            if self.counts[a] == 0:
                return a
        t = max(self.t, 1)
        bonus = np.sqrt(2.0 * math.log(t) / self.counts)
        return int(np.argmax(self.means + bonus))


class UCBTuned(Bandit):
    def select(self) -> int:
        for a in range(self.n_arms):
            if self.counts[a] == 0:
                return a
        t = max(self.t, 1)
        logt = math.log(t)
        v = np.array([self.variance(a) for a in range(self.n_arms)])
        v_t = v + np.sqrt(2.0 * logt / self.counts)
        bonus = np.sqrt(logt / self.counts * np.minimum(0.25, v_t))
        return int(np.argmax(self.means + bonus))


class ThompsonBeta(Bandit):
    """Beta-Bernoulli TS for binary rewards (token-level)."""

    def __init__(self, n_arms: int, seed: int = 0, a0: float = 1.0, b0: float = 1.0):
        super().__init__(n_arms, seed)
        self.alpha = np.full(n_arms, a0)
        self.beta = np.full(n_arms, b0)

    def select(self) -> int:
        return int(np.argmax(self.rng.beta(self.alpha, self.beta)))

    def update(self, arm: int, reward: float) -> None:
        super().update(arm, reward)
        self.alpha[arm] += reward
        self.beta[arm] += 1.0 - reward

    @property
    def arm_values(self) -> np.ndarray:
        return self.alpha / (self.alpha + self.beta)


class ThompsonGaussian(Bandit):
    """Gaussian TS with known observation noise (sequence-level r in [0,1])."""

    def __init__(self, n_arms: int, seed: int = 0, prior_mean: float = 0.5,
                 prior_var: float = 1.0, noise_var: float = 0.05):
        super().__init__(n_arms, seed)
        self.prior_mean = prior_mean
        self.prior_var = prior_var
        self.noise_var = noise_var

    def _posterior(self, arm: int):
        n = self.counts[arm]
        prec = 1.0 / self.prior_var + n / self.noise_var
        var = 1.0 / prec
        mean = var * (self.prior_mean / self.prior_var +
                      n * self.means[arm] / self.noise_var)
        return mean, var

    def select(self) -> int:
        samples = []
        for a in range(self.n_arms):
            m, v = self._posterior(a)
            samples.append(self.rng.normal(m, math.sqrt(v)))
        return int(np.argmax(samples))

    @property
    def arm_values(self) -> np.ndarray:
        return np.array([self._posterior(a)[0] for a in range(self.n_arms)])


class EpsilonGreedy(Bandit):
    def __init__(self, n_arms: int, seed: int = 0, eps: float = 0.1):
        super().__init__(n_arms, seed)
        self.eps = eps

    def select(self) -> int:
        for a in range(self.n_arms):
            if self.counts[a] == 0:
                return a
        if self.rng.random() < self.eps:
            return int(self.rng.integers(self.n_arms))
        return int(np.argmax(self.means))


class BanditBank:
    """Token-level setup: one independent bandit per draft position."""

    def __init__(self, n_positions: int, factory, seed: int = 0):
        self.bandits: List[Bandit] = [factory(seed + i) for i in range(n_positions)]

    def select_all(self) -> np.ndarray:
        return np.array([b.select() for b in self.bandits], np.int32)

    def update(self, position: int, arm: int, reward: float) -> None:
        self.bandits[position].update(arm, reward)

    @property
    def arm_values(self) -> np.ndarray:
        return np.stack([b.arm_values for b in self.bandits])


def make_bandit(kind: str, n_arms: int, seed: int = 0) -> Bandit:
    kinds = {"ucb1": UCB1, "ucb_tuned": UCBTuned, "ts_beta": ThompsonBeta,
             "ts_gaussian": ThompsonGaussian, "eps_greedy": EpsilonGreedy}
    return kinds[kind](n_arms, seed)
