"""Multi-armed bandit algorithms (host-side, O(arms) state).

The bandit state lives on the host: it is a handful of floats updated once
per verification call, so keeping it out of the jitted device program costs
nothing and keeps the policies interpretable (arm values are plain numpy).

Implemented: UCB1, UCB-Tuned (Auer et al. 2002), Thompson Sampling with
Beta-Bernoulli (token-level binary rewards) and Gaussian (sequence-level
continuous rewards) posteriors, EXP3 (adversarial), plus epsilon-greedy as
an extra baseline.

Batched serving contract: one scheduler tick produces B observations at
once, so every bandit supports ``select_batch(n)`` / ``update_batch(arms,
rewards)``.  Batched updates are ORDER-INDEPENDENT: the result is a pure
function of (pre-batch state, multiset of observations) — selection
probabilities / posteriors are computed once from the pre-batch state and
the statistics merge uses Chan's parallel algorithm, so stream index within
a tick carries no information.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np


class Bandit:
    """Base: incremental mean/variance tracking per arm."""

    def __init__(self, n_arms: int, seed: int = 0):
        self.n_arms = n_arms
        self.counts = np.zeros(n_arms, np.int64)
        self.means = np.zeros(n_arms, np.float64)
        self.m2 = np.zeros(n_arms, np.float64)     # sum of squared deviations
        self.t = 0
        self.rng = np.random.default_rng(seed)

    def select(self) -> int:
        raise NotImplementedError

    def update(self, arm: int, reward: float) -> None:
        self.t += 1
        self.counts[arm] += 1
        d = reward - self.means[arm]
        self.means[arm] += d / self.counts[arm]
        self.m2[arm] += d * (reward - self.means[arm])

    def select_batch(self, n: int) -> np.ndarray:
        """n arm indices for one batched tick, all drawn against the
        PRE-batch state (stochastic policies diversify via sampling;
        deterministic ones may repeat — see UCB1's fantasy-pull override)."""
        return np.array([self.select() for _ in range(n)], np.int32)

    def update_batch(self, arms, rewards) -> None:
        """Merge a tick's observations; order-independent (Chan's parallel
        mean/M2 merge per arm, grouped by arm index)."""
        arms = np.asarray(arms, np.int64)
        rewards = np.asarray(rewards, np.float64)
        for a in np.unique(arms):
            rs = rewards[arms == a]
            nb = rs.size
            mb = rs.mean()
            m2b = float(((rs - mb) ** 2).sum())
            na = int(self.counts[a])
            d = mb - self.means[a]
            n = na + nb
            self.means[a] += d * nb / n
            self.m2[a] += m2b + d * d * na * nb / n
            self.counts[a] = n
        self.t += arms.size

    def variance(self, arm: int) -> float:
        if self.counts[arm] < 2:
            return 0.25
        return self.m2[arm] / self.counts[arm]

    @property
    def arm_values(self) -> np.ndarray:
        return self.means.copy()

    def state_dict(self) -> dict:
        return {"counts": self.counts.copy(), "means": self.means.copy(),
                "m2": self.m2.copy(), "t": self.t}


class UCB1(Bandit):
    def select(self) -> int:
        for a in range(self.n_arms):       # play each arm once first
            if self.counts[a] == 0:
                return a
        t = max(self.t, 1)
        bonus = np.sqrt(2.0 * math.log(t) / self.counts)
        return int(np.argmax(self.means + bonus))

    def select_batch(self, n: int) -> np.ndarray:
        # fantasy pulls: deterministic UCB would hand every stream the same
        # arm; incrementing a pseudo-count per pick diversifies the batch
        # while staying a pure function of the pre-batch state.
        counts = self.counts.astype(np.float64).copy()
        picks = np.empty(n, np.int32)
        for j in range(n):
            zero = np.flatnonzero(counts == 0)
            if zero.size:
                a = int(zero[0])
            else:
                bonus = np.sqrt(2.0 * math.log(max(self.t + j, 1)) / counts)
                a = int(np.argmax(self.means + bonus))
            picks[j] = a
            counts[a] += 1.0
        return picks


class UCBTuned(Bandit):
    def select(self) -> int:
        for a in range(self.n_arms):
            if self.counts[a] == 0:
                return a
        t = max(self.t, 1)
        logt = math.log(t)
        v = np.array([self.variance(a) for a in range(self.n_arms)])
        v_t = v + np.sqrt(2.0 * logt / self.counts)
        bonus = np.sqrt(logt / self.counts * np.minimum(0.25, v_t))
        return int(np.argmax(self.means + bonus))


class ThompsonBeta(Bandit):
    """Beta-Bernoulli TS for binary rewards (token-level)."""

    def __init__(self, n_arms: int, seed: int = 0, a0: float = 1.0, b0: float = 1.0):
        super().__init__(n_arms, seed)
        self.alpha = np.full(n_arms, a0)
        self.beta = np.full(n_arms, b0)

    def select(self) -> int:
        return int(np.argmax(self.rng.beta(self.alpha, self.beta)))

    def update(self, arm: int, reward: float) -> None:
        super().update(arm, reward)
        self.alpha[arm] += reward
        self.beta[arm] += 1.0 - reward

    def update_batch(self, arms, rewards) -> None:
        Bandit.update_batch(self, arms, rewards)
        arms = np.asarray(arms, np.int64)
        rewards = np.asarray(rewards, np.float64)
        np.add.at(self.alpha, arms, rewards)
        np.add.at(self.beta, arms, 1.0 - rewards)

    @property
    def arm_values(self) -> np.ndarray:
        return self.alpha / (self.alpha + self.beta)


class ThompsonGaussian(Bandit):
    """Gaussian TS with known observation noise (sequence-level r in [0,1])."""

    def __init__(self, n_arms: int, seed: int = 0, prior_mean: float = 0.5,
                 prior_var: float = 1.0, noise_var: float = 0.05):
        super().__init__(n_arms, seed)
        self.prior_mean = prior_mean
        self.prior_var = prior_var
        self.noise_var = noise_var

    def _posterior(self, arm: int):
        n = self.counts[arm]
        prec = 1.0 / self.prior_var + n / self.noise_var
        var = 1.0 / prec
        mean = var * (self.prior_mean / self.prior_var +
                      n * self.means[arm] / self.noise_var)
        return mean, var

    def select(self) -> int:
        samples = []
        for a in range(self.n_arms):
            m, v = self._posterior(a)
            samples.append(self.rng.normal(m, math.sqrt(v)))
        return int(np.argmax(samples))

    @property
    def arm_values(self) -> np.ndarray:
        return np.array([self._posterior(a)[0] for a in range(self.n_arms)])


class EXP3(Bandit):
    """EXP3 (Auer et al. 2002b): adversarial bandit over rewards in [0, 1].

    Batched updates use the selection distribution frozen at the start of
    the tick for the importance weights; the per-observation multiplicative
    weight updates then commute, so the batch is order-independent."""

    def __init__(self, n_arms: int, seed: int = 0, gamma: float = 0.1):
        super().__init__(n_arms, seed)
        self.gamma = gamma
        self.log_w = np.zeros(n_arms, np.float64)

    def probs(self) -> np.ndarray:
        w = np.exp(self.log_w - self.log_w.max())
        w /= w.sum()
        return (1.0 - self.gamma) * w + self.gamma / self.n_arms

    def select(self) -> int:
        return int(self.rng.choice(self.n_arms, p=self.probs()))

    def select_batch(self, n: int) -> np.ndarray:
        return self.rng.choice(self.n_arms, size=n, p=self.probs()).astype(np.int32)

    def update(self, arm: int, reward: float) -> None:
        self.update_batch(np.array([arm]), np.array([reward]))

    def update_batch(self, arms, rewards) -> None:
        p = self.probs()                      # pre-batch state: commutes
        arms = np.asarray(arms, np.int64)
        rewards = np.asarray(rewards, np.float64)
        xhat = np.clip(rewards, 0.0, 1.0) / p[arms]
        np.add.at(self.log_w, arms, self.gamma * xhat / self.n_arms)
        self.log_w -= self.log_w.max()        # keep exp() in range
        Bandit.update_batch(self, arms, rewards)

    @property
    def arm_values(self) -> np.ndarray:
        return self.probs()


class EpsilonGreedy(Bandit):
    def __init__(self, n_arms: int, seed: int = 0, eps: float = 0.1):
        super().__init__(n_arms, seed)
        self.eps = eps

    def select(self) -> int:
        for a in range(self.n_arms):
            if self.counts[a] == 0:
                return a
        if self.rng.random() < self.eps:
            return int(self.rng.integers(self.n_arms))
        return int(np.argmax(self.means))


class BanditBank:
    """Token-level setup: one independent bandit per draft position."""

    def __init__(self, n_positions: int, factory, seed: int = 0):
        self.bandits: List[Bandit] = [factory(seed + i) for i in range(n_positions)]

    def select_all(self) -> np.ndarray:
        return np.array([b.select() for b in self.bandits], np.int32)

    def select_all_batch(self, n: int) -> np.ndarray:
        """(n, positions) arm matrix for one batched tick."""
        return np.stack([b.select_batch(n) for b in self.bandits], axis=1)

    def update(self, position: int, arm: int, reward: float) -> None:
        self.bandits[position].update(arm, reward)

    def update_batch(self, position: int, arms, rewards) -> None:
        self.bandits[position].update_batch(arms, rewards)

    @property
    def arm_values(self) -> np.ndarray:
        return np.stack([b.arm_values for b in self.bandits])


def make_bandit(kind: str, n_arms: int, seed: int = 0) -> Bandit:
    kinds = {"ucb1": UCB1, "ucb_tuned": UCBTuned, "ts_beta": ThompsonBeta,
             "ts_gaussian": ThompsonGaussian, "eps_greedy": EpsilonGreedy,
             "exp3": EXP3}
    return kinds[kind](n_arms, seed)
