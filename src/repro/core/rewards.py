"""Reward formulations (Section 3.2) and the per-verify modeled cost.

The cost model is roofline-style: one forward token costs a model its
active-parameter count (``ModelBundle.cost_per_token``), and decode is
memory-bound, so PRECISION scales that cost by the bytes actually streamed
— int8 draft weights move roughly half the bytes of bf16, which
``PRECISION_COST_FACTOR`` models as a 0.55x draft cost (payload halves;
per-channel scales and the unquantized embeddings/norms keep it off the
ideal 0.5).  ``modeled_session_cost`` is the single accounting rule every
engine uses: a session = n draft forwards (at the draft's precision) plus
one target verify forward.

Precision thereby becomes a BANDIT COST AXIS: a quantized-draft
``ShapeArm`` (``core/arms.py``) exposes a cheaper modeled cost per verify,
and the cost-adjusted reward lets the TapOut meta-bandit trade acceptance
against draft-side bytes with no new thresholds.
"""
from __future__ import annotations

# Relative modeled cost of one DRAFT forward token by weight precision.
PRECISION_COST_FACTOR = {"fp": 1.0, "fp32": 1.0, "bf16": 1.0, "int8": 0.55}


def precision_cost_factor(precision: str) -> float:
    return PRECISION_COST_FACTOR[precision]


def modeled_session_cost(n_draft_tokens: int, cost_draft: float,
                         cost_target: float, precision: str = "bf16") -> float:
    """Modeled cost of ONE draft/verify session: ``n_draft_tokens`` draft
    forwards (drafted tokens + any rollback refeeds) at the draft's
    precision, plus one target verify forward.  Callers whose draft bundle
    is already precision-scaled (engine-wide ``quant_draft``) pass the
    default precision."""
    return (n_draft_tokens * cost_draft * precision_cost_factor(precision)
            + cost_target)


def r_simple(n_accepted: int, n_drafted: int, gamma_max: int) -> float:
    """Normalized acceptance length |Y| / gamma."""
    return n_accepted / max(gamma_max, 1)


def r_blend(n_accepted: int, n_drafted: int, gamma_max: int,
            alpha: float = 0.5) -> float:
    """alpha * |Y|/gamma + (1-alpha) * |Y|/|X| (paper fixes alpha = 0.5)."""
    if n_drafted == 0:
        return 0.0
    return (alpha * n_accepted / max(gamma_max, 1)
            + (1.0 - alpha) * n_accepted / n_drafted)


def r_cost_adjusted(n_accepted: int, n_drafted: int, gamma_max: int,
                    rel_cost: float = 1.0) -> float:
    """``r_simple`` divided by the arm's modeled cost RELATIVE TO THE
    POOL'S CHEAPEST arm (``rel_cost >= 1``, see
    ``core.arms.shape_cost_factor``): equal acceptance at a lower modeled
    cost earns proportionally more reward — the per-verify cost model the
    quantized-draft arms compete on.  Normalizing against the cheapest arm
    (not the dearest) keeps the reward in [0, 1] WITHOUT clipping, so
    cheap arms never saturate and stay distinguishable."""
    return r_simple(n_accepted, n_drafted, gamma_max) / max(rel_cost, 1.0)


REWARDS = {"simple": r_simple, "blend": r_blend, "cost": r_cost_adjusted}
