"""Reward formulations (Section 3.2) and the per-verify modeled cost.

The cost model is roofline-style: one forward token costs a model its
active-parameter count (``ModelBundle.cost_per_token``), and decode is
memory-bound, so PRECISION scales that cost by the bytes actually streamed
— int8 draft weights move roughly half the bytes of bf16, which
``PRECISION_COST_FACTOR`` models as a 0.55x draft cost (payload halves;
per-channel scales and the unquantized embeddings/norms keep it off the
ideal 0.5).  ``modeled_session_cost`` is the single accounting rule every
engine uses: a session = n draft forwards (at the draft's precision) plus
one target verify forward.

Precision thereby becomes a BANDIT COST AXIS: a quantized-draft
``ShapeArm`` (``core/arms.py``) exposes a cheaper modeled cost per verify,
and the cost-adjusted reward lets the TapOut meta-bandit trade acceptance
against draft-side bytes with no new thresholds.
"""
from __future__ import annotations

# Relative modeled cost of one DRAFT forward token by weight precision.
PRECISION_COST_FACTOR = {"fp": 1.0, "fp32": 1.0, "bf16": 1.0, "int8": 0.55}


def precision_cost_factor(precision: str) -> float:
    return PRECISION_COST_FACTOR[precision]


def modeled_session_cost(n_draft_tokens: int, cost_draft: float,
                         cost_target: float, precision: str = "bf16", *,
                         routed_frac: float = 0.0,
                         routing_density: float = 1.0) -> float:
    """Modeled cost of ONE draft/verify session: ``n_draft_tokens`` draft
    forwards (drafted tokens + any rollback refeeds) at the draft's
    precision, plus one target verify forward.  Callers whose draft bundle
    is already precision-scaled (engine-wide ``quant_draft``) pass the
    default precision.

    ROUTING-DENSITY TERM (MoE targets): the memory-bound verify streams
    each routed expert's weights ONCE however many tokens hit it, so the
    routed share of ``cost_target`` (which assumes the single-token top_k
    active-parameter count) scales by ``routing_density`` =
    mean(distinct experts hit per stream) / top_k.  One decode token gives
    density 1 (cost unchanged); a gamma-token verify hits up to
    gamma * top_k distinct experts, so SPECULATION RAISES the per-verify
    routed cost — the workload-dependent trade-off the bandit learns from
    (``moe_routed_frac`` supplies the routed share; dense targets keep the
    defaults and are untouched)."""
    target_factor = 1.0 - routed_frac + routed_frac * routing_density
    return (n_draft_tokens * cost_draft * precision_cost_factor(precision)
            + cost_target * target_factor)


def moe_routed_frac(cfg) -> float:
    """Fraction of a target's ACTIVE per-token parameters that are routed
    experts — the share of ``cost_target`` the routing-density term scales.
    0.0 for dense targets (keeps ``modeled_session_cost`` untouched)."""
    if getattr(cfg, "moe", None) is None:
        return 0.0
    import dataclasses
    active = cfg.active_param_count()
    # routed active params = active count minus the same model with zero
    # routed experts touched per token (router/shared/attention unchanged)
    no_routed = cfg.replace(moe=dataclasses.replace(cfg.moe, top_k=0))
    routed = active - no_routed.active_param_count()
    return max(0.0, min(1.0, routed / max(active, 1)))


def r_simple(n_accepted: int, n_drafted: int, gamma_max: int) -> float:
    """Normalized acceptance length |Y| / gamma."""
    return n_accepted / max(gamma_max, 1)


def r_blend(n_accepted: int, n_drafted: int, gamma_max: int,
            alpha: float = 0.5) -> float:
    """alpha * |Y|/gamma + (1-alpha) * |Y|/|X| (paper fixes alpha = 0.5)."""
    if n_drafted == 0:
        return 0.0
    return (alpha * n_accepted / max(gamma_max, 1)
            + (1.0 - alpha) * n_accepted / n_drafted)


def r_cost_adjusted(n_accepted: int, n_drafted: int, gamma_max: int,
                    rel_cost: float = 1.0) -> float:
    """``r_simple`` divided by the arm's modeled cost RELATIVE TO THE
    POOL'S CHEAPEST arm (``rel_cost >= 1``, see
    ``core.arms.shape_cost_factor``): equal acceptance at a lower modeled
    cost earns proportionally more reward — the per-verify cost model the
    quantized-draft arms compete on.  Normalizing against the cheapest arm
    (not the dearest) keeps the reward in [0, 1] WITHOUT clipping, so
    cheap arms never saturate and stay distinguishable."""
    return r_simple(n_accepted, n_drafted, gamma_max) / max(rel_cost, 1.0)


REWARDS = {"simple": r_simple, "blend": r_blend, "cost": r_cost_adjusted}


# ------------------------------------------------- per-drafter state model
#
# Drafter identity is an arm dimension (core/arms.py ``ShapeArm.drafter``),
# and the dominant per-drafter cost difference at serving time is the
# per-stream DRAFT STATE each candidate keeps resident:
#
#   * a KV drafter (small transformer, EAGLE-style head) holds
#     2 * layers * kv_heads * head_dim * L bytes — LINEAR in context length;
#   * a Mamba2/SSD drafter holds a fixed conv window + recurrent ssm state —
#     O(1) in context length, which is what makes an extra recurrent
#     drafter nearly free per stream at long contexts.
#
# These helpers are the roofline model ``bench_drafters.py`` and the
# ``DrafterPool`` cost factors are built on; they intentionally count only
# the decode-resident state (not weights — weights are amortized across the
# batch and already covered by ``cost_per_token``).

_KV_ITEMSIZE = {"fp": 2, "bf16": 2, "fp32": 4, "int8": 1}


def kv_state_bytes(cfg, seq_len: int, kv_dtype=None) -> int:
    """Per-stream KV-cache bytes of an attention drafter at context length
    ``seq_len`` (k + v per attention layer; int8 KV stores 1-byte payload
    plus a per-(head, position) fp16 scale pair)."""
    key = "int8" if kv_dtype == "int8" else "bf16"
    item = _KV_ITEMSIZE[key]
    hd = cfg.resolved_head_dim
    per_tok = 2 * cfg.num_kv_heads * hd * item
    if key == "int8":
        per_tok += 2 * cfg.num_kv_heads * 2 * 2  # k+v fp16 scales
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.block_kind(i) != "mamba2")
    return int(n_attn * per_tok * seq_len)


def ssm_state_bytes(cfg) -> int:
    """Per-stream recurrent draft-state bytes of a Mamba2/SSD drafter:
    a (d_conv - 1)-token conv window plus the (heads, head_dim, d_state)
    fp32 ssm state per mamba2 layer — INDEPENDENT of context length."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    conv_dim = d_in + 2 * s.ngroups * s.d_state
    n_heads = d_in // s.head_dim
    per_layer = ((s.d_conv - 1) * conv_dim * 4          # conv window (f32)
                 + n_heads * s.head_dim * s.d_state * 4)  # ssm state (f32)
    n_ssm = sum(1 for i in range(cfg.num_layers)
                if cfg.block_kind(i) == "mamba2")
    return int(n_ssm * per_layer)


def drafter_state_bytes(cfg, seq_len: int, kv_dtype=None) -> int:
    """Per-stream decode-resident draft-state bytes for any drafter config
    at context length ``seq_len``: KV bytes for attention layers (linear in
    L) plus recurrent bytes for mamba2 layers (O(1) in L)."""
    total = kv_state_bytes(cfg, seq_len, kv_dtype)
    if cfg.ssm is not None:
        total += ssm_state_bytes(cfg)
    return total
