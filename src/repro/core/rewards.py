"""Reward formulations (Section 3.2)."""
from __future__ import annotations


def r_simple(n_accepted: int, n_drafted: int, gamma_max: int) -> float:
    """Normalized acceptance length |Y| / gamma."""
    return n_accepted / max(gamma_max, 1)


def r_blend(n_accepted: int, n_drafted: int, gamma_max: int,
            alpha: float = 0.5) -> float:
    """alpha * |Y|/gamma + (1-alpha) * |Y|/|X| (paper fixes alpha = 0.5)."""
    if n_drafted == 0:
        return 0.0
    return (alpha * n_accepted / max(gamma_max, 1)
            + (1.0 - alpha) * n_accepted / n_drafted)


REWARDS = {"simple": r_simple, "blend": r_blend}
