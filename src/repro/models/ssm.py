"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Prefill/train: chunked SSD — quadratic attention-like math inside chunks of
``chunk_size`` tokens, linear recurrence across chunks (lax.scan).  Decode:
O(1) recurrent state update.  The per-chunk einsum block is the compute
hot-spot and has a Pallas TPU kernel (``repro.kernels.ssd``); this module is
the XLA path and the numerical reference.

Layout: d_inner = expand*d_model, heads H = d_inner/head_dim P, groups G
(B/C shared across H/G heads), state N = d_state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm
from .sharding import constrain


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.ngroups * s.d_state
    return d_in, nheads, conv_dim


def init_ssm(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    # fused input projection: [z (gate), x, B, C, dt]
    zxbcdt = 2 * d_in + 2 * s.ngroups * s.d_state + H
    dt = jnp.exp(jax.random.uniform(ks[1], (H,)) *
                 (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "w_in": dense_init(ks[0], d, zxbcdt, dtype),
        "conv_w": (jax.random.normal(ks[2], (s.d_conv, conv_dim), dtype) * 0.1),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "out_norm": jnp.zeros((d_in,), dtype),
        "w_out": dense_init(ks[3], d_in, d, dtype),
    }


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv; returns (y, new_state). xBC: (B,S,Cd)."""
    K = conv_w.shape[0]
    B, S, Cd = xBC.shape
    if conv_state is None:
        ctx = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    y = sum(ctx[:, i:i + S] * conv_w[i] for i in range(K)) + conv_b
    new_state = ctx[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, Cd), xBC.dtype)
    return jax.nn.silu(y), new_state


def _segsum(x):
    """x: (..., Q). Returns (..., Q, Q) lower-tri cumulative sums
    seg[i,j] = sum_{j<k<=i} x[k] (i>=j), -inf above diagonal."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None,
                use_kernel: bool = False):
    """Chunked SSD scan.

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      softplus'd step sizes
    A:  (H,)           negative decay rates
    Bm: (B, S, G, N)   input mats;  Cm: (B, S, G, N) output mats
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q
    rep = H // G

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, G, N)
    Cc = Cm.reshape(Bsz, nc, Q, G, N)
    dA = dtc * A  # (B,nc,Q,H)  negative
    dA_cs = jnp.cumsum(dA, axis=2)

    if use_kernel:
        from repro.kernels import ops as kops
        y_diag, chunk_states = kops.ssd_chunk(xc, dtc, dA, dA_cs, Bc, Cc)
    else:
        # intra-chunk ("diagonal") output
        L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # (B,nc,H,Q,Q)
        CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)           # (B,nc,G,Q,Q)
        CB = jnp.repeat(CB, rep, axis=2)                        # -> H
        scores = CB * L
        y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", scores, dtc, xc)
        # per-chunk end states (B repeated to heads — do NOT sum over groups)
        decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # (B,nc,Q,H)
        Br = jnp.repeat(Bc, rep, axis=3)                        # (B,nc,Q,H,N)
        chunk_states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn",
                                  Br, dtc * decay_to_end, xc)   # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                    # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, inp):
        cd, cs = inp                                             # (B,H), (B,H,P,N)
        h_new = h * cd[..., None, None] + cs
        return h_new, h                                          # emit state *entering* chunk

    _, h_prev = jax.lax.scan(step, init_state.astype(jnp.float32),
                             (chunk_decay.transpose(1, 0, 2),
                              chunk_states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                     # (B,nc,H,P,N)
    final_state = (h_prev[:, -1] * chunk_decay[:, -1][..., None, None]
                   + chunk_states[:, -1].astype(jnp.float32))

    # inter-chunk ("off-diagonal") output
    state_decay = jnp.exp(dA_cs)                                  # decay from chunk start
    Cr = jnp.repeat(Cc, rep, axis=3)                              # (B,nc,Q,H,N)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Cr,
                       h_prev.astype(Cr.dtype), state_decay.astype(Cr.dtype))
    y = (y_diag + y_off).reshape(Bsz, Sp, H, P)[:, :S]
    return y, final_state


def ssm_mixer(params, cfg, x, state=None, *, decode: bool = False,
              use_kernel: bool = False):
    """Full Mamba-2 block mixer.  state = {"conv": (B,K-1,Cd), "ssm": (B,H,P,N)}.

    Returns (y, new_state).  When state is None (training), no state is
    returned-updated (final state discarded).
    """
    s = cfg.ssm
    d_in, H, conv_dim = _dims(cfg)
    G, N, P = s.ngroups, s.d_state, s.head_dim
    Bsz, S, _ = x.shape
    proj = x @ params["w_in"]
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + conv_dim]
    dt_raw = proj[..., d_in + conv_dim:]
    conv_state = state["conv"] if state is not None else None
    xBC, new_conv = _causal_conv(xBC, params["conv_w"], params["conv_b"], conv_state)
    xs = xBC[..., :d_in].reshape(Bsz, S, H, P)
    Bm = xBC[..., d_in:d_in + G * N].reshape(Bsz, S, G, N)
    Cm = xBC[..., d_in + G * N:].reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])                                          # (H,)

    if decode and S == 1:
        h0 = state["ssm"].astype(jnp.float32)                    # (B,H,P,N)
        dt1 = dt[:, 0]                                           # (B,H)
        dA = jnp.exp(dt1 * A)                                    # (B,H)
        Br = jnp.repeat(Bm[:, 0], H // G, axis=1)                # (B,H,N)
        Bx = jnp.einsum("bhn,bh,bhp->bhpn",
                        Br.astype(jnp.float32), dt1,
                        xs[:, 0].astype(jnp.float32))
        h1 = h0 * dA[..., None, None] + Bx
        Cr = jnp.repeat(Cm[:, 0], H // G, axis=1)                # (B,H,N)
        y = jnp.einsum("bhn,bhpn->bhp", Cr.astype(jnp.float32), h1)
        y = y[:, None]                                           # (B,1,H,P)
        new_ssm = h1
    else:
        init = state["ssm"] if state is not None else None
        y, new_ssm = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk_size,
                                 init_state=init, use_kernel=use_kernel)
    y = y + params["D"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"], cfg.rms_eps)
    out = y @ params["w_out"]
    new_state = {"conv": new_conv, "ssm": new_ssm} if (state is not None or decode) else None
    return out, new_state


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    d_in, H, conv_dim = _dims(cfg)
    return {"conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
            "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32)}
