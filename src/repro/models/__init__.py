"""Public API of the model stack.

Stable names the docs (``docs/index.md``) point at: configs (``config``),
the config-driven transformer family and its cache constructors
(``transformer`` — dense ``init_cache``/``step`` AND paged
``init_paged_cache``/``paged_step``; both accept ``kv_dtype="int8"``),
cache specs and rollback (``cache``), and int8 quantization helpers
(``quant``).
"""
from .config import (EncDecConfig, MLAConfig, MoEConfig, ModelConfig,
                     RGLRUConfig, SSMConfig, VisionStubConfig)
from .transformer import (commit_tree_path, decode_step, forward_hidden,
                          init_cache, init_paged_cache, init_params,
                          init_tree_nodes, logits_fn, paged_step, prefill,
                          step, tree_step, verify_chunk)
from .cache import (BlockAllocator, CacheSpec, PoolExhausted,
                    build_cache_spec, build_paged_cache_spec, paged_rollback,
                    rollback)
from .quant import (dequantize_weight, qmatmul, quantize_params,
                    quantize_rows, quantize_weight)

__all__ = [
    # configs
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
    "EncDecConfig", "VisionStubConfig",
    # transformer passes
    "init_params", "forward_hidden", "logits_fn",
    "step", "prefill", "decode_step", "verify_chunk",
    "paged_step", "tree_step", "commit_tree_path", "init_tree_nodes",
    # caches
    "init_cache", "init_paged_cache", "build_cache_spec",
    "build_paged_cache_spec", "CacheSpec", "rollback", "paged_rollback",
    "BlockAllocator", "PoolExhausted",
    # quantization
    "quantize_params", "quantize_weight", "dequantize_weight", "qmatmul",
    "quantize_rows",
]
