from .config import (EncDecConfig, MLAConfig, MoEConfig, ModelConfig,
                     RGLRUConfig, SSMConfig, VisionStubConfig)
from .transformer import (decode_step, forward_hidden, init_cache, init_params,
                          logits_fn, prefill, step, verify_chunk)
from .cache import build_cache_spec, rollback

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
    "EncDecConfig", "VisionStubConfig", "init_params", "init_cache",
    "forward_hidden", "step", "prefill", "decode_step", "verify_chunk",
    "logits_fn", "build_cache_spec", "rollback",
]
