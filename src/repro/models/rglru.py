"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> [gate branch (GeLU), recurrent branch: conv1d -> RG-LRU] ->
elementwise product -> output projection.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_r x_t + b_r)          recurrence gate
    i_t = sigmoid(W_i x_t + b_i)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill uses an associative scan over the sequence (log-depth on TPU);
decode is the O(1) recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init

_C = 8.0  # Griffin's fixed scalar


def init_rglru(key, cfg, dtype=jnp.float32):
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a ~ uniform(0.9, 0.999)^c-ish (Griffin appendix)
    u = jax.random.uniform(ks[4], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^{-1}(-log u / c)
    return {
        "w_x": dense_init(ks[0], d, w, dtype),      # recurrent branch in-proj
        "w_y": dense_init(ks[1], d, w, dtype),      # gate branch in-proj
        "conv_w": jax.random.normal(ks[2], (r.d_conv, w), dtype) * 0.1,
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": dense_init(ks[3], w, w, dtype),
        "b_r": jnp.zeros((w,), dtype),
        "w_i": dense_init(ks[5], w, w, dtype),
        "b_i": jnp.zeros((w,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _conv(x, w, b, state):
    K = w.shape[0]
    B, S, W = x.shape
    if state is None:
        ctx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(ctx[:, i:i + S] * w[i] for i in range(K)) + b
    return y, ctx[:, -(K - 1):]


def _rglru_scan(params, x, h0):
    """x: (B,S,W) fp32; h0: (B,W) fp32. Returns (y, h_final)."""
    r = jax.nn.sigmoid(x @ params["w_r"].astype(jnp.float32) + params["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(x @ params["w_i"].astype(jnp.float32) + params["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r               # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)
    # h_t = a_t h_{t-1} + g_t  via associative scan on (a, g)
    def combine(l, r_):
        a1, g1 = l
        a2, g2 = r_
        return a1 * a2, g1 * a2 + g2
    a_s, g_s = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = a_s * h0[:, None, :] + g_s
    return h, h[:, -1]


def rglru_mixer(params, cfg, x, state=None, *, decode: bool = False):
    """state = {"conv": (B,K-1,W), "rec": (B,W)}. Returns (y, new_state)."""
    xr = x @ params["w_x"]
    gate = jax.nn.gelu(x @ params["w_y"])
    conv_state = state["conv"] if state is not None else None
    xr, new_conv = _conv(xr, params["conv_w"], params["conv_b"], conv_state)
    h0 = (state["rec"].astype(jnp.float32) if state is not None
          else jnp.zeros((x.shape[0], xr.shape[-1]), jnp.float32))
    if decode and x.shape[1] == 1:
        xf = xr.astype(jnp.float32)
        r = jax.nn.sigmoid(xf @ params["w_r"].astype(jnp.float32) + params["b_r"].astype(jnp.float32))
        i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) + params["b_i"].astype(jnp.float32))
        log_a = -_C * jax.nn.softplus(params["lam"]) * r
        a = jnp.exp(log_a)
        h = a * h0[:, None] + jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i * xf)
        y, h_fin = h, h[:, -1]
    else:
        y, h_fin = _rglru_scan(params, xr.astype(jnp.float32), h0)
    out = (y.astype(x.dtype) * gate) @ params["w_out"]
    new_state = ({"conv": new_conv, "rec": h_fin}
                 if (state is not None or decode) else None)
    return out, new_state


def init_rglru_state(cfg, batch, dtype=jnp.float32):
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, r.d_conv - 1, w), dtype),
            "rec": jnp.zeros((batch, w), jnp.float32)}
