"""Int8 quantization: draft weights (quantize-once) and KV-cache rows.

Two independent axes, both symmetric int8 with float32 scales (the
TensorRT-Model-Optimizer per-channel recipe, expressed functionally):

* **Weights** — ``quantize_params`` walks a parameter pytree ONCE at engine
  init and replaces each linear weight ``w (..., d_in, d_out)`` with
  ``{"qw": int8, "scale": (..., d_out) f32}`` where
  ``scale[c] = max|w[:, c]| / 127`` (symmetric PER-OUTPUT-CHANNEL).
  ``qmatmul`` then computes ``(x @ qw) * scale`` — the dequantization rides
  the matmul epilogue, the bf16 weight matrix is never materialized.
  Per-channel matters: one outlier column no longer clips every other
  column's resolution, and the scale factors out of the matmul exactly
  (``x @ (qw * scale) == (x @ qw) * scale``).

* **KV rows** — caches built with ``kv_dtype="int8"`` store K/V (and MLA
  latents) as int8 with one scale PER STORED ROW PER HEAD:
  ``k (…, L, G, D) int8`` + ``k_scale (…, L, G) f32`` (headless latents
  carry one scale per row).  Rows are quantized at write time
  (``quantize_rows``) and dequantized at read time — in-register by the
  quantized Pallas decode kernels (``kernels.decode_attention``), by a
  gather + multiply on the XLA paths.  Per-row scales make writes purely
  local (no running amax state to thread through jit) and keep rollback
  semantics untouched: a dead row's scale is as dead as its payload.

The roofline consequence (why the bandit cares): decode is memory-bound,
so int8 draft weights and int8 KV each roughly halve the bytes the hot
loop streams — ``core.rewards.precision_cost_factor`` exposes that as the
modeled relative cost of a quantized-draft arm.
"""
from __future__ import annotations

import jax.numpy as jnp

# Linear-layer weight leaves eligible for int8 quantization (attention,
# MLA and dense-FFN projections). Everything else — embeddings (table
# lookups + tied lm_head), norms/biases (1-D), MoE expert banks (gathered
# by index, see models/moe.py), router/shared/cross/encoder subtrees —
# stays full precision.
WEIGHT_QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo",                      # attention projections
    "w_in", "w_out", "w_gate",                   # dense FFN
    "w_q", "w_dq", "w_uq", "w_dkv", "w_uk", "w_uv",  # MLA projections
})

# Subtrees ``quantize_params`` never descends into.
SKIP_SUBTREES = frozenset({
    "embed", "lm_head", "experts", "shared", "router",
    "encoder", "enc_proj", "vis_proj", "cross",
})

# KV-cache leaves that carry an int8 payload when ``kv_dtype="int8"``;
# each pairs with a ``<name>_scale`` float32 leaf.
KV_QUANT_LEAVES = ("k", "v", "ckv", "krope")


def scale_key(leaf: str) -> str:
    return leaf + "_scale"


# ------------------------------------------------------------- weights

def quantize_weight(w):
    """Symmetric per-output-channel int8: w (..., d_in, d_out) ->
    {"qw": int8 same shape, "scale": (..., d_out) float32}."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[..., None, :]),
                 -127, 127).astype(jnp.int8)
    return {"qw": q, "scale": scale}


def is_quantized(w) -> bool:
    return isinstance(w, dict) and "qw" in w


def dequantize_weight(qw, dtype=jnp.float32):
    return (qw["qw"].astype(jnp.float32)
            * qw["scale"][..., None, :]).astype(dtype)


def resolve_weight(w, dtype=None):
    """A plain weight matrix whichever representation ``w`` is in (for the
    few sites that index/reshape the matrix instead of matmul-ing it)."""
    if is_quantized(w):
        return dequantize_weight(w, dtype or jnp.float32)
    return w if dtype is None else w.astype(dtype)


def qmatmul(x, w):
    """``x @ w`` for raw OR quantized ``w`` — the single matmul entry point
    of the model stack.  Quantized: the int8 matrix is cast to the
    activation dtype on the fly and the per-channel scale is applied to the
    OUTPUT (exactly equal to dequantize-then-matmul, without ever holding
    the dequantized matrix)."""
    if not is_quantized(w):
        return x @ w
    return (x @ w["qw"].astype(x.dtype)) * w["scale"].astype(x.dtype)


def quantize_params(params):
    """Quantize every eligible linear weight in a parameter pytree (see
    ``WEIGHT_QUANT_KEYS`` / ``SKIP_SUBTREES``).  Returns a NEW pytree; the
    input is untouched.  Works on unrolled layer lists and scan-stacked
    cycles alike (the per-channel axis is -1, the reduce axis -2, whatever
    leading stack axes exist)."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if key in SKIP_SUBTREES:
                    out[key] = val
                elif key in WEIGHT_QUANT_KEYS and not is_quantized(val):
                    out[key] = quantize_weight(val)
                else:
                    out[key] = walk(val)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(params)


# ------------------------------------------------------------- KV rows

def quantize_rows(x):
    """Symmetric per-row int8 over the LAST axis: x (..., D) ->
    (int8 (..., D), scale (...) float32).  For attention K/V the trailing
    shape is (L, G, D) so the scale is per stored row per head; for MLA
    latents (L, R) it is one scale per row."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_rows(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def kv_is_quantized(layer_cache, leaf: str = "k") -> bool:
    """True iff this cache layer stores ``leaf`` as int8 (trace-time
    static — dtypes are part of the jaxpr, so jitted code branches free)."""
    return layer_cache[leaf].dtype == jnp.int8


__all__ = [
    "WEIGHT_QUANT_KEYS", "SKIP_SUBTREES", "KV_QUANT_LEAVES", "scale_key",
    "quantize_weight", "is_quantized", "dequantize_weight", "resolve_weight",
    "qmatmul", "quantize_params",
    "quantize_rows", "dequantize_rows", "kv_is_quantized",
]
