"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed into a per-token latent ``c_kv`` of rank
``kv_lora_rank`` plus a single shared RoPE key of dim ``qk_rope_head_dim``;
the cache stores only these (the technique's memory win).

Two execution paths:
  * prefill/train: decompress K/V per head and reuse the flash ``sdpa``
    (chunked, long-sequence safe).
  * cached decode (short S): the "absorbed" formulation — queries are folded
    through W_uk so attention runs directly against the latent cache, never
    materializing per-head K/V for the full context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import NEG_INF, sdpa
from .common import apply_rope, dense_init, rms_norm
from .quant import (dequantize_rows, kv_is_quantized, qmatmul, quantize_rows,
                    resolve_weight)
from .sharding import constrain


def init_mla(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], d, m.q_lora_rank, dtype)
        p["q_norm"] = jnp.zeros((m.q_lora_rank,), dtype)
        p["w_uq"] = dense_init(ks[1], m.q_lora_rank, H * qk_dim, dtype)
    else:
        p["w_q"] = dense_init(ks[0], d, H * qk_dim, dtype)
    p["w_dkv"] = dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype)
    p["kv_norm"] = jnp.zeros((m.kv_lora_rank,), dtype)
    p["w_uk"] = dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype)
    p["w_uv"] = dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dtype)
    p["wo"] = dense_init(ks[5], H * m.v_head_dim, d, dtype)
    return p


def _queries(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        q = qmatmul(rms_norm(qmatmul(x, params["w_dq"]), params["q_norm"],
                             cfg.rms_eps), params["w_uq"])
    else:
        q = qmatmul(x, params["w_q"])
    q = q.reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(params, cfg, x, positions):
    m = cfg.mla
    ckv_rope = qmatmul(x, params["w_dkv"])
    c_kv, k_rope = jnp.split(ckv_rope, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.rms_eps)
    # shared (single-"head") rope key, stored post-rotation
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _expand_kv(params, cfg, c_kv, k_rope):
    """Decompress latents to per-head K/V (prefill path)."""
    m = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.num_heads
    k_nope = qmatmul(c_kv, params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = qmatmul(c_kv, params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))], axis=-1)
    return k, v


def mla_train(params, cfg, x, positions, impl: str = "auto"):
    m = cfg.mla
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    k, v = _expand_kv(params, cfg, c_kv, k_rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = constrain(q, None, None, "model")
    out = sdpa(q, k, v, positions, positions, impl=impl)
    B, S = x.shape[:2]
    return qmatmul(out.reshape(B, S, -1), params["wo"])


def _latent_entries(cache_layer, c_kv, k_rope):
    """Leaf updates for a latent write: int8 caches quantize per ROW (the
    latent has no head axis) and carry ``ckv_scale`` / ``krope_scale``."""
    if kv_is_quantized(cache_layer, "ckv"):
        cq, cs = quantize_rows(c_kv)
        rq, rs = quantize_rows(k_rope)
        return {"ckv": cq, "krope": rq, "ckv_scale": cs, "krope_scale": rs}
    return {"ckv": c_kv, "krope": k_rope}


def cache_latents(cache_layer, dtype):
    """Read a dense MLA cache layer's (ckv, krope) as ``dtype``."""
    if kv_is_quantized(cache_layer, "ckv"):
        return (dequantize_rows(cache_layer["ckv"], cache_layer["ckv_scale"],
                                dtype),
                dequantize_rows(cache_layer["krope"],
                                cache_layer["krope_scale"], dtype))
    return cache_layer["ckv"].astype(dtype), cache_layer["krope"].astype(dtype)


def write_mla_cache(cache_layer, c_kv, k_rope, pos0, ring: bool):
    L = cache_layer["ckv"].shape[1]
    S = c_kv.shape[1]
    newpos = pos0 + jnp.arange(S, dtype=jnp.int32)
    entries = _latent_entries(cache_layer, c_kv, k_rope)
    if not ring:
        out = {key: jax.lax.dynamic_update_slice_in_dim(
                   cache_layer[key], val.astype(cache_layer[key].dtype),
                   pos0, 1)
               for key, val in entries.items()}
        out["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["pos"], newpos, pos0, 0)
        return out
    if S >= L:
        entries = {key: val[:, -L:] for key, val in entries.items()}
        newpos = newpos[-L:]
    slots = (newpos % L).astype(jnp.int32)
    out = {key: cache_layer[key].at[:, slots].set(
               val.astype(cache_layer[key].dtype))
           for key, val in entries.items()}
    out["pos"] = cache_layer["pos"].at[slots].set(newpos)
    return out


def _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope, mask):
    """Absorbed-formulation attention against latent K: queries folded
    through W_uk run directly on (ckv, krope) under an explicit visibility
    ``mask`` ((S, L) shared or (B, S, L) per-lane).  Shared by the dense,
    paged and tree cached paths.  Returns (B, S, d_model)."""
    m = cfg.mla
    H = cfg.num_heads
    B, S = q_nope.shape[:2]
    # the absorbed path folds W_uk/W_uv INTO einsums over reshaped views, so
    # quantized variants are materialized here (per-channel dequant) instead
    # of riding a matmul epilogue
    w_uk = resolve_weight(params["w_uk"], q_nope.dtype).reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_c = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    scores = (jnp.einsum("bshr,blr->bhsl", q_c, ckv) +
              jnp.einsum("bshr,blr->bhsl", q_rope, krope)).astype(jnp.float32)
    scores = scores / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None], scores, NEG_INF)           # (B,H,S,L)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, :, None], p, 0.0)
    o_c = jnp.einsum("bhsl,blr->bshr", p.astype(ckv.dtype), ckv)
    w_uv = resolve_weight(params["w_uv"], o_c.dtype).reshape(
        m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", o_c, w_uv)
    return qmatmul(out.reshape(B, S, -1), params["wo"])


def _gather_latent_pages(layer_cache, tables, dtype):
    """Per-stream logical (ckv, krope) views of the latent pools as
    ``dtype``, dequantizing int8 pools against their scale pools."""
    from .attention import gather_pages
    cg = gather_pages(layer_cache["ckv"], tables)
    rg = gather_pages(layer_cache["krope"], tables)
    if kv_is_quantized(layer_cache, "ckv"):
        return (dequantize_rows(cg, gather_pages(layer_cache["ckv_scale"],
                                                 tables), dtype),
                dequantize_rows(rg, gather_pages(layer_cache["krope_scale"],
                                                 tables), dtype))
    return cg.astype(dtype), rg.astype(dtype)


def mla_paged(params, cfg, x, cache_layer, tables, lengths, *,
              impl: str = "auto"):
    """Paged cached step (absorbed formulation) against latent block pools.

    cache_layer: {"ckv": (N, bs, R), "krope": (N, bs, Dr)} global pools;
    tables (B, MB); lengths (B,).  Per-stream positions are contiguous, so
    the mask is simply ``row < lengths[b] + S`` and causal vs. the query.
    """
    from .attention import paged_kpos, paged_write
    B, S, _ = x.shape
    positions = lengths[:, None].astype(jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    cache_layer = {key: paged_write(cache_layer[key], val, tables, lengths)
                   for key, val in _latent_entries(cache_layer, c_kv,
                                                   k_rope).items()}
    ckv, krope = _gather_latent_pages(cache_layer, tables, x.dtype)
    kpos = paged_kpos(lengths + S, ckv.shape[1])                      # (B, L)
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= positions[:, :, None])
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), cache_layer


def mla_cached(params, cfg, x, pos0, cache_layer, *, ring: bool = False,
               impl: str = "auto"):
    """Cached step via the absorbed formulation (S is small: 1..gamma)."""
    B, S, _ = x.shape
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    cache_layer = write_mla_cache(cache_layer, c_kv, k_rope, pos0, ring)
    ckv, krope = cache_latents(cache_layer, x.dtype)     # (B,L,R), (B,L,Dr)
    kpos = cache_layer["pos"]
    mask = (kpos[None, :] >= 0) & (kpos[None, :] <= positions[:, None])
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), cache_layer


# ------------------------------------------------------------ tree path

def init_tree_nodes_mla(cfg, batch: int, dtype):
    """Empty latent node carry for one MLA layer (0 rows; levels append)."""
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, 0, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, 0, m.qk_rope_head_dim), dtype)}


def mla_tree(params, cfg, x, positions, cache_layer, prev_nodes, node_mask,
             base, *, impl: str = "auto"):
    """Tree-node MLA over ``cache latents + node latents`` without cache
    writes; cache rows visible iff stored position < ``base`` (the pointer —
    see ``attention.attn_tree`` for why the rule is strict), node rows
    visible per the ancestor ``node_mask``.  Returns (out, nodes)."""
    B, S, _ = x.shape
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    nodes = {"ckv": jnp.concatenate([prev_nodes["ckv"].astype(c_kv.dtype),
                                     c_kv], axis=1),
             "krope": jnp.concatenate([prev_nodes["krope"].astype(k_rope.dtype),
                                       k_rope], axis=1)}
    kpos = cache_layer["pos"]
    cmask = (kpos[None, :] >= 0) & (kpos[None, :] < base)        # (1, L)
    cmask = jnp.broadcast_to(cmask, (S, kpos.shape[0]))          # (Tc, L)
    mask = jnp.concatenate([cmask, node_mask], axis=1)
    ckv_c, krope_c = cache_latents(cache_layer, x.dtype)
    # pin [cache latents | node latents] replicated (see attn_tree: SPMD
    # concat-on-sharded-dim miscompile)
    ckv = constrain(jnp.concatenate([ckv_c, nodes["ckv"].astype(x.dtype)],
                                    axis=1))
    krope = constrain(jnp.concatenate(
        [krope_c, nodes["krope"].astype(x.dtype)], axis=1))
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), nodes


def mla_tree_paged(params, cfg, x, layer_cache, tables, lengths, depths,
                   prev_nodes, node_mask, *, impl: str = "auto"):
    """Paged tree-node MLA: committed-row validity is ``p < lengths``; the
    latent pool is not written.  Returns (out, nodes)."""
    from .attention import paged_kpos
    B, S, _ = x.shape
    positions = lengths[:, None].astype(jnp.int32) + depths[None, :]
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    nodes = {"ckv": jnp.concatenate([prev_nodes["ckv"].astype(c_kv.dtype),
                                     c_kv], axis=1),
             "krope": jnp.concatenate([prev_nodes["krope"].astype(k_rope.dtype),
                                       k_rope], axis=1)}
    ckv_c, krope_c = _gather_latent_pages(layer_cache, tables, x.dtype)
    kpos = paged_kpos(lengths, ckv_c.shape[1])
    cmask = jnp.broadcast_to(kpos[:, None, :] >= 0,              # (B, Tc, L)
                             (B, S, ckv_c.shape[1]))
    nmask = jnp.broadcast_to(node_mask[None], (B,) + node_mask.shape)
    mask = jnp.concatenate([cmask, nmask], axis=2)
    # pin [gathered latents | node latents] replicated (see attn_tree)
    ckv = constrain(jnp.concatenate([ckv_c, nodes["ckv"].astype(x.dtype)],
                                    axis=1))
    krope = constrain(jnp.concatenate(
        [krope_c, nodes["krope"].astype(x.dtype)], axis=1))
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), nodes


def commit_tree_rows_mla(cache_layer, nodes, path, n_commit, base):
    """Scatter accepted-path node latents into a DENSE MLA cache (fixed-P
    write, padding rows stored at position -1 — see attention twin)."""
    P = path.shape[0]
    rows_c = jnp.take(nodes["ckv"], path, axis=1)
    rows_r = jnp.take(nodes["krope"], path, axis=1)
    entries = _latent_entries(cache_layer, rows_c, rows_r)
    out = {key: jax.lax.dynamic_update_slice_in_dim(
               cache_layer[key], val.astype(cache_layer[key].dtype), base, 1)
           for key, val in entries.items()}
    stored = jnp.where(jnp.arange(P) < n_commit,
                       base + jnp.arange(P, dtype=jnp.int32), -1)
    out["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["pos"], stored.astype(jnp.int32), base, 0)
    return out


def commit_tree_rows_paged_mla(layer_cache, nodes, path, tables, lengths):
    """Scatter accepted-path node latents into the PAGED latent pools.
    Writes land at positions >= lengths[b] only, so under prefix sharing
    (docs/prefix_sharing.md) the admission-time COW invariant guarantees
    the touched blocks are sole-owner — no clone here."""
    from .attention import paged_write
    rows_c = jnp.take(nodes["ckv"], path, axis=1)
    rows_r = jnp.take(nodes["krope"], path, axis=1)
    return {key: paged_write(layer_cache[key], val, tables, lengths)
            for key, val in _latent_entries(layer_cache, rows_c,
                                            rows_r).items()}
