"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed into a per-token latent ``c_kv`` of rank
``kv_lora_rank`` plus a single shared RoPE key of dim ``qk_rope_head_dim``;
the cache stores only these (the technique's memory win).

Two execution paths:
  * prefill/train: decompress K/V per head and reuse the flash ``sdpa``
    (chunked, long-sequence safe).
  * cached decode (short S): the "absorbed" formulation — queries are folded
    through W_uk so attention runs directly against the latent cache, never
    materializing per-head K/V for the full context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import NEG_INF, sdpa
from .common import apply_rope, dense_init, rms_norm
from .sharding import constrain


def init_mla(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], d, m.q_lora_rank, dtype)
        p["q_norm"] = jnp.zeros((m.q_lora_rank,), dtype)
        p["w_uq"] = dense_init(ks[1], m.q_lora_rank, H * qk_dim, dtype)
    else:
        p["w_q"] = dense_init(ks[0], d, H * qk_dim, dtype)
    p["w_dkv"] = dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype)
    p["kv_norm"] = jnp.zeros((m.kv_lora_rank,), dtype)
    p["w_uk"] = dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype)
    p["w_uv"] = dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dtype)
    p["wo"] = dense_init(ks[5], H * m.v_head_dim, d, dtype)
    return p


def _queries(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        q = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.rms_eps) @ params["w_uq"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(params, cfg, x, positions):
    m = cfg.mla
    ckv_rope = x @ params["w_dkv"]
    c_kv, k_rope = jnp.split(ckv_rope, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.rms_eps)
    # shared (single-"head") rope key, stored post-rotation
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _expand_kv(params, cfg, c_kv, k_rope):
    """Decompress latents to per-head K/V (prefill path)."""
    m = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.num_heads
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))], axis=-1)
    return k, v


def mla_train(params, cfg, x, positions, impl: str = "auto"):
    m = cfg.mla
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    k, v = _expand_kv(params, cfg, c_kv, k_rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = constrain(q, None, None, "model")
    out = sdpa(q, k, v, positions, positions, impl=impl)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ params["wo"]


def write_mla_cache(cache_layer, c_kv, k_rope, pos0, ring: bool):
    L = cache_layer["ckv"].shape[1]
    S = c_kv.shape[1]
    newpos = pos0 + jnp.arange(S, dtype=jnp.int32)
    if not ring:
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["ckv"], c_kv.astype(cache_layer["ckv"].dtype), pos0, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["krope"], k_rope.astype(cache_layer["krope"].dtype), pos0, 1)
        sp = jax.lax.dynamic_update_slice_in_dim(cache_layer["pos"], newpos, pos0, 0)
        return {"ckv": cc, "krope": cr, "pos": sp}
    if S >= L:
        c_kv, k_rope, newpos = c_kv[:, -L:], k_rope[:, -L:], newpos[-L:]
    slots = (newpos % L).astype(jnp.int32)
    cc = cache_layer["ckv"].at[:, slots].set(c_kv.astype(cache_layer["ckv"].dtype))
    cr = cache_layer["krope"].at[:, slots].set(k_rope.astype(cache_layer["krope"].dtype))
    sp = cache_layer["pos"].at[slots].set(newpos)
    return {"ckv": cc, "krope": cr, "pos": sp}


def _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope, mask):
    """Absorbed-formulation attention against latent K: queries folded
    through W_uk run directly on (ckv, krope) under an explicit visibility
    ``mask`` ((S, L) shared or (B, S, L) per-lane).  Shared by the dense,
    paged and tree cached paths.  Returns (B, S, d_model)."""
    m = cfg.mla
    H = cfg.num_heads
    B, S = q_nope.shape[:2]
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_c = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    scores = (jnp.einsum("bshr,blr->bhsl", q_c, ckv) +
              jnp.einsum("bshr,blr->bhsl", q_rope, krope)).astype(jnp.float32)
    scores = scores / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None], scores, NEG_INF)           # (B,H,S,L)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, :, None], p, 0.0)
    o_c = jnp.einsum("bhsl,blr->bshr", p.astype(ckv.dtype), ckv)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", o_c, w_uv)
    return out.reshape(B, S, -1) @ params["wo"]


def mla_paged(params, cfg, x, cache_layer, tables, lengths, *,
              impl: str = "auto"):
    """Paged cached step (absorbed formulation) against latent block pools.

    cache_layer: {"ckv": (N, bs, R), "krope": (N, bs, Dr)} global pools;
    tables (B, MB); lengths (B,).  Per-stream positions are contiguous, so
    the mask is simply ``row < lengths[b] + S`` and causal vs. the query.
    """
    from .attention import gather_pages, paged_kpos, paged_write
    B, S, _ = x.shape
    positions = lengths[:, None].astype(jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    cache_layer = {
        "ckv": paged_write(cache_layer["ckv"], c_kv, tables, lengths),
        "krope": paged_write(cache_layer["krope"], k_rope, tables, lengths)}
    ckv = gather_pages(cache_layer["ckv"], tables).astype(x.dtype)    # (B, L, R)
    krope = gather_pages(cache_layer["krope"], tables).astype(x.dtype)
    kpos = paged_kpos(lengths + S, ckv.shape[1])                      # (B, L)
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= positions[:, :, None])
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), cache_layer


def mla_cached(params, cfg, x, pos0, cache_layer, *, ring: bool = False,
               impl: str = "auto"):
    """Cached step via the absorbed formulation (S is small: 1..gamma)."""
    B, S, _ = x.shape
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    cache_layer = write_mla_cache(cache_layer, c_kv, k_rope, pos0, ring)
    ckv = cache_layer["ckv"].astype(x.dtype)             # (B, L, R)
    krope = cache_layer["krope"].astype(x.dtype)         # (B, L, Dr)
    kpos = cache_layer["pos"]
    mask = (kpos[None, :] >= 0) & (kpos[None, :] <= positions[:, None])
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), cache_layer


# ------------------------------------------------------------ tree path

def init_tree_nodes_mla(cfg, batch: int, dtype):
    """Empty latent node carry for one MLA layer (0 rows; levels append)."""
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, 0, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, 0, m.qk_rope_head_dim), dtype)}


def mla_tree(params, cfg, x, positions, cache_layer, prev_nodes, node_mask,
             base, *, impl: str = "auto"):
    """Tree-node MLA over ``cache latents + node latents`` without cache
    writes; cache rows visible iff stored position < ``base`` (the pointer —
    see ``attention.attn_tree`` for why the rule is strict), node rows
    visible per the ancestor ``node_mask``.  Returns (out, nodes)."""
    B, S, _ = x.shape
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    nodes = {"ckv": jnp.concatenate([prev_nodes["ckv"].astype(c_kv.dtype),
                                     c_kv], axis=1),
             "krope": jnp.concatenate([prev_nodes["krope"].astype(k_rope.dtype),
                                       k_rope], axis=1)}
    kpos = cache_layer["pos"]
    cmask = (kpos[None, :] >= 0) & (kpos[None, :] < base)        # (1, L)
    cmask = jnp.broadcast_to(cmask, (S, kpos.shape[0]))          # (Tc, L)
    mask = jnp.concatenate([cmask, node_mask], axis=1)
    ckv = jnp.concatenate([cache_layer["ckv"].astype(x.dtype),
                           nodes["ckv"].astype(x.dtype)], axis=1)
    krope = jnp.concatenate([cache_layer["krope"].astype(x.dtype),
                             nodes["krope"].astype(x.dtype)], axis=1)
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), nodes


def mla_tree_paged(params, cfg, x, layer_cache, tables, lengths, depths,
                   prev_nodes, node_mask, *, impl: str = "auto"):
    """Paged tree-node MLA: committed-row validity is ``p < lengths``; the
    latent pool is not written.  Returns (out, nodes)."""
    from .attention import gather_pages, paged_kpos
    B, S, _ = x.shape
    positions = lengths[:, None].astype(jnp.int32) + depths[None, :]
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    nodes = {"ckv": jnp.concatenate([prev_nodes["ckv"].astype(c_kv.dtype),
                                     c_kv], axis=1),
             "krope": jnp.concatenate([prev_nodes["krope"].astype(k_rope.dtype),
                                       k_rope], axis=1)}
    ckv_c = gather_pages(layer_cache["ckv"], tables).astype(x.dtype)
    krope_c = gather_pages(layer_cache["krope"], tables).astype(x.dtype)
    kpos = paged_kpos(lengths, ckv_c.shape[1])
    cmask = jnp.broadcast_to(kpos[:, None, :] >= 0,              # (B, Tc, L)
                             (B, S, ckv_c.shape[1]))
    nmask = jnp.broadcast_to(node_mask[None], (B,) + node_mask.shape)
    mask = jnp.concatenate([cmask, nmask], axis=2)
    ckv = jnp.concatenate([ckv_c, nodes["ckv"].astype(x.dtype)], axis=1)
    krope = jnp.concatenate([krope_c, nodes["krope"].astype(x.dtype)], axis=1)
    return _absorbed_attend(params, cfg, q_nope, q_rope, ckv, krope,
                            mask), nodes


def commit_tree_rows_mla(cache_layer, nodes, path, n_commit, base):
    """Scatter accepted-path node latents into a DENSE MLA cache (fixed-P
    write, padding rows stored at position -1 — see attention twin)."""
    P = path.shape[0]
    rows_c = jnp.take(nodes["ckv"], path, axis=1).astype(cache_layer["ckv"].dtype)
    rows_r = jnp.take(nodes["krope"], path, axis=1).astype(cache_layer["krope"].dtype)
    cc = jax.lax.dynamic_update_slice_in_dim(cache_layer["ckv"], rows_c, base, 1)
    cr = jax.lax.dynamic_update_slice_in_dim(cache_layer["krope"], rows_r, base, 1)
    stored = jnp.where(jnp.arange(P) < n_commit,
                       base + jnp.arange(P, dtype=jnp.int32), -1)
    sp = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["pos"], stored.astype(jnp.int32), base, 0)
    return {"ckv": cc, "krope": cr, "pos": sp}


def commit_tree_rows_paged_mla(layer_cache, nodes, path, tables, lengths):
    """Scatter accepted-path node latents into the PAGED latent pools."""
    from .attention import paged_write
    rows_c = jnp.take(nodes["ckv"], path, axis=1)
    rows_r = jnp.take(nodes["krope"], path, axis=1)
    return {"ckv": paged_write(layer_cache["ckv"], rows_c, tables, lengths),
            "krope": paged_write(layer_cache["krope"], rows_r, tables, lengths)}
