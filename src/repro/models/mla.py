"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed into a per-token latent ``c_kv`` of rank
``kv_lora_rank`` plus a single shared RoPE key of dim ``qk_rope_head_dim``;
the cache stores only these (the technique's memory win).

Two execution paths:
  * prefill/train: decompress K/V per head and reuse the flash ``sdpa``
    (chunked, long-sequence safe).
  * cached decode (short S): the "absorbed" formulation — queries are folded
    through W_uk so attention runs directly against the latent cache, never
    materializing per-head K/V for the full context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import NEG_INF, sdpa
from .common import apply_rope, dense_init, rms_norm
from .sharding import constrain


def init_mla(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[0], d, m.q_lora_rank, dtype)
        p["q_norm"] = jnp.zeros((m.q_lora_rank,), dtype)
        p["w_uq"] = dense_init(ks[1], m.q_lora_rank, H * qk_dim, dtype)
    else:
        p["w_q"] = dense_init(ks[0], d, H * qk_dim, dtype)
    p["w_dkv"] = dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype)
    p["kv_norm"] = jnp.zeros((m.kv_lora_rank,), dtype)
    p["w_uk"] = dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype)
    p["w_uv"] = dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dtype)
    p["wo"] = dense_init(ks[5], H * m.v_head_dim, d, dtype)
    return p


def _queries(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        q = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.rms_eps) @ params["w_uq"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(params, cfg, x, positions):
    m = cfg.mla
    ckv_rope = x @ params["w_dkv"]
    c_kv, k_rope = jnp.split(ckv_rope, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.rms_eps)
    # shared (single-"head") rope key, stored post-rotation
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _expand_kv(params, cfg, c_kv, k_rope):
    """Decompress latents to per-head K/V (prefill path)."""
    m = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.num_heads
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))], axis=-1)
    return k, v


def mla_train(params, cfg, x, positions, impl: str = "auto"):
    m = cfg.mla
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    k, v = _expand_kv(params, cfg, c_kv, k_rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = constrain(q, None, None, "model")
    out = sdpa(q, k, v, positions, positions, impl=impl)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ params["wo"]


def write_mla_cache(cache_layer, c_kv, k_rope, pos0, ring: bool):
    L = cache_layer["ckv"].shape[1]
    S = c_kv.shape[1]
    newpos = pos0 + jnp.arange(S, dtype=jnp.int32)
    if not ring:
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["ckv"], c_kv.astype(cache_layer["ckv"].dtype), pos0, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["krope"], k_rope.astype(cache_layer["krope"].dtype), pos0, 1)
        sp = jax.lax.dynamic_update_slice_in_dim(cache_layer["pos"], newpos, pos0, 0)
        return {"ckv": cc, "krope": cr, "pos": sp}
    if S >= L:
        c_kv, k_rope, newpos = c_kv[:, -L:], k_rope[:, -L:], newpos[-L:]
    slots = (newpos % L).astype(jnp.int32)
    cc = cache_layer["ckv"].at[:, slots].set(c_kv.astype(cache_layer["ckv"].dtype))
    cr = cache_layer["krope"].at[:, slots].set(k_rope.astype(cache_layer["krope"].dtype))
    sp = cache_layer["pos"].at[slots].set(newpos)
    return {"ckv": cc, "krope": cr, "pos": sp}


def mla_paged(params, cfg, x, cache_layer, tables, lengths, *,
              impl: str = "auto"):
    """Paged cached step (absorbed formulation) against latent block pools.

    cache_layer: {"ckv": (N, bs, R), "krope": (N, bs, Dr)} global pools;
    tables (B, MB); lengths (B,).  Per-stream positions are contiguous, so
    the mask is simply ``row < lengths[b] + S`` and causal vs. the query.
    """
    from .attention import gather_pages, paged_kpos, paged_write
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    positions = lengths[:, None].astype(jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    cache_layer = {
        "ckv": paged_write(cache_layer["ckv"], c_kv, tables, lengths),
        "krope": paged_write(cache_layer["krope"], k_rope, tables, lengths)}
    ckv = gather_pages(cache_layer["ckv"], tables).astype(x.dtype)    # (B, L, R)
    krope = gather_pages(cache_layer["krope"], tables).astype(x.dtype)
    kpos = paged_kpos(lengths + S, ckv.shape[1])                      # (B, L)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_c = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    scores = (jnp.einsum("bshr,blr->bhsl", q_c, ckv) +
              jnp.einsum("bshr,blr->bhsl", q_rope, krope)).astype(jnp.float32)
    scores = scores / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= positions[:, :, None])
    scores = jnp.where(mask[:, None], scores, NEG_INF)                # (B,H,S,L)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, :, None], p, 0.0)
    o_c = jnp.einsum("bhsl,blr->bshr", p.astype(ckv.dtype), ckv)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", o_c, w_uv)
    return out.reshape(B, S, -1) @ params["wo"], cache_layer


def mla_cached(params, cfg, x, pos0, cache_layer, *, ring: bool = False,
               impl: str = "auto"):
    """Cached step via the absorbed formulation (S is small: 1..gamma)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    cache_layer = write_mla_cache(cache_layer, c_kv, k_rope, pos0, ring)
    ckv = cache_layer["ckv"].astype(x.dtype)             # (B, L, R)
    krope = cache_layer["krope"].astype(x.dtype)         # (B, L, Dr)
    kpos = cache_layer["pos"]
    # absorb W_uk into the queries: q_c (B,S,H,R)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_c = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    scores = (jnp.einsum("bshr,blr->bhsl", q_c, ckv) +
              jnp.einsum("bshr,blr->bhsl", q_rope, krope)).astype(jnp.float32)
    scores = scores / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    mask = (kpos[None, :] >= 0) & (kpos[None, :] <= positions[:, None])
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask.any(-1)[None, None, :, None], p, 0.0)
    o_c = jnp.einsum("bhsl,blr->bshr", p.astype(ckv.dtype), ckv)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", o_c, w_uv)
    return out.reshape(B, S, -1) @ params["wo"], cache_layer
