"""Unified model configuration for every supported architecture family.

One ``ModelConfig`` drives layer construction for dense / MoE / MLA / SSM /
hybrid / enc-dec / VLM models.  Per-layer behaviour is selected by
``block_pattern`` which is cycled over the layer stack:

  "attn"    full causal self-attention (GQA / MQA / MHA)
  "local"   sliding-window causal self-attention (``window`` tokens)
  "mla"     DeepSeek multi-head latent attention (compressed KV cache)
  "mamba2"  Mamba-2 SSD state-space mixer (attention-free)
  "rglru"   RecurrentGemma RG-LRU gated linear recurrence (attention-free)

The FFN of each block is dense unless ``moe`` is set, in which case layers
listed in ``moe.dense_layers`` stay dense and the rest use the routed MoE.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden size
    num_shared_experts: int = 0
    d_shared: int = 0                  # hidden size of the shared expert(s)
    capacity_factor: float = 1.25      # train-time token capacity per expert
    router_aux_weight: float = 0.01    # load-balance aux loss weight
    dense_layers: Tuple[int, ...] = () # layer indices that keep a dense FFN
    routed_scale: float = 1.0          # scaling on routed expert output
    # tiny-batch decode via active-expert weight GATHER instead of the full
    # dispatch einsum. Off by default: on a model-sharded expert bank the
    # gather's collectives cost ~17x what it saves in HBM (§Perf, refuted
    # hypothesis — kept for single-host serving where it does win).
    decode_gather: bool = False


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0               # 0 = full-rank queries (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                 # SSD head dim (nheads = d_inner // head_dim)
    ngroups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0                 # 0 = d_model
    d_conv: int = 4
    block_width_mult: int = 1


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (audio / seq2seq) configuration."""
    num_encoder_layers: int = 0
    encoder_is_causal: bool = False
    # The modality frontend (mel-spectrogram + conv feature extractor) is a
    # STUB: input_specs() provides precomputed frame embeddings of this shape.
    frontend_dim: int = 0              # embedding dim produced by the stub
    frontend_len: int = 1024           # number of frames the stub emits


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM vision frontend STUB: precomputed patch embeddings + projector."""
    vit_dim: int = 1024
    num_patches: int = 256
    projector_hidden: int = 0          # 0 = vit_dim*4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 = d_model // num_heads
    activation: str = "swiglu"         # swiglu|geglu|gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = True
    logits_softcap: float = 0.0
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                    # sliding window for "local" blocks
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionStubConfig] = None
    # long-context decode: ring-buffer window applied to "attn" blocks when a
    # sequence exceeds max_full_cache_len (beyond-paper variant, DESIGN §4.2).
    long_context_window: int = 8192
    max_full_cache_len: int = 65536
    # scan-over-layers (small HLO / fast compile). The dry-run roofline pass
    # unrolls instead: XLA cost_analysis counts a scan body once, which would
    # undercount FLOPs/bytes/collectives by the trip count.
    scan_layers: bool = True
    # Megatron-SP-style sequence sharding of the inter-block residual stream
    # (training path): cuts remat-saved activations by the model-axis size at
    # the cost of one gather per block. (§Perf iteration 1.)
    seq_shard_activations: bool = True
    source: str = ""                   # citation for the config

    # ---- derived ------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.num_layers))

    def is_moe_layer(self, layer: int) -> bool:
        if self.moe is None:
            return False
        return layer not in self.moe.dense_layers

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None and self.encdec.num_encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        kinds = set(self.layer_kinds())
        return kinds <= {"mamba2", "rglru"}

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory is bounded (sub-quadratic cache)."""
        return True  # every arch: native state (ssm/rglru) or ring-buffer window

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS) ----------------
    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        return _count_params(self, active_only=True)

    def reduced(self, *, layers: int = 2, d_model: int = 256, n_experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        d_model = min(d_model, self.d_model)
        heads = max(1, min(self.num_heads, d_model // 64))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        hd = min(self.resolved_head_dim, 64)
        kw = dict(
            num_layers=layers, d_model=d_model, num_heads=heads,
            num_kv_heads=kv, head_dim=hd,
            d_ff=max(64, min(self.d_ff, d_model * 4)),
            vocab_size=min(vocab, self.vocab_size),
            window=min(self.window, 64) if self.window else 0,
            long_context_window=256, max_full_cache_len=4096,
            name=self.name + "-smoke",
        )
        if self.moe is not None:
            ne = min(n_experts, self.moe.num_experts)
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=ne, top_k=min(self.moe.top_k, 2),
                d_expert=max(32, d_model // 2),
                d_shared=max(32, d_model // 2) if self.moe.num_shared_experts else 0,
                dense_layers=tuple(i for i in self.moe.dense_layers if i < layers))
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, q_lora_rank=0,
                qk_nope_head_dim=hd, qk_rope_head_dim=32, v_head_dim=hd)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=32, head_dim=32, chunk_size=32)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=d_model)
        if self.encdec is not None:
            kw["encdec"] = dataclasses.replace(
                self.encdec, num_encoder_layers=min(2, self.encdec.num_encoder_layers),
                frontend_dim=min(self.encdec.frontend_dim, 128), frontend_len=16)
        if self.vision is not None:
            kw["vision"] = dataclasses.replace(
                self.vision, vit_dim=64, num_patches=8, projector_hidden=128)
        # keep the pattern but make sure at least one full cycle fits
        pat = self.block_pattern
        if layers < len(pat):
            pat = pat[:layers]
        kw["block_pattern"] = pat
        return self.replace(**kw)


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        qdim = cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        n = d * qdim if not m.q_lora_rank else d * m.q_lora_rank + m.q_lora_rank * qdim
        n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        n += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
        n += cfg.num_heads * m.v_head_dim * d
        return n
    n = d * cfg.num_heads * hd            # Q
    n += 2 * d * cfg.num_kv_heads * hd    # K, V
    n += cfg.num_heads * hd * d           # O
    if cfg.qkv_bias:
        n += (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
    return n


def _ffn_params(cfg: ModelConfig, layer: int, active_only: bool) -> int:
    d = cfg.d_model
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    if cfg.is_moe_layer(layer):
        m = cfg.moe
        per = mult * d * m.d_expert
        n_routed = m.top_k if active_only else m.num_experts
        n = per * n_routed + d * m.num_experts  # + router
        if m.num_shared_experts:
            n += m.num_shared_experts * mult * d * m.d_shared
        return n
    return mult * d * cfg.d_ff


def _mixer_params(cfg: ModelConfig, kind: str) -> int:
    d = cfg.d_model
    if kind in ("attn", "local"):
        return _attn_params(cfg)
    if kind == "mla":
        return _attn_params(cfg)
    if kind == "mamba2":
        s = cfg.ssm
        d_in = s.expand * d
        nheads = d_in // s.head_dim
        zxbcdt = d * (2 * d_in + 2 * s.ngroups * s.d_state + nheads)
        conv = s.d_conv * (d_in + 2 * s.ngroups * s.d_state)
        out = d_in * d
        return zxbcdt + conv + out + 2 * nheads + d_in  # A,D,dt_bias(normish)
    if kind == "rglru":
        w = cfg.rglru.lru_width or d
        return 2 * d * w + cfg.rglru.d_conv * w + 3 * w + w * d
    raise ValueError(kind)


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        n += _mixer_params(cfg, kind)
        if kind in ("attn", "local", "mla"):   # mixer blocks carry the FFN
            n += _ffn_params(cfg, i, active_only)
        elif kind in ("mamba2",):
            pass                               # mamba2 block has no separate FFN
        elif kind == "rglru":
            n += _ffn_params(cfg, i, active_only)
        n += 2 * cfg.d_model                   # norms
    if cfg.is_encdec:
        e = cfg.encdec
        for _ in range(e.num_encoder_layers):
            n += _attn_params(cfg) + _ffn_params(cfg, -1, active_only) + 2 * cfg.d_model
        # cross attention per decoder layer
        n += cfg.num_layers * (_attn_params(cfg) + cfg.d_model)
        n += e.frontend_dim * cfg.d_model      # frontend projector
    if cfg.vision is not None:
        v = cfg.vision
        h = v.projector_hidden or v.vit_dim * 4
        n += v.vit_dim * h + h * cfg.d_model
    return n
