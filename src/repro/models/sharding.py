"""Mesh context + activation sharding constraints.

Models are written mesh-agnostically: they call ``constrain(x, *axes)`` with
*logical* axis names; if no mesh is active (CPU unit tests) this is a no-op.
Axis names that are missing from the active mesh, or that do not divide the
corresponding dimension, are dropped — so the same model code lowers on a
1-device CPU, a 16x16 pod and a 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None

# logical -> mesh axes. "batch" expands to every data-parallel mesh axis.
BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"
EXPERT_AXIS = "model"


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _ACTIVE_MESH
    prev, _ACTIVE_MESH = _ACTIVE_MESH, mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _ACTIVE_MESH = prev


AxisLike = Union[None, str, Tuple[str, ...]]


def _resolve_axis(mesh: Mesh, axis: AxisLike, dim: int,
                  used: Optional[set] = None) -> AxisLike:
    """Drop mesh axes that are absent, do not divide ``dim``, or were
    already assigned to an earlier dimension of the same spec (a mesh axis
    may appear at most once per PartitionSpec — size-1 axes would
    otherwise 'divide' every dim and duplicate)."""
    if axis is None:
        return None
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    kept = []
    size = 1
    for n in names:
        if n not in mesh.axis_names or (used is not None and n in used):
            continue
        nsz = mesh.shape[n]
        if dim % (size * nsz) != 0:
            continue
        kept.append(n)
        size *= nsz
    if not kept:
        return None
    if used is not None:
        used.update(kept)
    return kept[0] if len(kept) == 1 else tuple(kept)


def resolve_spec(mesh: Mesh, spec: Sequence[AxisLike], shape: Sequence[int]) -> P:
    axes = list(spec) + [None] * (len(shape) - len(spec))
    used: set = set()
    return P(*[_resolve_axis(mesh, a, d, used) for a, d in zip(axes, shape)])


def constrain(x, *spec: AxisLike):
    """with_sharding_constraint with logical axes; no-op without a mesh."""
    mesh = _ACTIVE_MESH
    if mesh is None or len(mesh.devices.ravel()) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_spec(mesh, spec, x.shape)))


def batch_spec() -> Tuple[str, ...]:
    return BATCH_AXES


def named(spec: Sequence[AxisLike], shape: Sequence[int]) -> Optional[NamedSharding]:
    mesh = _ACTIVE_MESH
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(mesh, spec, shape))
