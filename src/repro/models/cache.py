"""Decode-state (KV / recurrent) cache.

A cache is a pytree:
    {"pos": int32 scalar (tokens consumed so far),
     "layers": {"prefix": [...], "stack": stacked-or-None, "tail": [...]},
     "cross": optional per-decoder-layer encoder KV (enc-dec only)}

Per-layer entries by block kind:
    attn/local : {"k","v": (B, L, G, D), "pos": (L,) int32}   (+ ring flag in spec)
    mla        : {"ckv": (B, L, R), "krope": (B, L, Dr), "pos": (L,)}
    mamba2     : {"conv": (B, K-1, Cd), "ssm": (B, H, P, N)}
    rglru      : {"conv": (B, K-1, W), "rec": (B, W)}

``CacheSpec`` carries the STATIC layout decisions (ring?, buffer length) so
jitted code can branch on them at trace time.  Rollback for attention-style
caches is O(1) (reset "pos"; stale slots carry future positions and are
masked out).  Recurrent layers need recompute-from-snapshot — the engine
keeps the pre-draft cache value (free in functional JAX) instead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

from .config import ModelConfig

RING_SLACK = 256  # extra slots so multi-token (verify) steps never clobber
                  # keys still inside another in-flight query's window


@dataclass(frozen=True)
class LayerCacheSpec:
    kind: str          # attn|mla|mamba2|rglru
    length: int = 0    # KV buffer length (attn/mla)
    ring: bool = False
    window: int = 0    # attention window (0 = full)


@dataclass(frozen=True)
class CacheSpec:
    layers: Tuple[LayerCacheSpec, ...]
    max_len: int

    @property
    def cheap_rollback(self) -> bool:
        return all(l.kind in ("attn", "mla") for l in self.layers)


def build_cache_spec(cfg: ModelConfig, max_len: int) -> CacheSpec:
    specs = []
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind in ("attn", "mla"):
            if max_len > cfg.max_full_cache_len:
                w = cfg.long_context_window
                specs.append(LayerCacheSpec(kind, w + RING_SLACK, True, w))
            else:
                specs.append(LayerCacheSpec(kind, max_len, False, 0))
        elif kind == "local":
            w = cfg.window or 4096
            L = min(max_len, w + RING_SLACK)
            specs.append(LayerCacheSpec("attn", L, L < max_len, w))
        elif kind in ("mamba2", "rglru"):
            specs.append(LayerCacheSpec(kind))
        else:
            raise ValueError(kind)
    return CacheSpec(tuple(specs), max_len)


def init_layer_cache(cfg: ModelConfig, spec: LayerCacheSpec, batch: int,
                     dtype=jnp.bfloat16):
    if spec.kind == "attn":
        hd = cfg.resolved_head_dim
        return {"k": jnp.zeros((batch, spec.length, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, spec.length, cfg.num_kv_heads, hd), dtype),
                "pos": jnp.full((spec.length,), -1, jnp.int32)}
    if spec.kind == "mla":
        m = cfg.mla
        return {"ckv": jnp.zeros((batch, spec.length, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, spec.length, m.qk_rope_head_dim), dtype),
                "pos": jnp.full((spec.length,), -1, jnp.int32)}
    if spec.kind == "mamba2":
        from .ssm import init_ssm_state
        return init_ssm_state(cfg, batch, dtype)
    if spec.kind == "rglru":
        from .rglru import init_rglru_state
        return init_rglru_state(cfg, batch, dtype)
    raise ValueError(spec.kind)


def rollback(cache, new_pos):
    """O(1) pointer rollback (valid for attention/MLA-only stacks)."""
    return {**cache, "pos": jnp.asarray(new_pos, jnp.int32)}
