"""Decode-state (KV / recurrent) cache: dense slot caches AND the paged pool.

Dense cache (single stream / slot-stacked lanes) is a pytree:
    {"pos": int32 scalar (tokens consumed so far),
     "layers": {"prefix": [...], "stack": stacked-or-None, "tail": [...]},
     "cross": optional per-decoder-layer encoder KV (enc-dec only)}

Per-layer entries by block kind:
    attn/local : {"k","v": (B, L, G, D), "pos": (L,) int32}   (+ ring flag in spec)
    mla        : {"ckv": (B, L, R), "krope": (B, L, Dr), "pos": (L,)}
    mamba2     : {"conv": (B, K-1, Cd), "ssm": (B, H, P, N)}
    rglru      : {"conv": (B, K-1, W), "rec": (B, W)}

``CacheSpec`` carries the STATIC layout decisions (ring?, buffer length) so
jitted code can branch on them at trace time.  Rollback for attention-style
caches is O(1) (reset "pos"; stale slots carry future positions and are
masked out).  Recurrent layers need recompute-from-snapshot — the engine
keeps the pre-draft cache value (free in functional JAX) instead.

Paged cache (batched serving) replaces the per-slot ``max_len`` buffers with
ONE global block pool per layer plus per-stream block tables:

    {"lengths": (B,) int32   — valid tokens per stream,
     "tables":  (B, MB) int32 — logical block -> physical block id,
     "layers":  attn {"k","v": (N, bs, G, D)}; mla {"ckv": (N, bs, R),
                "krope": (N, bs, Dr)}; recurrent entries unchanged (B, ...)}

Logical position ``p`` of stream ``b`` lives at physical row
``tables[b, p // bs] * bs + p % bs``.  Positions are contiguous per stream,
so the position-validity mask degenerates to ``p < lengths[b]`` and rollback
is a per-stream LENGTH TRUNCATION — no cache-kind special cases, no stale
future slots.  ``BlockAllocator`` (host-side free list) hands physical
blocks to streams at admission and reclaims them at release; physical block
0 is a reserved TRASH block every empty table row points at, so masked
batch lanes write garbage there instead of into a neighbor's pages.

Blocks are REFCOUNTED (docs/prefix_sharing.md): several streams' table
rows may point at the same physical block (``share``), and registering a
block in the ``PrefixCache`` marks it IMMUTABLE and takes a reference of
its own, so prefilled prompt prefixes survive the stream that computed
them.  ``truncate``/``release`` decrement instead of freeing; a block
returns to the free list only when its last reference drops.  A stream may
write a block only while it is its sole, non-immutable owner — the
copy-on-write primitive (``BlockAllocator.cow`` + ``paged_copy_block``)
privatizes a shared block in O(block) before the first divergent write.

Both layouts optionally store K/V (and MLA latents) as INT8 with per-row
float32 scales (``kv_quant`` specs, ``models/quant.py``): payload leaves
switch dtype and gain a ``*_scale`` sibling of the same leading shape, and
every invariant above — pointer rollback, length truncation, trash-block
writes — applies to the scale leaves verbatim.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .config import ModelConfig

RING_SLACK = 256  # extra slots so multi-token (verify) steps never clobber
                  # keys still inside another in-flight query's window

# cache-leaf keys that live in the GLOBAL paged pool (no per-stream axis);
# everything else in a paged cache's layers is per-stream state. Shared by
# the engine's lane plumbing and the bench's memory accounting.  The
# ``*_scale`` leaves exist only on int8-quantized caches (kv_quant specs)
# and ride the pool exactly like their payloads.
POOL_LEAF_KEYS = frozenset({"k", "v", "ckv", "krope",
                            "k_scale", "v_scale", "ckv_scale",
                            "krope_scale"})


@dataclass(frozen=True)
class LayerCacheSpec:
    kind: str          # attn|mla|mamba2|rglru
    length: int = 0    # KV buffer length (attn/mla)
    ring: bool = False
    window: int = 0    # attention window (0 = full)


@dataclass(frozen=True)
class CacheSpec:
    layers: Tuple[LayerCacheSpec, ...]
    max_len: int
    # paged layout (0/False = dense). ``num_blocks`` counts PHYSICAL blocks
    # including the reserved trash block 0; ``max_blocks`` is the per-stream
    # table width = ceil(max_len / block_size).
    paged: bool = False
    block_size: int = 0
    num_blocks: int = 0
    max_blocks: int = 0
    # int8 KV storage: attention/MLA payload leaves become int8 and gain a
    # float32 per-row(-per-head) ``*_scale`` sibling (models/quant.py);
    # recurrent state keeps the float cache dtype.
    kv_quant: bool = False

    @property
    def cheap_rollback(self) -> bool:
        return all(l.kind in ("attn", "mla") for l in self.layers)


def build_cache_spec(cfg: ModelConfig, max_len: int, *,
                     kv_quant: bool = False) -> CacheSpec:
    specs = []
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind in ("attn", "mla"):
            if max_len > cfg.max_full_cache_len:
                w = cfg.long_context_window
                specs.append(LayerCacheSpec(kind, w + RING_SLACK, True, w))
            else:
                specs.append(LayerCacheSpec(kind, max_len, False, 0))
        elif kind == "local":
            w = cfg.window or 4096
            L = min(max_len, w + RING_SLACK)
            specs.append(LayerCacheSpec("attn", L, L < max_len, w))
        elif kind in ("mamba2", "rglru"):
            specs.append(LayerCacheSpec(kind))
        else:
            raise ValueError(kind)
    return CacheSpec(tuple(specs), max_len, kv_quant=kv_quant)


def init_layer_cache(cfg: ModelConfig, spec: LayerCacheSpec, batch: int,
                     dtype=jnp.bfloat16, kv_quant: bool = False):
    if spec.kind == "attn":
        hd = cfg.resolved_head_dim
        G, L = cfg.num_kv_heads, spec.length
        if kv_quant:
            return {"k": jnp.zeros((batch, L, G, hd), jnp.int8),
                    "v": jnp.zeros((batch, L, G, hd), jnp.int8),
                    "k_scale": jnp.zeros((batch, L, G), jnp.float32),
                    "v_scale": jnp.zeros((batch, L, G), jnp.float32),
                    "pos": jnp.full((L,), -1, jnp.int32)}
        return {"k": jnp.zeros((batch, L, G, hd), dtype),
                "v": jnp.zeros((batch, L, G, hd), dtype),
                "pos": jnp.full((L,), -1, jnp.int32)}
    if spec.kind == "mla":
        m = cfg.mla
        L = spec.length
        if kv_quant:
            return {"ckv": jnp.zeros((batch, L, m.kv_lora_rank), jnp.int8),
                    "krope": jnp.zeros((batch, L, m.qk_rope_head_dim),
                                       jnp.int8),
                    "ckv_scale": jnp.zeros((batch, L), jnp.float32),
                    "krope_scale": jnp.zeros((batch, L), jnp.float32),
                    "pos": jnp.full((L,), -1, jnp.int32)}
        return {"ckv": jnp.zeros((batch, L, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, L, m.qk_rope_head_dim), dtype),
                "pos": jnp.full((L,), -1, jnp.int32)}
    if spec.kind == "mamba2":
        from .ssm import init_ssm_state
        return init_ssm_state(cfg, batch, dtype)
    if spec.kind == "rglru":
        from .rglru import init_rglru_state
        return init_rglru_state(cfg, batch, dtype)
    raise ValueError(spec.kind)


def rollback(cache, new_pos):
    """O(1) pointer rollback (valid for attention/MLA-only stacks)."""
    return {**cache, "pos": jnp.asarray(new_pos, jnp.int32)}


# ===================================================================== paged

def build_paged_cache_spec(cfg: ModelConfig, max_len: int, *,
                           block_size: int = 64,
                           pool_tokens: Optional[int] = None,
                           kv_quant: bool = False) -> CacheSpec:
    """Paged layout for ``cfg``: attn/local/mla layers share one block table
    per stream; every logical position is stored (windowed layers mask
    instead of ring-wrapping — freeing out-of-window blocks is future work).
    ``pool_tokens`` sizes the GLOBAL pool shared by every stream; the
    default (``max_len``) backs roughly one full-length stream — batched
    callers must size it themselves (``transformer.init_paged_cache``
    defaults to ``batch * max_len``, the dense-equivalent capacity)."""
    pool_tokens = max_len if pool_tokens is None else pool_tokens
    max_blocks = -(-max_len // block_size)
    num_blocks = -(-pool_tokens // block_size) + 1          # +1: trash block 0
    specs = []
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind in ("attn", "mla"):
            specs.append(LayerCacheSpec(kind, max_len, False, 0))
        elif kind == "local":
            specs.append(LayerCacheSpec("attn", max_len, False, cfg.window or 4096))
        elif kind in ("mamba2", "rglru"):
            specs.append(LayerCacheSpec(kind))
        else:
            raise ValueError(kind)
    return CacheSpec(tuple(specs), max_len, paged=True, block_size=block_size,
                     num_blocks=num_blocks, max_blocks=max_blocks,
                     kv_quant=kv_quant)


def init_paged_layer_cache(cfg: ModelConfig, spec: LayerCacheSpec,
                           cache_spec: CacheSpec, batch: int,
                           dtype=jnp.bfloat16):
    """One layer's slice of the paged cache: a GLOBAL pool for attention
    kinds (no batch axis — streams share it via the block table), the usual
    per-stream state for recurrent kinds.  ``cache_spec.kv_quant`` pools
    store int8 payloads plus per-row(-per-head) float32 scale pools."""
    N, bs = cache_spec.num_blocks, cache_spec.block_size
    if spec.kind == "attn":
        hd = cfg.resolved_head_dim
        G = cfg.num_kv_heads
        if cache_spec.kv_quant:
            return {"k": jnp.zeros((N, bs, G, hd), jnp.int8),
                    "v": jnp.zeros((N, bs, G, hd), jnp.int8),
                    "k_scale": jnp.zeros((N, bs, G), jnp.float32),
                    "v_scale": jnp.zeros((N, bs, G), jnp.float32)}
        return {"k": jnp.zeros((N, bs, G, hd), dtype),
                "v": jnp.zeros((N, bs, G, hd), dtype)}
    if spec.kind == "mla":
        m = cfg.mla
        if cache_spec.kv_quant:
            return {"ckv": jnp.zeros((N, bs, m.kv_lora_rank), jnp.int8),
                    "krope": jnp.zeros((N, bs, m.qk_rope_head_dim), jnp.int8),
                    "ckv_scale": jnp.zeros((N, bs), jnp.float32),
                    "krope_scale": jnp.zeros((N, bs), jnp.float32)}
        return {"ckv": jnp.zeros((N, bs, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((N, bs, m.qk_rope_head_dim), dtype)}
    return init_layer_cache(cfg, spec, batch, dtype)


def paged_rollback(cache, new_lengths):
    """O(1) paged rollback: truncate per-stream lengths. Rows past the new
    length are logically dead (the ``p < length`` mask) and will be
    overwritten in place when the stream grows again — identical physical
    rows, no copy, no per-kind special case."""
    return {**cache, "lengths": jnp.asarray(new_lengths, jnp.int32)}


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class BlockAllocator:
    """Host-side physical-block allocator for one paged pool.

    Invariants (asserted by tests):
      * block 0 (trash) is never handed out;
      * ``free + in_use == num_blocks - 1`` after EVERY mutation, where a
        block is in use iff its refcount is positive (shared blocks count
        once no matter how many table rows alias them);
      * free blocks have refcount 0 and are not immutable;
      * table rows of unallocated logical blocks point at the trash block;
      * a slot writes a block only while ``writable(slot, idx)`` — sole
        owner, not immutable.  Aliased or cached blocks must be privatized
        with ``cow`` before the first divergent write.
    """

    def __init__(self, num_blocks: int, max_blocks: int, batch: int):
        assert num_blocks >= 2, "need at least one non-trash block"
        self.num_blocks = num_blocks
        self.max_blocks = max_blocks
        self.batch = batch
        self.free: List[int] = list(range(num_blocks - 1, 0, -1))  # LIFO
        self.owned: List[List[int]] = [[] for _ in range(batch)]
        self.tables = np.zeros((batch, max_blocks), np.int32)
        # per-PHYSICAL-block state: how many owners (slots' table rows plus
        # at most one PrefixCache reference) alias the block, and whether it
        # is a registered immutable prefix block (never a write target)
        self.refcount = np.zeros(num_blocks, np.int32)
        self.immutable = np.zeros(num_blocks, bool)
        self.peak_in_use = 0

    # ------------------------------------------------------------ queries
    @property
    def blocks_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self.free)

    def blocks_for(self, n_tokens: int, block_size: int) -> int:
        """Logical blocks covering ``n_tokens``.  Raises ``ValueError``
        when that exceeds the per-stream table width ``max_blocks`` — the
        request can NEVER fit, so silently clamping (the old behavior)
        would under-reserve and route the overflow through trash block 0."""
        n = -(-max(n_tokens, 1) // block_size)
        if n > self.max_blocks:
            raise ValueError(
                f"{n_tokens} tokens need {n} blocks > max_blocks="
                f"{self.max_blocks}; the stream cannot fit its table row")
        return n

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= len(self.free)

    def writable(self, slot: int, idx: int) -> bool:
        """May ``slot`` write into its ``idx``-th logical block? True iff
        it is the block's only reference and the block is not a registered
        immutable prefix — the copy-on-write predicate."""
        blk = self.owned[slot][idx]
        return self.refcount[blk] == 1 and not self.immutable[blk]

    def sharing_stats(self) -> dict:
        return {"blocks_in_use": self.blocks_in_use,
                "shared_blocks": int(np.sum(self.refcount > 1)),
                "immutable_blocks": int(np.sum(self.immutable))}

    def check_conservation(self) -> bool:
        """The allocator conservation invariant, checked STRUCTURALLY:
        every non-trash block is either on the free list (refcount 0) or
        referenced (refcount > 0), never both and never neither, so
        ``free + in_use == num_blocks - 1`` with no double-free and no
        leak.  Cheap enough to assert inside preemption-churn loops."""
        free = set(self.free)
        if len(free) != len(self.free):          # duplicate free entries
            return False
        live = {b for b in range(1, self.num_blocks) if self.refcount[b] > 0}
        return (not (free & live)
                and len(free) + len(live) == self.num_blocks - 1
                and all(self.refcount[b] == 0 for b in free))

    # ------------------------------------------------------------ refcounts
    def addref(self, blk: int) -> None:
        """Take an extra reference on an in-use block (PrefixCache
        registration / a new stream adopting it via ``share``)."""
        assert 0 < blk < self.num_blocks and self.refcount[blk] > 0, blk
        self.refcount[blk] += 1

    def decref(self, blk: int) -> bool:
        """Drop one reference; the block returns to the free list (and
        sheds its immutable mark) only when the last reference goes."""
        assert self.refcount[blk] > 0, f"decref of free block {blk}"
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            self.immutable[blk] = False
            self.free.append(blk)
            return True
        return False

    def _note_usage(self) -> None:
        if self.blocks_in_use > self.peak_in_use:
            self.peak_in_use = self.blocks_in_use

    def reset_peak(self) -> None:
        """Re-base the peak to the CURRENT usage — benches call this after
        warmup so truncate/release churn before the measured window cannot
        leave a stale peak in their pool-stats rows."""
        self.peak_in_use = self.blocks_in_use

    # ------------------------------------------------------------ mutation
    def allocate(self, slot: int, n_blocks: int) -> np.ndarray:
        """Reserve ``n_blocks`` fresh private blocks for the empty ``slot``;
        returns the updated table row.  Raises ``PoolExhausted`` if the free
        list is short (callers backpressure instead of admitting) and
        ``ValueError`` if the request exceeds the table width."""
        assert not self.owned[slot], f"slot {slot} already holds blocks"
        self.tables[slot, :] = 0
        self.extend(slot, n_blocks)
        return self.tables[slot]

    def extend(self, slot: int, n_blocks: int) -> np.ndarray:
        """Append ``n_blocks`` fresh private blocks after ``slot``'s current
        run (admission reserves the non-shared suffix this way)."""
        have = len(self.owned[slot])
        if have + n_blocks > self.max_blocks:
            raise ValueError(
                f"slot {slot}: {have}+{n_blocks} blocks exceed max_blocks="
                f"{self.max_blocks}")
        if n_blocks > len(self.free):
            raise PoolExhausted(
                f"need {n_blocks} blocks, {len(self.free)} free")
        for i in range(n_blocks):
            blk = self.free.pop()
            self.refcount[blk] = 1
            self.owned[slot].append(blk)
            self.tables[slot, have + i] = blk
        self._note_usage()
        return self.tables[slot]

    def share(self, slot: int, blocks: Sequence[int]) -> np.ndarray:
        """Point the empty ``slot``'s table row at EXISTING in-use blocks
        (a prefix-cache hit adopting a cached prompt prefix).  Each block
        gains a reference; no pool memory is consumed."""
        assert not self.owned[slot], f"slot {slot} already holds blocks"
        blocks = [int(b) for b in blocks]
        if len(blocks) > self.max_blocks:
            raise ValueError(f"{len(blocks)} shared blocks exceed max_blocks="
                             f"{self.max_blocks}")
        for b in blocks:
            self.addref(b)
        self.owned[slot] = blocks
        self.tables[slot, :] = 0
        self.tables[slot, :len(blocks)] = blocks
        self._note_usage()
        return self.tables[slot]

    def cow(self, slot: int, idx: int) -> Tuple[int, int]:
        """Copy-on-write: replace ``slot``'s ``idx``-th logical block with a
        fresh private block, dropping its reference on the old one.  Returns
        ``(src, dst)`` physical ids — the caller must copy the pool rows
        (``paged_copy_block``) before the stream's next write."""
        if not self.free:
            raise PoolExhausted("no free block for copy-on-write")
        src = self.owned[slot][idx]
        dst = self.free.pop()
        self.refcount[dst] = 1
        self.owned[slot][idx] = dst
        self.tables[slot, idx] = dst
        self.decref(src)
        self._note_usage()
        return src, dst

    def truncate(self, slot: int, keep_tokens: int, block_size: int) -> int:
        """Drop whole blocks past ``keep_tokens`` from ``slot``'s run
        (preemption / shrink); each loses one reference and returns to the
        free list only if that was the last.  Returns how many blocks the
        slot dropped.  Per-tick speculative rollback does NOT call this —
        reserved capacity makes rollback a pure length write — but
        release-on-close and preemption do."""
        keep = 0 if keep_tokens <= 0 else -(-keep_tokens // block_size)
        keep = min(keep, len(self.owned[slot]))
        dropped = 0
        while len(self.owned[slot]) > keep:
            blk = self.owned[slot].pop()
            self.tables[slot, len(self.owned[slot])] = 0
            self.decref(blk)
            dropped += 1
        self._note_usage()
        return dropped

    def release(self, slot: int) -> int:
        """Drop every block owned by ``slot``; blocks whose last reference
        this was return to the free list (shared/cached blocks survive)."""
        n = self.truncate(slot, 0, 1)
        self.tables[slot, :] = 0
        return n


def paged_copy_block(cache, src: int, dst: int):
    """O(block) copy-on-write primitive: duplicate physical block ``src``'s
    rows into ``dst`` across EVERY pool leaf — K/V payloads, MLA latents,
    and the int8 ``*_scale`` siblings, which are per-row state and must
    travel with their payload block (docs/prefix_sharing.md).  Per-stream
    leaves (tables/lengths/recurrent state) are untouched."""
    def f(path, a):
        if getattr(path[-1], "key", None) in POOL_LEAF_KEYS:
            return a.at[dst].set(a[src])
        return a
    return {**cache,
            "layers": jax.tree_util.tree_map_with_path(f, cache["layers"])}


# ============================================================ prefix cache

class _PrefixNode:
    """One block-aligned prompt chunk: the trie path from the root spells
    the token prefix, ``blocks[i]`` is the physical block holding its KV in
    allocator ``i``'s pool (draft and target travel together)."""
    __slots__ = ("children", "blocks", "tick")

    def __init__(self, blocks):
        self.children: Dict[tuple, "_PrefixNode"] = {}
        self.blocks = blocks
        self.tick = 0


class PrefixCache:
    """Host-side radix cache of prefilled prompt prefixes over a pair of
    block pools (docs/prefix_sharing.md).

    Prompts are split into block-aligned chunks; each cached chunk maps the
    HASHED chunk (dict-keyed on the token tuple, so hash collisions cannot
    corrupt a lookup) to one physical block per allocator.  ``match`` walks
    the trie for the longest cached chunk run that prefixes a new prompt;
    ``insert`` registers a stream's freshly prefilled blocks, taking a
    cache-owned reference on each (``addref``) and marking it immutable so
    it survives the stream's release and can never be written in place.

    No resume state beyond the block run is stored: the engines' refeed
    invariant (draft re-enters from ``seq[-2:]``, target from ``seq[-1:]``)
    means a hit resumes decode from tables + lengths alone — the "cached
    last-token state" of the design degenerates to the block run itself.

    Eviction is LRU over trie LEAVES and gated on ``refcount == 1`` in
    every allocator: a chunk still aliased by a live stream is pinned."""

    def __init__(self, block_size: int, allocs: Sequence[BlockAllocator]):
        self.block_size = block_size
        self.allocs = tuple(allocs)
        self.root = _PrefixNode(None)
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evictions = 0

    def _chunks(self, tokens: Sequence[int], limit: Optional[int] = None):
        bs = self.block_size
        n = (len(tokens) if limit is None else min(limit, len(tokens))) // bs
        return [tuple(tokens[i * bs:(i + 1) * bs]) for i in range(n)]

    # -------------------------------------------------------------- stats
    @property
    def n_chunks(self) -> int:
        def count(node):
            return sum(1 + count(c) for c in node.children.values())
        return count(self.root)

    def cached_blocks(self) -> int:
        """Physical blocks held by the cache, summed over allocators."""
        return self.n_chunks * len(self.allocs)

    def evictable_chunks(self) -> int:
        """Chunks droppable RIGHT NOW or after their descendants go: the
        capacity ``can_admit`` may count on reclaiming via ``evict``."""
        def walk(node):
            n, all_ok = 0, True
            for c in node.children.values():
                cn, cok = walk(c)
                n += cn
                all_ok = all_ok and cok
            if node is self.root:
                return n, all_ok
            mine = all_ok and all(a.refcount[b] == 1
                                  for a, b in zip(self.allocs, node.blocks))
            return n + (1 if mine else 0), mine
        return walk(self.root)[0]

    # ------------------------------------------------------------- lookup
    def match(self, tokens: Sequence[int], limit_tokens: Optional[int] = None,
              touch: bool = True):
        """Longest cached chunk run prefixing ``tokens[:limit_tokens]``:
        returns ``(n_chunks, runs)`` with ``runs[i]`` the physical blocks in
        allocator ``i``.  ``touch=False`` (admission feasibility probes)
        leaves the LRU clocks and hit/miss counters alone."""
        if touch:
            self._tick += 1
        node, runs = self.root, [[] for _ in self.allocs]
        for chunk in self._chunks(tokens, limit_tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            if touch:
                child.tick = self._tick
            for i, blk in enumerate(child.blocks):
                runs[i].append(blk)
            node = child
        n = len(runs[0])
        if touch:
            if n:
                self.hits += 1
                self.hit_tokens += n * self.block_size
            else:
                self.misses += 1
        return n, runs

    def insert(self, tokens: Sequence[int], n_chunks: int,
               rows: Sequence[Sequence[int]]) -> int:
        """Register ``tokens``' first ``n_chunks`` chunks, backed by
        ``rows[i][d]`` (allocator ``i``, depth ``d``).  Depths already
        cached are left as-is (the existing copy wins — the new stream
        adopted it anyway); new depths gain a cache-owned reference and the
        immutable mark.  Returns how many chunks were newly cached."""
        self._tick += 1
        node, added = self.root, 0
        for d, chunk in enumerate(self._chunks(tokens)[:n_chunks]):
            child = node.children.get(chunk)
            if child is None:
                blocks = tuple(int(rows[i][d])
                               for i in range(len(self.allocs)))
                for alloc, blk in zip(self.allocs, blocks):
                    alloc.addref(blk)
                    alloc.immutable[blk] = True
                child = node.children[chunk] = _PrefixNode(blocks)
                added += 1
            child.tick = self._tick
            node = child
        return added

    # ------------------------------------------------------------ eviction
    def _evictable_leaves(self):
        out = []
        def walk(parent):
            for key, node in parent.children.items():
                walk(node)
                if not node.children and all(
                        a.refcount[b] == 1
                        for a, b in zip(self.allocs, node.blocks)):
                    out.append((node.tick, parent, key, node))
        walk(self.root)
        out.sort(key=lambda t: t[0])
        return out

    def evict(self, n_blocks: int) -> int:
        """Drop least-recently-used evictable leaves until ``n_blocks``
        blocks are freed PER ALLOCATOR or nothing evictable remains
        (interior chunks unlock as their children go).  Returns the number
        of chunks evicted."""
        dropped = 0
        while dropped < n_blocks:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            for _, parent, key, node in leaves[:n_blocks - dropped]:
                del parent.children[key]
                for alloc, blk in zip(self.allocs, node.blocks):
                    alloc.decref(blk)          # 1 -> 0: back to the free list
                dropped += 1
                self.evictions += 1
        return dropped

    def stats(self) -> dict:
        return {"chunks": self.n_chunks, "hits": self.hits,
                "misses": self.misses, "hit_tokens": self.hit_tokens,
                "evictions": self.evictions}


# ================================================= encoder segment pool

class EncoderSegmentPool:
    """Host-side refcounting over the SHARED ENCODER SEGMENT pools of an
    enc-dec paged cache (``transformer.init_paged_cache``).

    The device side is a per-cross-layer (n_segments, T, G, hd) K/V pool;
    every lane's ``cross_seg`` row indexes into it.  This class owns the
    admission-time bookkeeping, mirroring the prefix cache's adoption
    semantics for encoder outputs: segments are keyed by a DIGEST of the
    raw conditioning payload (frame embeddings), so N streams decoding
    against the same encoded input share ONE segment — one encoder forward,
    one K/V copy — exactly like a prefix-cache hit skips a shared prefill.

    Segment 0 is the reserved NULL segment (all-zero K/V = cross no-op) and
    is never allocated or refcounted.  Segments are immutable once written:
    ``acquire`` either returns an existing segment (hit, +1 ref) or hands
    out a free index the caller must fill via ``write_cross_segment``.
    """

    def __init__(self, n_segments: int):
        self.n_segments = int(n_segments)
        self._free = list(range(self.n_segments - 1, 0, -1))
        self._by_digest: Dict[str, int] = {}
        self._digest_of: Dict[int, str] = {}
        self.refcount: Dict[int, int] = {}
        self.seg_bytes: Dict[int, int] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def digest(payload) -> str:
        """Content key of one conditioning payload (any array)."""
        import hashlib
        a = np.ascontiguousarray(np.asarray(payload))
        h = hashlib.sha1(a.tobytes())
        h.update(str((a.shape, a.dtype)).encode())
        return h.hexdigest()

    @property
    def free_segments(self) -> int:
        return len(self._free)

    def acquire(self, digest: str, nbytes: int) -> Tuple[int, bool]:
        """Return ``(segment, is_new)`` for a payload digest: a hit addrefs
        the existing segment; a miss pops a free index (the caller encodes
        and writes it).  ``nbytes`` is the payload size the segment stands
        in for — the sharing accounting of ``stats()``."""
        seg = self._by_digest.get(digest)
        if seg is not None:
            self.refcount[seg] += 1
            self.hits += 1
            return seg, False
        if not self._free:
            raise RuntimeError("encoder segment pool exhausted")
        seg = self._free.pop()
        self._by_digest[digest] = seg
        self._digest_of[seg] = digest
        self.refcount[seg] = 1
        self.seg_bytes[seg] = int(nbytes)
        self.misses += 1
        return seg, True

    def release(self, seg: int) -> bool:
        """Drop one reference; a segment whose last reference goes returns
        to the free list.  Segment 0 (null) is a no-op."""
        if seg == 0:
            return False
        self.refcount[seg] -= 1
        if self.refcount[seg]:
            return False
        del self.refcount[seg]
        del self._by_digest[self._digest_of.pop(seg)]
        del self.seg_bytes[seg]
        self._free.append(seg)
        return True

    def stats(self) -> dict:
        """Sharing accounting: ``logical_bytes`` is what N private copies
        would cost, ``unique_bytes`` what the pool actually holds — the
        bench's ~1/N claim is their ratio."""
        unique = sum(self.seg_bytes.values())
        logical = sum(self.seg_bytes[s] * r for s, r in self.refcount.items())
        return {"segments": self.n_segments - 1,
                "unique_segments": len(self.refcount),
                "logical_refs": sum(self.refcount.values()),
                "unique_bytes": unique, "logical_bytes": logical,
                "hits": self.hits, "misses": self.misses}
