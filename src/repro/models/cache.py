"""Decode-state (KV / recurrent) cache: dense slot caches AND the paged pool.

Dense cache (single stream / slot-stacked lanes) is a pytree:
    {"pos": int32 scalar (tokens consumed so far),
     "layers": {"prefix": [...], "stack": stacked-or-None, "tail": [...]},
     "cross": optional per-decoder-layer encoder KV (enc-dec only)}

Per-layer entries by block kind:
    attn/local : {"k","v": (B, L, G, D), "pos": (L,) int32}   (+ ring flag in spec)
    mla        : {"ckv": (B, L, R), "krope": (B, L, Dr), "pos": (L,)}
    mamba2     : {"conv": (B, K-1, Cd), "ssm": (B, H, P, N)}
    rglru      : {"conv": (B, K-1, W), "rec": (B, W)}

``CacheSpec`` carries the STATIC layout decisions (ring?, buffer length) so
jitted code can branch on them at trace time.  Rollback for attention-style
caches is O(1) (reset "pos"; stale slots carry future positions and are
masked out).  Recurrent layers need recompute-from-snapshot — the engine
keeps the pre-draft cache value (free in functional JAX) instead.

Paged cache (batched serving) replaces the per-slot ``max_len`` buffers with
ONE global block pool per layer plus per-stream block tables:

    {"lengths": (B,) int32   — valid tokens per stream,
     "tables":  (B, MB) int32 — logical block -> physical block id,
     "layers":  attn {"k","v": (N, bs, G, D)}; mla {"ckv": (N, bs, R),
                "krope": (N, bs, Dr)}; recurrent entries unchanged (B, ...)}

Logical position ``p`` of stream ``b`` lives at physical row
``tables[b, p // bs] * bs + p % bs``.  Positions are contiguous per stream,
so the position-validity mask degenerates to ``p < lengths[b]`` and rollback
is a per-stream LENGTH TRUNCATION — no cache-kind special cases, no stale
future slots.  ``BlockAllocator`` (host-side free list) hands physical
blocks to streams at admission and reclaims them at release; physical block
0 is a reserved TRASH block every empty table row points at, so masked
batch lanes write garbage there instead of into a neighbor's pages.

Both layouts optionally store K/V (and MLA latents) as INT8 with per-row
float32 scales (``kv_quant`` specs, ``models/quant.py``): payload leaves
switch dtype and gain a ``*_scale`` sibling of the same leading shape, and
every invariant above — pointer rollback, length truncation, trash-block
writes — applies to the scale leaves verbatim.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from .config import ModelConfig

RING_SLACK = 256  # extra slots so multi-token (verify) steps never clobber
                  # keys still inside another in-flight query's window

# cache-leaf keys that live in the GLOBAL paged pool (no per-stream axis);
# everything else in a paged cache's layers is per-stream state. Shared by
# the engine's lane plumbing and the bench's memory accounting.  The
# ``*_scale`` leaves exist only on int8-quantized caches (kv_quant specs)
# and ride the pool exactly like their payloads.
POOL_LEAF_KEYS = frozenset({"k", "v", "ckv", "krope",
                            "k_scale", "v_scale", "ckv_scale",
                            "krope_scale"})


@dataclass(frozen=True)
class LayerCacheSpec:
    kind: str          # attn|mla|mamba2|rglru
    length: int = 0    # KV buffer length (attn/mla)
    ring: bool = False
    window: int = 0    # attention window (0 = full)


@dataclass(frozen=True)
class CacheSpec:
    layers: Tuple[LayerCacheSpec, ...]
    max_len: int
    # paged layout (0/False = dense). ``num_blocks`` counts PHYSICAL blocks
    # including the reserved trash block 0; ``max_blocks`` is the per-stream
    # table width = ceil(max_len / block_size).
    paged: bool = False
    block_size: int = 0
    num_blocks: int = 0
    max_blocks: int = 0
    # int8 KV storage: attention/MLA payload leaves become int8 and gain a
    # float32 per-row(-per-head) ``*_scale`` sibling (models/quant.py);
    # recurrent state keeps the float cache dtype.
    kv_quant: bool = False

    @property
    def cheap_rollback(self) -> bool:
        return all(l.kind in ("attn", "mla") for l in self.layers)


def build_cache_spec(cfg: ModelConfig, max_len: int, *,
                     kv_quant: bool = False) -> CacheSpec:
    specs = []
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind in ("attn", "mla"):
            if max_len > cfg.max_full_cache_len:
                w = cfg.long_context_window
                specs.append(LayerCacheSpec(kind, w + RING_SLACK, True, w))
            else:
                specs.append(LayerCacheSpec(kind, max_len, False, 0))
        elif kind == "local":
            w = cfg.window or 4096
            L = min(max_len, w + RING_SLACK)
            specs.append(LayerCacheSpec("attn", L, L < max_len, w))
        elif kind in ("mamba2", "rglru"):
            specs.append(LayerCacheSpec(kind))
        else:
            raise ValueError(kind)
    return CacheSpec(tuple(specs), max_len, kv_quant=kv_quant)


def init_layer_cache(cfg: ModelConfig, spec: LayerCacheSpec, batch: int,
                     dtype=jnp.bfloat16, kv_quant: bool = False):
    if spec.kind == "attn":
        hd = cfg.resolved_head_dim
        G, L = cfg.num_kv_heads, spec.length
        if kv_quant:
            return {"k": jnp.zeros((batch, L, G, hd), jnp.int8),
                    "v": jnp.zeros((batch, L, G, hd), jnp.int8),
                    "k_scale": jnp.zeros((batch, L, G), jnp.float32),
                    "v_scale": jnp.zeros((batch, L, G), jnp.float32),
                    "pos": jnp.full((L,), -1, jnp.int32)}
        return {"k": jnp.zeros((batch, L, G, hd), dtype),
                "v": jnp.zeros((batch, L, G, hd), dtype),
                "pos": jnp.full((L,), -1, jnp.int32)}
    if spec.kind == "mla":
        m = cfg.mla
        L = spec.length
        if kv_quant:
            return {"ckv": jnp.zeros((batch, L, m.kv_lora_rank), jnp.int8),
                    "krope": jnp.zeros((batch, L, m.qk_rope_head_dim),
                                       jnp.int8),
                    "ckv_scale": jnp.zeros((batch, L), jnp.float32),
                    "krope_scale": jnp.zeros((batch, L), jnp.float32),
                    "pos": jnp.full((L,), -1, jnp.int32)}
        return {"ckv": jnp.zeros((batch, L, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, L, m.qk_rope_head_dim), dtype),
                "pos": jnp.full((L,), -1, jnp.int32)}
    if spec.kind == "mamba2":
        from .ssm import init_ssm_state
        return init_ssm_state(cfg, batch, dtype)
    if spec.kind == "rglru":
        from .rglru import init_rglru_state
        return init_rglru_state(cfg, batch, dtype)
    raise ValueError(spec.kind)


def rollback(cache, new_pos):
    """O(1) pointer rollback (valid for attention/MLA-only stacks)."""
    return {**cache, "pos": jnp.asarray(new_pos, jnp.int32)}


# ===================================================================== paged

def build_paged_cache_spec(cfg: ModelConfig, max_len: int, *,
                           block_size: int = 64,
                           pool_tokens: Optional[int] = None,
                           kv_quant: bool = False) -> CacheSpec:
    """Paged layout for ``cfg``: attn/local/mla layers share one block table
    per stream; every logical position is stored (windowed layers mask
    instead of ring-wrapping — freeing out-of-window blocks is future work).
    ``pool_tokens`` sizes the GLOBAL pool shared by every stream; the
    default (``max_len``) backs roughly one full-length stream — batched
    callers must size it themselves (``transformer.init_paged_cache``
    defaults to ``batch * max_len``, the dense-equivalent capacity)."""
    pool_tokens = max_len if pool_tokens is None else pool_tokens
    max_blocks = -(-max_len // block_size)
    num_blocks = -(-pool_tokens // block_size) + 1          # +1: trash block 0
    specs = []
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind in ("attn", "mla"):
            specs.append(LayerCacheSpec(kind, max_len, False, 0))
        elif kind == "local":
            specs.append(LayerCacheSpec("attn", max_len, False, cfg.window or 4096))
        elif kind in ("mamba2", "rglru"):
            specs.append(LayerCacheSpec(kind))
        else:
            raise ValueError(kind)
    return CacheSpec(tuple(specs), max_len, paged=True, block_size=block_size,
                     num_blocks=num_blocks, max_blocks=max_blocks,
                     kv_quant=kv_quant)


def init_paged_layer_cache(cfg: ModelConfig, spec: LayerCacheSpec,
                           cache_spec: CacheSpec, batch: int,
                           dtype=jnp.bfloat16):
    """One layer's slice of the paged cache: a GLOBAL pool for attention
    kinds (no batch axis — streams share it via the block table), the usual
    per-stream state for recurrent kinds.  ``cache_spec.kv_quant`` pools
    store int8 payloads plus per-row(-per-head) float32 scale pools."""
    N, bs = cache_spec.num_blocks, cache_spec.block_size
    if spec.kind == "attn":
        hd = cfg.resolved_head_dim
        G = cfg.num_kv_heads
        if cache_spec.kv_quant:
            return {"k": jnp.zeros((N, bs, G, hd), jnp.int8),
                    "v": jnp.zeros((N, bs, G, hd), jnp.int8),
                    "k_scale": jnp.zeros((N, bs, G), jnp.float32),
                    "v_scale": jnp.zeros((N, bs, G), jnp.float32)}
        return {"k": jnp.zeros((N, bs, G, hd), dtype),
                "v": jnp.zeros((N, bs, G, hd), dtype)}
    if spec.kind == "mla":
        m = cfg.mla
        if cache_spec.kv_quant:
            return {"ckv": jnp.zeros((N, bs, m.kv_lora_rank), jnp.int8),
                    "krope": jnp.zeros((N, bs, m.qk_rope_head_dim), jnp.int8),
                    "ckv_scale": jnp.zeros((N, bs), jnp.float32),
                    "krope_scale": jnp.zeros((N, bs), jnp.float32)}
        return {"ckv": jnp.zeros((N, bs, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((N, bs, m.qk_rope_head_dim), dtype)}
    return init_layer_cache(cfg, spec, batch, dtype)


def paged_rollback(cache, new_lengths):
    """O(1) paged rollback: truncate per-stream lengths. Rows past the new
    length are logically dead (the ``p < length`` mask) and will be
    overwritten in place when the stream grows again — identical physical
    rows, no copy, no per-kind special case."""
    return {**cache, "lengths": jnp.asarray(new_lengths, jnp.int32)}


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class BlockAllocator:
    """Host-side physical-block allocator for one paged pool.

    Invariants (asserted by tests):
      * block 0 (trash) is never handed out;
      * a physical block belongs to at most one slot at a time;
      * ``free + in_use == num_blocks - 1`` at all times;
      * table rows of unallocated logical blocks point at the trash block.
    """

    def __init__(self, num_blocks: int, max_blocks: int, batch: int):
        assert num_blocks >= 2, "need at least one non-trash block"
        self.num_blocks = num_blocks
        self.max_blocks = max_blocks
        self.batch = batch
        self.free: List[int] = list(range(num_blocks - 1, 0, -1))  # LIFO
        self.owned: List[List[int]] = [[] for _ in range(batch)]
        self.tables = np.zeros((batch, max_blocks), np.int32)
        self.peak_in_use = 0

    # ------------------------------------------------------------ queries
    @property
    def blocks_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self.free)

    def blocks_for(self, n_tokens: int, block_size: int) -> int:
        return min(-(-max(n_tokens, 1) // block_size), self.max_blocks)

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= len(self.free)

    # ------------------------------------------------------------ mutation
    def allocate(self, slot: int, n_blocks: int) -> np.ndarray:
        """Reserve ``n_blocks`` physical blocks for ``slot``; returns the
        updated table row. Raises ``PoolExhausted`` if the free list is
        short (callers backpressure instead of admitting)."""
        n_blocks = min(n_blocks, self.max_blocks)
        assert not self.owned[slot], f"slot {slot} already holds blocks"
        if n_blocks > len(self.free):
            raise PoolExhausted(
                f"need {n_blocks} blocks, {len(self.free)} free")
        blocks = [self.free.pop() for _ in range(n_blocks)]
        self.owned[slot] = blocks
        row = self.tables[slot]
        row[:] = 0
        row[:n_blocks] = blocks
        self.peak_in_use = max(self.peak_in_use, self.blocks_in_use)
        return row

    def truncate(self, slot: int, keep_tokens: int, block_size: int) -> int:
        """Free whole blocks past ``keep_tokens`` (preemption / shrink);
        returns how many were released. Per-tick speculative rollback does
        NOT call this — reserved capacity makes rollback a pure length
        write — but release-on-close and preemption do."""
        keep = self.blocks_for(keep_tokens, block_size) if keep_tokens > 0 else 0
        released = 0
        while len(self.owned[slot]) > keep:
            blk = self.owned[slot].pop()
            self.tables[slot, len(self.owned[slot])] = 0
            self.free.append(blk)
            released += 1
        return released

    def release(self, slot: int) -> int:
        """Return every block owned by ``slot`` to the free list."""
        n = self.truncate(slot, 0, 1)
        self.tables[slot, :] = 0
        return n
