"""Self-attention: GQA/MQA/MHA, optional sliding window, qk-norm, QKV bias.

Two XLA execution paths (the Pallas TPU kernels in ``repro.kernels`` are the
hardware target; on CPU they are validated in interpret mode only):

  * ``naive``     — materializes the (Sq, Sk) score matrix; used for small
                    shapes and as the reference.
  * ``flash_xla`` — query-chunked map + kv-chunked scan with online softmax;
                    O(chunk^2) live memory, required for 32k+ dry-runs.

All masking is position-based: key slot ``s`` is visible to query ``i`` iff
``0 <= kpos[s] <= qpos[i]`` and (windowed) ``qpos[i] - kpos[s] < window``.
This single rule covers causal training, ring-buffer decode caches and
rollback-by-pointer (stale slots carry pos -1 or a future position).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, rms_norm, softcap
from .quant import dequantize_rows, kv_is_quantized, qmatmul, quantize_rows
from .sharding import constrain

NEG_INF = -1e30


# --------------------------------------------------------------- params

def init_attention(key, cfg, *, cross: bool = False, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def qkv_proj(params, cfg, x, positions=None, *, rope: bool = True):
    """Returns q (B,S,H,D), k/v (B,S,G,D); rope applied if positions given."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = qmatmul(x, params["wq"])
    k = qmatmul(x, params["wk"])
    v = qmatmul(x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# --------------------------------------------------------------- sdpa

def _mask(qpos, kpos, window: int, causal: bool):
    """(Sq, Sk) boolean visibility mask from absolute positions."""
    m = kpos[None, :] >= 0
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def explicit_mask_sdpa(q, k, v, mask, cap=0.0, seq_sharded: bool = False):
    """Score-matrix attention under an EXPLICIT visibility mask.

    q (B,Sq,H,D); k,v (B,Sk,G,D); mask (Sq,Sk) or (B,Sq,Sk) bool.  The
    position-based paths derive their mask from (qpos, kpos); the tree paths
    pass an ancestor mask that positions cannot express (siblings share a
    RoPE position but must not see each other).
    """
    B, Sq, H, D = q.shape
    G = k.shape[2]
    qg = q.reshape(B, Sq, G, H // G, D)
    scores = jnp.einsum("bsgqd,btgd->bgqst", qg, k).astype(jnp.float32)
    if seq_sharded:
        # keep the KV length sharded over "model": XLA then emits the
        # distributed-softmax pattern (partial max/sum + tiny all-reduce)
        # instead of all-gathering the cache (§Perf iteration 2)
        scores = constrain(scores, ("pod", "data"), None, None, None, "model")
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    scores = softcap(scores, cap)
    if mask.ndim == 2:
        mask = mask[None]
    m = mask[:, None, None]                                  # (B,1,1,Sq,Sk)
    scores = jnp.where(m, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (no valid key yet) -> zeros, not NaN
    p = jnp.where(mask.any(-1)[:, None, None, :, None], p, 0.0)
    out = jnp.einsum("bgqst,btgd->bsgqd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1])


def _naive_sdpa(q, k, v, qpos, kpos, window, causal, cap=0.0,
                seq_sharded: bool = False):
    return explicit_mask_sdpa(q, k, v, _mask(qpos, kpos, window, causal),
                              cap, seq_sharded=seq_sharded)


def _flash_xla(q, k, v, qpos, kpos, window, causal, cap=0.0,
               q_chunk: int = 512, kv_chunk: int = 1024):
    """Pure-XLA flash attention: scan over KV chunks with online softmax."""
    B, Sq, H, D = q.shape
    G = k.shape[2]
    Dv = v.shape[-1]
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, k.shape[1])
    # pad to multiples
    pq = (-Sq) % qc
    pk = (-k.shape[1]) % kc
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, pq), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pk), constant_values=-1)
    Sqp, Skp = q.shape[1], k.shape[1]
    nq, nk = Sqp // qc, Skp // kc
    qs = q.reshape(B, nq, qc, G, H // G, D).transpose(1, 0, 2, 3, 4, 5)
    qps = qpos.reshape(nq, qc)
    ks = k.reshape(B, nk, kc, G, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kc, G, Dv).transpose(1, 0, 2, 3, 4)
    kps = kpos.reshape(nk, kc)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    def q_block(args):
        qb, qp = args  # (B,qc,G,Hg,D), (qc,)

        def kv_step(carry, kv):
            m_i, l_i, acc = carry
            kb, vb, kp = kv
            s = jnp.einsum("bqghd,bkgd->bqghk", qb, kb).astype(jnp.float32) * scale
            s = softcap(s, cap)
            msk = _mask(qp, kp, window, causal)            # (qc, kc)
            s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqghk,bkgd->bqghd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, qc, G, H // G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, G, H // G), jnp.float32)
        a0 = jnp.zeros((B, qc, G, H // G, Dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        out = jnp.where((l_f > 0)[..., None], out, 0.0)
        return out.astype(q.dtype)

    out = jax.lax.map(q_block, (qs, qps))                  # (nq,B,qc,G,Hg,D)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, H, Dv)
    return out[:, :Sq]


def sdpa(q, k, v, qpos, kpos, *, window: int = 0, causal: bool = True,
         logits_softcap: float = 0.0, impl: str = "auto",
         seq_sharded: bool = False):
    """Scaled dot-product attention with position-based masking.

    q: (B,Sq,H,D); k,v: (B,Sk,G,D) with H % G == 0.
    qpos: (Sq,) absolute positions of queries; kpos: (Sk,) of keys (-1 =
    invalid slot). seq_sharded: the KV length axis is sharded over "model"
    (set for decode caches whose KV-head count cannot shard) — keeps
    attention local via distributed softmax.
    """
    if impl == "auto":
        flops_proxy = q.shape[1] * k.shape[1]
        impl = "flash_xla" if flops_proxy > 512 * 2048 else "naive"
    if impl == "naive":
        return _naive_sdpa(q, k, v, qpos, kpos, window, causal, logits_softcap,
                           seq_sharded=seq_sharded)
    if impl == "flash_xla":
        return _flash_xla(q, k, v, qpos, kpos, window, causal, logits_softcap)
    raise ValueError(impl)


# --------------------------------------------------------------- blocks

def attn_train(params, cfg, x, positions, *, window: int = 0,
               causal: bool = True, impl: str = "auto"):
    """Full-sequence self-attention (no cache); causal unless encoder."""
    q, k, v = qkv_proj(params, cfg, x, positions)
    q = constrain(q, None, None, "model")
    k = constrain(k, None, None, "model")
    out = sdpa(q, k, v, positions, positions, window=window, causal=causal,
               logits_softcap=cfg.logits_softcap, impl=impl)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    return qmatmul(out, params["wo"])


def _kv_entries(cache_layer, k_new, v_new):
    """The leaf updates a K/V write must apply: {k, v} for float caches,
    {k, v, k_scale, v_scale} (rows quantized here) for int8 caches."""
    if kv_is_quantized(cache_layer):
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return {"k": k_new, "v": v_new}


def cache_kv(cache_layer, dtype):
    """Read a dense cache layer's K/V as ``dtype`` — dequantizing int8
    payloads against their per-row scales, a plain cast otherwise."""
    if kv_is_quantized(cache_layer):
        return (dequantize_rows(cache_layer["k"], cache_layer["k_scale"], dtype),
                dequantize_rows(cache_layer["v"], cache_layer["v_scale"], dtype))
    return cache_layer["k"].astype(dtype), cache_layer["v"].astype(dtype)


def write_cache(cache_layer, k_new, v_new, pos0, ring: bool):
    """Insert S new K/V rows at absolute position pos0 (traced scalar).
    Int8 caches quantize the rows here and write scale rows alongside."""
    L = cache_layer["k"].shape[1]
    S = k_new.shape[1]
    newpos = pos0 + jnp.arange(S, dtype=jnp.int32)
    entries = _kv_entries(cache_layer, k_new, v_new)
    if not ring:
        out = {key: jax.lax.dynamic_update_slice_in_dim(
                   cache_layer[key], val.astype(cache_layer[key].dtype),
                   pos0, 1)
               for key, val in entries.items()}
        out["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["pos"], newpos, pos0, 0)
        return out
    if S >= L:  # only the last L tokens can survive
        entries = {key: val[:, -L:] for key, val in entries.items()}
        newpos = newpos[-L:]
    slots = (newpos % L).astype(jnp.int32)
    out = {key: cache_layer[key].at[:, slots].set(
               val.astype(cache_layer[key].dtype))
           for key, val in entries.items()}
    out["pos"] = cache_layer["pos"].at[slots].set(newpos)
    return out


def attn_cached(params, cfg, x, pos0, cache_layer, *, window: int = 0,
                ring: bool = False, impl: str = "auto"):
    """Prefill/decode step: S new tokens starting at absolute pos0.

    ``ring`` is STATIC (decided by the cache spec at cache-init time): ring
    caches wrap writes modulo the buffer length; full caches use contiguous
    dynamic-update-slice writes.
    """
    B, S, _ = x.shape
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)
    q, k, v = qkv_proj(params, cfg, x, positions)
    cache_layer = write_cache(cache_layer, k, v, pos0, ring=ring)
    # decode caches whose KV-head count can't shard over "model" are
    # sequence-sharded (launch/shardings.cache_spec) -> distributed softmax
    from .sharding import get_mesh
    mesh = get_mesh()
    L = cache_layer["k"].shape[1]
    G = cache_layer["k"].shape[2]
    seq_sharded = bool(
        mesh is not None and "model" in mesh.axis_names and
        G % mesh.shape["model"] != 0 and L % mesh.shape["model"] == 0)
    kk, vv = cache_kv(cache_layer, q.dtype)
    out = sdpa(q, kk, vv, positions,
               cache_layer["pos"], window=window,
               logits_softcap=cfg.logits_softcap, impl=impl,
               seq_sharded=seq_sharded)
    out = out.reshape(B, S, -1)
    return qmatmul(out, params["wo"]), cache_layer


# ------------------------------------------------------------ paged path

def paged_write(pool, new, tables, lengths):
    """Scatter S new per-stream rows into the global block pool.

    pool (N, bs, ...); new (B, S, ...); tables (B, MB); lengths (B,) tokens
    already stored per stream.  Stream b's token at logical position p lands
    in physical row ``tables[b, p // bs] * bs + p % bs``.  Lanes whose table
    row is all-zero (masked/empty slots) write into the trash block 0; the
    allocator never hands block 0 to a stream, so those writes cannot leak
    into a neighbor's pages.

    Sharing invariant (docs/prefix_sharing.md): writes land only at
    positions >= lengths[b], and admission copy-on-writes any refcount>1 /
    immutable block overlapping the stream's write frontier BEFORE the
    first tick — so this scatter only ever touches sole-owner blocks, and
    needs no refcount awareness of its own.  Rollback stays a pure length
    write (``paged_rollback``) for the same reason: shared blocks live
    strictly below the frontier and are never rewritten in place.
    """
    N, bs = pool.shape[0], pool.shape[1]
    B, S = new.shape[:2]
    MB = tables.shape[1]
    offs = lengths[:, None].astype(jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    blk = offs // bs
    phys = jnp.take_along_axis(tables, jnp.clip(blk, 0, MB - 1), axis=1)
    # beyond-table overflow goes to the TRASH block, never a live one —
    # wrapping into tables[b, MB-1] would silently corrupt the stream's
    # own newest rows (engines assert lengths stay within max_len)
    phys = jnp.where(blk < MB, phys, 0)                          # (B, S)
    rows = phys * bs + offs % bs                                 # (B, S)
    flat = pool.reshape((N * bs,) + pool.shape[2:])
    flat = flat.at[rows.reshape(-1)].set(
        new.reshape((B * S,) + new.shape[2:]).astype(pool.dtype))
    return flat.reshape(pool.shape)


def gather_pages(pool, tables):
    """Materialize each stream's logical view (B, MB*bs, ...) of the pool.

    This is the XLA gather path (CPU/correctness); the Pallas kernel
    ``kernels.decode_attention.paged_decode_attention`` streams blocks via
    the table instead of materializing the view.
    """
    N, bs = pool.shape[0], pool.shape[1]
    B, MB = tables.shape
    rows = (tables[:, :, None] * bs +
            jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(B, MB * bs)
    flat = pool.reshape((N * bs,) + pool.shape[2:])
    return flat[rows]                                            # (B, MB*bs, ...)


def paged_write_kv(layer_cache, k_new, v_new, tables, lengths):
    """``paged_write`` for a whole attention layer, quantizing rows first
    when the pools are int8 (scale pools written through the same table)."""
    entries = _kv_entries(layer_cache, k_new, v_new)
    return {key: paged_write(layer_cache[key], val, tables, lengths)
            for key, val in entries.items()}


def gather_kv_pages(layer_cache, tables, dtype):
    """Each stream's logical K/V view (B, MB*bs, G, D) as ``dtype`` —
    gathering and dequantizing the scale pools when the payload is int8."""
    kg = gather_pages(layer_cache["k"], tables)
    vg = gather_pages(layer_cache["v"], tables)
    if kv_is_quantized(layer_cache):
        return (dequantize_rows(kg, gather_pages(layer_cache["k_scale"],
                                                 tables), dtype),
                dequantize_rows(vg, gather_pages(layer_cache["v_scale"],
                                                 tables), dtype))
    return kg.astype(dtype), vg.astype(dtype)


def paged_kpos(lengths, length: int):
    """(B, length) logical key positions, -1 past each stream's length.
    Paged layouts are contiguous per stream, so position == row index."""
    idx = jnp.arange(length, dtype=jnp.int32)[None, :]
    return jnp.where(idx < lengths[:, None], idx, -1)


def sdpa_lanes(q, k, v, qpos, kpos, *, window: int = 0, causal: bool = True,
               logits_softcap: float = 0.0, impl: str = "auto"):
    """``sdpa`` with PER-LANE positions: qpos (B, Sq), kpos (B, Sk).

    Batched serving has every lane at its own sequence position, so the
    shared-position ``sdpa`` cannot serve it; each lane runs the same
    single-stream kernel under vmap (identical shapes -> one program).
    """
    lane = functools.partial(sdpa, window=window, causal=causal,
                             logits_softcap=logits_softcap, impl=impl)
    return jax.vmap(lambda q1, k1, v1, qp, kp:
                    lane(q1[None], k1[None], v1[None], qp, kp)[0])(
                        q, k, v, qpos, kpos)


def attn_paged(params, cfg, x, layer_cache, tables, lengths, *,
               window: int = 0, impl: str = "auto"):
    """Paged prefill/decode step: S new tokens per stream, each stream at
    its own position ``lengths[b]``. Returns (out, new_layer_cache)."""
    B, S, _ = x.shape
    positions = lengths[:, None].astype(jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    q, k, v = qkv_proj(params, cfg, x, positions)
    layer_cache = paged_write_kv(layer_cache, k, v, tables, lengths)
    kg, vg = gather_kv_pages(layer_cache, tables, q.dtype)
    kpos = paged_kpos(lengths + S, kg.shape[1])
    out = sdpa_lanes(q, kg, vg, positions, kpos, window=window,
                     logits_softcap=cfg.logits_softcap, impl=impl)
    out = out.reshape(B, S, -1)
    return qmatmul(out, params["wo"]), layer_cache


# ------------------------------------------------------------ tree path

def init_tree_nodes_attn(cfg, batch: int, dtype):
    """Empty node-KV carry for one attention layer (0 rows; levels append)."""
    hd = cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, 0, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, 0, cfg.num_kv_heads, hd), dtype)}


def attn_tree(params, cfg, x, positions, cache_layer, prev_nodes, node_mask,
              base, *, window: int = 0, impl: str = "auto"):
    """Tree-node attention over ``cache + nodes`` WITHOUT cache writes.

    x (B, Tc, d) current tree nodes; positions (Tc,) their absolute RoPE
    positions (siblings share one); prev_nodes {"k","v"} (B, Tp, G, D) node
    K/V from shallower levels (Tp = 0 on the first feed); node_mask
    (Tc, Tp+Tc) ancestor visibility over [prev, current]; ``base`` the
    cache pointer — only rows with stored position in [0, base) are
    COMMITTED tokens.  The strict ``< base`` rule (vs the chain path's
    ``<= qpos``) is load-bearing: tree passes never overwrite stale rows
    before attending, so rows carrying rolled-back future positions must be
    masked by the pointer, not by the query position.

    Returns (out (B,Tc,d_model), nodes) with nodes = prev + current K/V.
    """
    B, S, _ = x.shape
    q, k, v = qkv_proj(params, cfg, x, positions)
    nodes = {"k": jnp.concatenate([prev_nodes["k"].astype(k.dtype), k], axis=1),
             "v": jnp.concatenate([prev_nodes["v"].astype(v.dtype), v], axis=1)}
    kpos = cache_layer["pos"]
    cmask = (kpos[None, :] >= 0) & (kpos[None, :] < base)        # (1, L)
    if window:
        cmask = cmask & ((positions[:, None] - kpos[None, :]) < window)
    cmask = jnp.broadcast_to(cmask, (S, kpos.shape[0]))          # (Tc, L)
    mask = jnp.concatenate([cmask, node_mask], axis=1)           # (Tc, L+Tn)
    kc, vc = cache_kv(cache_layer, q.dtype)
    # gather [cache rows | node rows] before attending: XLA SPMD miscompiles
    # a concatenate whose operand is sharded on the concat dim when the
    # result length is not divisible by the axis (tree verify appends Tn
    # node rows to the L-row cache), so the concat result must be pinned
    # replicated — the tree pass is one fused forward, the all-gather is
    # its natural KV layout anyway
    kk = constrain(jnp.concatenate([kc, nodes["k"]], axis=1))
    vv = constrain(jnp.concatenate([vc, nodes["v"]], axis=1))
    out = explicit_mask_sdpa(q, kk, vv, mask, cfg.logits_softcap)
    return qmatmul(out.reshape(B, S, -1), params["wo"]), nodes


def attn_tree_paged(params, cfg, x, layer_cache, tables, lengths, depths,
                    prev_nodes, node_mask, *, window: int = 0,
                    impl: str = "auto"):
    """Paged tree-node attention: per-stream positions ``lengths[b] +
    depths``, committed-row validity is the paged ``p < lengths`` rule (no
    stale-row hazard — rows past the length are dead by construction).
    Returns (out, nodes) like ``attn_tree``; the pool is NOT written.
    """
    B, S, _ = x.shape
    positions = lengths[:, None].astype(jnp.int32) + depths[None, :]  # (B,Tc)
    q, k, v = qkv_proj(params, cfg, x, positions)
    nodes = {"k": jnp.concatenate([prev_nodes["k"].astype(k.dtype), k], axis=1),
             "v": jnp.concatenate([prev_nodes["v"].astype(v.dtype), v], axis=1)}
    kg, vg = gather_kv_pages(layer_cache, tables, q.dtype)
    kpos = paged_kpos(lengths, kg.shape[1])                      # (B, L)
    cmask = kpos[:, None, :] >= 0                                # (B, 1, L)
    if window:
        cmask = cmask & ((positions[:, :, None] - kpos[:, None, :]) < window)
    cmask = jnp.broadcast_to(cmask, (B, S, kg.shape[1]))
    nmask = jnp.broadcast_to(node_mask[None], (B,) + node_mask.shape)
    mask = jnp.concatenate([cmask, nmask], axis=2)
    # pin [gathered pages | node rows] replicated (see attn_tree: SPMD
    # concat-on-sharded-dim miscompile)
    kk = constrain(jnp.concatenate([kg, nodes["k"]], axis=1))
    vv = constrain(jnp.concatenate([vg, nodes["v"]], axis=1))
    out = explicit_mask_sdpa(q, kk, vv, mask, cfg.logits_softcap)
    return qmatmul(out.reshape(B, S, -1), params["wo"]), nodes


def commit_tree_rows_attn(cache_layer, nodes, path, n_commit, base):
    """Scatter accepted-path node K/V into a DENSE attention cache.

    path (P,) node row indices (padded past ``n_commit``); rows land at
    slots ``base .. base+P-1``; stored positions are ``base+i`` for
    ``i < n_commit`` and ``-1`` (never visible) for the padding rows, so a
    fixed-width write commits a variable-length path.
    """
    P = path.shape[0]
    rows_k = jnp.take(nodes["k"], path, axis=1)
    rows_v = jnp.take(nodes["v"], path, axis=1)
    entries = _kv_entries(cache_layer, rows_k, rows_v)
    out = {key: jax.lax.dynamic_update_slice_in_dim(
               cache_layer[key], val.astype(cache_layer[key].dtype), base, 1)
           for key, val in entries.items()}
    stored = jnp.where(jnp.arange(P) < n_commit,
                       base + jnp.arange(P, dtype=jnp.int32), -1)
    out["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["pos"], stored.astype(jnp.int32), base, 0)
    return out


def commit_tree_rows_paged_attn(layer_cache, nodes, path, tables, lengths):
    """Scatter accepted-path node K/V into the PAGED pool at each stream's
    current length; rows past the engine's subsequent ``lengths + n_commit``
    truncation are dead under the ``p < length`` mask.  Like every paged
    commit, it writes only at positions >= lengths[b] — under prefix
    sharing those blocks are sole-owner by the admission-time COW
    invariant, so the commit stays O(path) and never clones a block."""
    rows_k = jnp.take(nodes["k"], path, axis=1)
    rows_v = jnp.take(nodes["v"], path, axis=1)
    return paged_write_kv(layer_cache, rows_k, rows_v, tables, lengths)


# ------------------------------------------------------- cross-attention

def cross_attn(params, cfg, x, enc, enc_mask=None, impl: str = "auto"):
    """Decoder->encoder attention.

    ``enc`` is either precomputed KV (dict k/v, the decode path) or the raw
    encoder output (B, T, d) from which KV is projected (the train path)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = qmatmul(x, params["wq"]).reshape(B, S, cfg.num_heads, hd)
    if not isinstance(enc, dict):
        enc = encode_cross_kv(params, cfg, enc)
    k, v = enc["k"], enc["v"]
    T = k.shape[1]
    qpos = jnp.zeros((S,), jnp.int32)
    kpos = jnp.zeros((T,), jnp.int32) if enc_mask is None else jnp.where(enc_mask, 0, -1)
    out = sdpa(q, k, v, qpos, kpos, causal=False, impl=impl)
    return qmatmul(out.reshape(B, S, -1), params["wo"])


def encode_cross_kv(params, cfg, enc_out):
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
    v = (enc_out @ params["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
    return {"k": k, "v": v}
