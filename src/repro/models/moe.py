"""Routed mixture-of-experts FFN — group-parallel, capacity-bounded dispatch.

GSPMD/expert-parallel formulation (GShard-style groups, no (T, E, C)
one-hot dispatch tensor and no cross-shard scatter):

  1. tokens stay grouped (G=batch, S, d) with G sharded over the data axes —
     every dispatch step below is LOCAL to a data shard;
  2. router top-k → ids/gates (G, S, K);
  3. position-in-expert via a (G, S, E) cumsum along S (top-k ids are
     distinct within a token, so no within-token correction is needed);
  4. batched scatter into a per-group buffer (G, E, C, d), C = S*K*cf/E
     (tokens over per-group capacity are dropped — GShard semantics);
  5. expert einsum over the E axis; expert weights are sharded
     ("model", FSDP) so the E dimension is consumed model-parallel;
  6. local gather back + gate-weighted combine.

Shared experts (DeepSeek) are a plain dense FFN added to the routed output.
Aux: Switch load-balance loss, router z-loss, drop fraction.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import activation_fn, dense_init
from .sharding import constrain


def init_moe(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    glu = cfg.activation in ("swiglu", "geglu")

    def expert_bank(k, n, dff):
        kk = jax.random.split(k, 3)
        p = {
            "w_in": jax.vmap(lambda q: dense_init(q, d, dff, dtype))(jax.random.split(kk[0], n)),
            "w_out": jax.vmap(lambda q: dense_init(q, dff, d, dtype))(jax.random.split(kk[1], n)),
        }
        if glu:
            p["w_gate"] = jax.vmap(lambda q: dense_init(q, d, dff, dtype))(jax.random.split(kk[2], n))
        return p

    p = {"router": dense_init(ks[0], d, m.num_experts, dtype),
         "experts": expert_bank(ks[1], m.num_experts, m.d_expert)}
    if m.num_shared_experts:
        dsh = (m.d_shared or m.d_expert) * m.num_shared_experts
        kk = jax.random.split(ks[2], 3)
        sh = {"w_in": dense_init(kk[0], d, dsh, dtype),
              "w_out": dense_init(kk[1], dsh, d, dtype)}
        if glu:
            sh["w_gate"] = dense_init(kk[2], d, dsh, dtype)
        p["shared"] = sh
    return p


def _ffn_apply(p, x, act):
    h = x @ p["w_in"]
    if "w_gate" in p:
        h = act(h) * (x @ p["w_gate"])
    else:
        h = act(h)
    return h @ p["w_out"]


def _moe_decode_gather(params, cfg, x, gates, ids, act):
    """x (G,S,d); gates/ids (G,S,K). Gathers (G*S*K) expert weight rows."""
    m = cfg.moe
    G, S, d = x.shape
    K = m.top_k
    flat_ids = ids.reshape(-1)                                   # (T*K,)
    w_in = params["experts"]["w_in"][flat_ids]                   # (T*K,d,f)
    w_out = params["experts"]["w_out"][flat_ids]                 # (T*K,f,d)
    xt = jnp.repeat(x.reshape(-1, d), K, axis=0)                 # (T*K,d)
    h = jnp.einsum("td,tdf->tf", xt, w_in)
    if "w_gate" in params["experts"]:
        w_g = params["experts"]["w_gate"][flat_ids]
        h = act(h) * jnp.einsum("td,tdf->tf", xt, w_g)
    else:
        h = act(h)
    yt = jnp.einsum("tf,tfd->td", h, w_out)                      # (T*K,d)
    yt = yt.reshape(G, S, K, d) * gates.astype(yt.dtype)[..., None]
    return yt.sum(axis=2) * jnp.asarray(m.routed_scale, x.dtype)


def moe_ffn(params, cfg, x, *, capacity_factor: float = None
            ) -> Tuple[jnp.ndarray, dict]:
    """x: (G, S, d) — G is the (data-sharded) group/batch axis.
    Returns (y (G,S,d), aux dict)."""
    m = cfg.moe
    act = activation_fn(cfg.activation)
    G, S, d = x.shape
    E, K = m.num_experts, m.top_k
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    C = max(1, min(int(S * K * cf / E + 0.999), S * K))

    logits = (x @ params["router"]).astype(jnp.float32)            # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)                           # (G,S,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    if m.decode_gather and G * S * K < E:
        # tiny-batch decode: gather ONLY the active experts' weights instead
        # of streaming the full expert bank through the dispatch einsum —
        # at batch 1 that is the difference between reading N_total and
        # N_active parameters per token (§Perf long_500k iteration)
        y = _moe_decode_gather(params, cfg, x, gates, ids, act)
        if "shared" in params:
            y = y + _ffn_apply(params["shared"], x, act)
        hit = jax.nn.one_hot(ids, E, dtype=jnp.int32).sum((1, 2))     # (G,E)
        aux = {"moe_aux_loss": jnp.zeros(()), "moe_z_loss": jnp.zeros(()),
               "moe_drop_frac": jnp.zeros(()),
               "moe_experts_hit": (hit > 0).sum(-1).astype(jnp.float32)}
        return y, aux

    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int8).sum(2)         # (G,S,E)
    pos_base = (jnp.cumsum(onehot.astype(jnp.int32), axis=1)
                - onehot.astype(jnp.int32))                        # (G,S,E)
    pos = jnp.take_along_axis(pos_base, ids.astype(jnp.int32), axis=2)  # (G,S,K)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C - 1).astype(jnp.int32)

    # local batched scatter into (G, E, C, d)
    e_f = ids.reshape(G, S * K).astype(jnp.int32)
    p_f = pos_c.reshape(G, S * K)
    upd = jnp.repeat(x, K, axis=1) * keep.reshape(G, S * K, 1).astype(x.dtype)
    g_ix = jnp.arange(G, dtype=jnp.int32)[:, None]
    buf = jnp.zeros((G, E, C, d), x.dtype).at[g_ix, e_f, p_f].add(upd)
    buf = constrain(buf, ("pod", "data"), None, None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, params["experts"]["w_in"])
    if "w_gate" in params["experts"]:
        h = act(h) * jnp.einsum("gecd,edf->gecf", buf, params["experts"]["w_gate"])
    else:
        h = act(h)
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["experts"]["w_out"])
    out_buf = constrain(out_buf, ("pod", "data"), None, None, None)

    gathered = out_buf[g_ix, e_f, p_f]                              # (G,S*K,d)
    gathered = gathered.reshape(G, S, K, d)
    gathered = gathered * (gates * keep).astype(gathered.dtype)[..., None]
    y = gathered.sum(axis=2) * jnp.asarray(m.routed_scale, x.dtype)

    if "shared" in params:
        y = y + _ffn_apply(params["shared"], x, act)

    me = probs.mean((0, 1))                                         # (E,)
    ce = onehot.astype(jnp.float32).mean((0, 1)) / K                # frac of assignments
    aux = {
        "moe_aux_loss": m.router_aux_weight * E * jnp.sum(me * ce),
        "moe_z_loss": 1e-3 * jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
        "moe_drop_frac": 1.0 - keep.astype(jnp.float32).mean(),
        # distinct experts activated per group over the S tokens of this
        # call — the serving tick's routing-density signal: a multi-token
        # verify streams experts_hit/E of the routed bank (vs top_k/E for
        # one decode token), which core/rewards.py turns into the
        # routing-density term of the modeled session cost
        "moe_experts_hit": (onehot > 0).any(axis=1).sum(-1).astype(jnp.float32),
    }
    return y, aux
