"""Config-driven transformer family: init + train/prefill/decode passes.

Production details:
  * scan-over-layers: homogeneous layer cycles are stacked and driven by
    ``lax.scan`` (small HLO, fast compile at 94-layer scale); heterogeneous
    prefix/tail layers run as plain Python loops.
  * remat: each scanned cycle is wrapped in ``jax.checkpoint`` for training.
  * the same ``step`` function serves prefill (S tokens), speculative
    verification (S = gamma+1, returns all logits) and decode (S = 1).
  * enc-dec (audio) and VLM wrappers are integrated: stub frontends provide
    precomputed frame/patch embeddings (DESIGN.md carve-out), a learned
    projector maps them into the decoder's embedding space.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (encode_cross_kv, init_attention, attn_train,
                        cross_attn, commit_tree_rows_attn,
                        commit_tree_rows_paged_attn, init_tree_nodes_attn)
from .blocks import (block_cached, block_paged, block_train, block_tree,
                     ffn_apply, init_block, init_ffn)
from .mla import (commit_tree_rows_mla, commit_tree_rows_paged_mla,
                  init_tree_nodes_mla)
from .cache import (CacheSpec, LayerCacheSpec, build_cache_spec,
                    build_paged_cache_spec, init_layer_cache,
                    init_paged_layer_cache)
from .common import dense_init, embed_init, rms_norm, softcap
from .config import ModelConfig
from .sharding import constrain


# ------------------------------------------------------------ grouping

@dataclass(frozen=True)
class LayerGrouping:
    prefix: Tuple[int, ...]
    scan_start: int
    n_cycles: int
    period: int
    tail: Tuple[int, ...]


def layer_grouping(cfg: ModelConfig) -> LayerGrouping:
    P = len(cfg.block_pattern)
    start = 0
    if cfg.moe is not None and cfg.moe.dense_layers:
        start = max(cfg.moe.dense_layers) + 1
    n_cycles = max((cfg.num_layers - start) // P, 0)
    if n_cycles < 2 or not cfg.scan_layers:   # unrolled
        return LayerGrouping(tuple(range(cfg.num_layers)), cfg.num_layers, 0, P, ())
    tail_start = start + n_cycles * P
    return LayerGrouping(tuple(range(start)), start, n_cycles, P,
                         tuple(range(tail_start, cfg.num_layers)))


# ------------------------------------------------------------ init

def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    g = layer_grouping(cfg)
    keys = jax.random.split(key, 8)
    cross = cfg.is_encdec
    p: dict = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
               "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)

    lkeys = jax.random.split(keys[2], cfg.num_layers)
    layers = {"prefix": [init_block(lkeys[i], cfg, i, cross=cross, dtype=dtype)
                         for i in g.prefix],
              "tail": [init_block(lkeys[i], cfg, i, cross=cross, dtype=dtype)
                       for i in g.tail]}
    if g.n_cycles:
        def init_cycle(ck):
            cks = jax.random.split(ck, g.period)
            return {str(j): init_block(cks[j], cfg, g.scan_start + j,
                                       cross=cross, dtype=dtype)
                    for j in range(g.period)}
        layers["stack"] = jax.vmap(init_cycle)(
            jax.random.split(keys[3], g.n_cycles))
    else:
        layers["stack"] = None
    p["layers"] = layers

    if cfg.is_encdec:
        e = cfg.encdec
        ekeys = jax.random.split(keys[4], e.num_encoder_layers + 1)
        p["enc_proj"] = dense_init(ekeys[0], e.frontend_dim, cfg.d_model, dtype)
        enc_cfg = cfg.replace(block_pattern=("attn",), moe=None)
        p["encoder"] = {
            "layers": [init_block(ekeys[i + 1], enc_cfg, i, dtype=dtype)
                       for i in range(e.num_encoder_layers)],
            "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.vision is not None:
        v = cfg.vision
        h = v.projector_hidden or v.vit_dim * 4
        vk = jax.random.split(keys[5], 2)
        p["vis_proj"] = {"w1": dense_init(vk[0], v.vit_dim, h, dtype),
                         "w2": dense_init(vk[1], h, cfg.d_model, dtype)}
    return p


# ------------------------------------------------------------ embed/head

def embed_tokens(params, cfg, tokens):
    x = params["embed"][tokens]
    return x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)


def project_vision(params, patch_embeds):
    h = jax.nn.gelu(patch_embeds @ params["vis_proj"]["w1"])
    return h @ params["vis_proj"]["w2"]


def logits_fn(params, cfg, hidden):
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = hidden @ w
    logits = constrain(logits, ("pod", "data"), None, "model")
    return softcap(logits, cfg.logits_softcap)


# ------------------------------------------------------------ encoder

def encode(params, cfg, frame_embeds, impl: str = "auto"):
    """Audio/enc-dec encoder over stub frontend embeddings (B, T, F)."""
    x = frame_embeds @ params["enc_proj"]
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    enc_cfg = cfg.replace(block_pattern=("attn",), moe=None)
    causal = cfg.encdec.encoder_is_causal
    for i, lp in enumerate(params["encoder"]["layers"]):
        h = rms_norm(x, lp["norm1"], cfg.rms_eps)
        h = attn_train(lp["mixer"], enc_cfg, h, positions, causal=causal, impl=impl)
        x = x + h
        h = rms_norm(x, lp["norm2"], cfg.rms_eps)
        x = x + ffn_apply(lp["ffn"], enc_cfg, h)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.rms_eps)


# ------------------------------------------------------------ train pass

def forward_hidden(params, cfg: ModelConfig, tokens, *, patch_embeds=None,
                   frame_embeds=None, impl: str = "auto", remat: bool = True):
    """Full-sequence causal pass. Returns (hidden (B,S',d), aux_loss scalar).

    S' = S (+ num_patches for VLM). Loss masking over patch positions is the
    caller's job (``training.losses``)."""
    g = layer_grouping(cfg)
    x = embed_tokens(params, cfg, tokens)
    if patch_embeds is not None:
        x = jnp.concatenate([project_vision(params, patch_embeds).astype(x.dtype), x], axis=1)
    x = constrain(x, ("pod", "data"), None, None)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_out = encode(params, cfg, frame_embeds, impl) if frame_embeds is not None else None

    aux_total = jnp.zeros((), jnp.float32)
    seq_spec = ("model",) if cfg.seq_shard_activations else (None,)

    def run_block(lp, idx, x):
        # residual stream sequence-sharded between blocks (Megatron-SP style):
        # the remat-saved per-layer input shrinks by the model-axis size.
        x = constrain(x, ("pod", "data"), *seq_spec)
        x, aux = block_train(lp, cfg, idx, x, positions, enc_out=enc_out, impl=impl)
        a = sum(v for k, v in aux.items() if k.endswith("loss"))
        return x, jnp.asarray(a, jnp.float32)

    for i, lp in zip(g.prefix, params["layers"]["prefix"]):
        x, a = run_block(lp, i, x)
        aux_total += a

    if g.n_cycles:
        def cycle(x, cp):
            a_c = jnp.zeros((), jnp.float32)
            for j in range(g.period):
                x, a = run_block(cp[str(j)], g.scan_start + j, x)
                a_c += a
            return x, a_c
        body = jax.checkpoint(cycle) if remat else cycle
        x, a_cyc = jax.lax.scan(body, x, params["layers"]["stack"])
        aux_total += a_cyc.sum()

    for i, lp in zip(g.tail, params["layers"]["tail"]):
        x, a = run_block(lp, i, x)
        aux_total += a

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, aux_total


# ------------------------------------------------------------ cached step

def _n_moe_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.num_layers)
               if cfg.is_moe_layer(i) and cfg.block_kind(i) != "mamba2")


def _kv_quant(kv_dtype: Optional[str]) -> bool:
    if kv_dtype in (None, "fp", "bf16", "fp32"):
        return False
    if kv_dtype == "int8":
        return True
    raise ValueError(f"kv_dtype must be None/'fp'/'int8', got {kv_dtype!r}")

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               kv_dtype: Optional[str] = None):
    """Dense decode cache.  ``kv_dtype``: None/"fp" store K/V in ``dtype``;
    "int8" stores attention/MLA payloads as int8 with per-row float32
    scales (``models/quant.py``); recurrent state always keeps ``dtype``."""
    kv_quant = _kv_quant(kv_dtype)
    spec = build_cache_spec(cfg, max_len, kv_quant=kv_quant)
    g = layer_grouping(cfg)

    def mk(i):
        return init_layer_cache(cfg, spec.layers[i], batch, dtype,
                                kv_quant=kv_quant)

    layers = {"prefix": [mk(i) for i in g.prefix],
              "tail": [mk(i) for i in g.tail],
              "stack": None}
    if g.n_cycles:
        one_cycle = {str(j): mk(g.scan_start + j) for j in range(g.period)}
        layers["stack"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (g.n_cycles,) + a.shape), one_cycle)
    cache = {"pos": jnp.zeros((), jnp.int32), "layers": layers}
    if cfg.is_encdec:
        cache["cross"] = None  # filled by prefill(enc_out=...)
    if _n_moe_layers(cfg):
        # routing-density channel: mean distinct-experts-hit per stream over
        # the routed layers of the LAST step call.  Present from init so the
        # cache pytree structure is stable under while_loop/scan carries.
        cache["moe_stats"] = jnp.zeros((batch,), jnp.float32)
    return cache, spec


def _init_cross(params, cfg, enc_out):
    g = layer_grouping(cfg)
    cross = {"prefix": [encode_cross_kv(params["layers"]["prefix"][k]["cross"], cfg, enc_out)
                        for k in range(len(g.prefix))],
             "tail": [encode_cross_kv(params["layers"]["tail"][k]["cross"], cfg, enc_out)
                      for k in range(len(g.tail))],
             "stack": None}
    if g.n_cycles:
        cross["stack"] = jax.vmap(
            lambda cp: {str(j): encode_cross_kv(cp[str(j)]["cross"], cfg, enc_out)
                        for j in range(g.period)}
        )(params["layers"]["stack"])
    return cross


def step(params, cfg: ModelConfig, tokens, cache, spec: CacheSpec, *,
         patch_embeds=None, frame_embeds=None, all_logits: bool = False,
         impl: str = "auto", remat: bool = False):
    """Advance the model by S tokens against the cache.

    Serves prefill (S large), speculative verification (S = gamma+1,
    ``all_logits=True``) and decode (S = 1).
    Returns (logits, new_cache): logits (B,S,V) if all_logits else (B,1,V).
    """
    g = layer_grouping(cfg)
    pos0 = cache["pos"]
    x = embed_tokens(params, cfg, tokens)
    if patch_embeds is not None:
        x = jnp.concatenate([project_vision(params, patch_embeds).astype(x.dtype), x], axis=1)
    x = constrain(x, ("pod", "data"), None, None)

    if frame_embeds is not None:
        enc_out = encode(params, cfg, frame_embeds, impl)
        cache = {**cache, "cross": _init_cross(params, cfg, enc_out)}
    cross = cache.get("cross")

    layers = cache["layers"]
    new_layers = {"prefix": [], "tail": [], "stack": None}
    want_moe = "moe_stats" in cache
    moe_acc = jnp.zeros((x.shape[0],), jnp.float32) if want_moe else None

    for k, i in enumerate(g.prefix):
        st = {} if want_moe else None
        x, lc = block_cached(params["layers"]["prefix"][k], cfg, i, x, pos0,
                             layers["prefix"][k], spec.layers[i],
                             cross_kv=None if cross is None else cross["prefix"][k],
                             moe_stats=st, impl=impl)
        new_layers["prefix"].append(lc)
        if st:
            moe_acc = moe_acc + st["experts_hit"]

    if g.n_cycles:
        def cycle(carry, xs):
            x, acc = carry
            if cross is not None:
                cp, cc, cx = xs
            else:
                (cp, cc), cx = xs, None
            new_cc = {}
            for j in range(g.period):
                idx = g.scan_start + j
                st = {} if acc is not None else None
                x, lc = block_cached(cp[str(j)], cfg, idx, x, pos0, cc[str(j)],
                                     spec.layers[idx],
                                     cross_kv=None if cx is None else cx[str(j)],
                                     moe_stats=st, impl=impl)
                new_cc[str(j)] = lc
                if st:
                    acc = acc + st["experts_hit"]
            return (x, acc), new_cc
        body = jax.checkpoint(cycle) if remat else cycle
        xs = ((params["layers"]["stack"], layers["stack"], cross["stack"])
              if cross is not None else
              (params["layers"]["stack"], layers["stack"]))
        (x, moe_acc), new_stack = jax.lax.scan(body, (x, moe_acc), xs)
        new_layers["stack"] = new_stack

    for k, i in enumerate(g.tail):
        st = {} if want_moe else None
        x, lc = block_cached(params["layers"]["tail"][k], cfg, i, x, pos0,
                             layers["tail"][k], spec.layers[i],
                             cross_kv=None if cross is None else cross["tail"][k],
                             moe_stats=st, impl=impl)
        new_layers["tail"].append(lc)
        if st:
            moe_acc = moe_acc + st["experts_hit"]

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if not all_logits:
        x = x[:, -1:]
    logits = logits_fn(params, cfg, x)
    S_new = tokens.shape[1] + (0 if patch_embeds is None else patch_embeds.shape[1])
    new_cache = {**cache, "pos": pos0 + S_new, "layers": new_layers}
    if want_moe:
        new_cache["moe_stats"] = (
            moe_acc / max(_n_moe_layers(cfg), 1)
        ).reshape(cache["moe_stats"].shape)
    return logits, new_cache


# ------------------------------------------------------------ paged step

def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     block_size: int = 64, pool_tokens: Optional[int] = None,
                     dtype=jnp.bfloat16, kv_dtype: Optional[str] = None,
                     enc_segments: Optional[int] = None):
    """Paged decode cache: one global block pool per attention layer plus
    per-stream (tables, lengths). Recurrent layers keep (B, ...) state.
    ``pool_tokens`` defaults to ``batch * max_len`` — the dense engine's
    capacity — so the refactor is drop-in; serving passes less to decouple
    memory from worst-case per-slot buffers.  ``kv_dtype="int8"`` stores
    the pools quantized (per-row scales ride sibling pools), roughly
    doubling the tokens a byte budget can back.

    Enc-dec targets add SHARED ENCODER SEGMENT POOLS: per cross-attention
    layer a (n_segments, frontend_len, G, hd) K/V pool plus a per-stream
    ``cross_seg`` segment index.  Segment 0 is the reserved NULL segment
    (all-zero K/V — zero V makes cross attention an exact no-op for
    unconditioned lanes), so one encoded input shared by N lanes costs one
    segment, refcounted host-side by ``models.cache.EncoderSegmentPool``.
    ``enc_segments`` sizes the pool (default: one per lane + the null)."""
    spec = build_paged_cache_spec(cfg, max_len, block_size=block_size,
                                  pool_tokens=pool_tokens or batch * max_len,
                                  kv_quant=_kv_quant(kv_dtype))
    g = layer_grouping(cfg)

    def mk(i):
        return init_paged_layer_cache(cfg, spec.layers[i], spec, batch, dtype)

    layers = {"prefix": [mk(i) for i in g.prefix],
              "tail": [mk(i) for i in g.tail],
              "stack": None}
    if g.n_cycles:
        one_cycle = {str(j): mk(g.scan_start + j) for j in range(g.period)}
        layers["stack"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (g.n_cycles,) + a.shape), one_cycle)
    cache = {"lengths": jnp.zeros((batch,), jnp.int32),
             "tables": jnp.zeros((batch, spec.max_blocks), jnp.int32),
             "layers": layers}
    if cfg.is_encdec:
        nseg = enc_segments or batch + 1
        tf = cfg.encdec.frontend_len
        kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

        def seg_kv(lead=()):
            z = jnp.zeros(lead + (nseg, tf, kvh, hd), dtype)
            return {"k": z, "v": z}

        cross = {"prefix": [seg_kv() for _ in g.prefix],
                 "tail": [seg_kv() for _ in g.tail],
                 "stack": None}
        if g.n_cycles:
            cross["stack"] = {str(j): seg_kv((g.n_cycles,))
                              for j in range(g.period)}
        cache["cross"] = cross
        cache["cross_seg"] = jnp.zeros((batch,), jnp.int32)
    if _n_moe_layers(cfg):
        cache["moe_stats"] = jnp.zeros((batch,), jnp.float32)
    return cache, spec


def paged_step(params, cfg: ModelConfig, tokens, cache, spec: CacheSpec, *,
               patch_embeds=None, all_logits: bool = False,
               impl: str = "auto"):
    """Advance B independent streams by S tokens against the paged cache.

    Unlike ``step`` (one shared ``pos`` scalar) every stream writes at its
    own ``lengths[b]`` and attends through its own block-table row, so ONE
    jitted program serves lanes at arbitrary sequence positions — and the
    pool is shared, which a vmap-of-single-stream formulation cannot express
    (per-lane writes to one buffer do not compose under vmap).

    Conditioning: ``patch_embeds`` (B, P, vit_dim) are projected and
    PREPENDED to the token chunk (positions = the lanes' current lengths),
    mirroring the dense ``step``; enc-dec caches carry shared encoder
    segment pools — each lane's ``cross_seg`` row is gathered into a
    per-lane cross-KV once per call, so conditioning rides entirely inside
    the (opaque) cache and every jitted session works unchanged.
    Returns (logits, new_cache); new_cache has ``lengths + S``.
    """
    assert spec.paged
    g = layer_grouping(cfg)
    lengths, tables = cache["lengths"], cache["tables"]
    x = embed_tokens(params, cfg, tokens)
    if patch_embeds is not None:
        x = jnp.concatenate(
            [project_vision(params, patch_embeds).astype(x.dtype), x], axis=1)
    x = constrain(x, ("pod", "data"), None, None)

    cross = None
    if cache.get("cross") is not None:
        seg = cache["cross_seg"]
        cp = cache["cross"]
        cross = {"prefix": [jax.tree.map(lambda a: a[seg], c)
                            for c in cp["prefix"]],
                 "tail": [jax.tree.map(lambda a: a[seg], c)
                          for c in cp["tail"]],
                 "stack": None if cp["stack"] is None else
                 jax.tree.map(lambda a: a[:, seg], cp["stack"])}

    layers = cache["layers"]
    new_layers = {"prefix": [], "tail": [], "stack": None}
    want_moe = "moe_stats" in cache
    moe_acc = jnp.zeros((x.shape[0],), jnp.float32) if want_moe else None

    for k, i in enumerate(g.prefix):
        st = {} if want_moe else None
        x, lc = block_paged(params["layers"]["prefix"][k], cfg, i, x,
                            layers["prefix"][k], tables, lengths,
                            spec.layers[i],
                            cross_kv=None if cross is None else cross["prefix"][k],
                            moe_stats=st, impl=impl)
        new_layers["prefix"].append(lc)
        if st:
            moe_acc = moe_acc + st["experts_hit"]

    if g.n_cycles:
        def cycle(carry, xs):
            x, acc = carry
            if cross is not None:
                cp_, cc, cx = xs
            else:
                (cp_, cc), cx = xs, None
            new_cc = {}
            for j in range(g.period):
                idx = g.scan_start + j
                st = {} if acc is not None else None
                x, lc = block_paged(cp_[str(j)], cfg, idx, x, cc[str(j)],
                                    tables, lengths, spec.layers[idx],
                                    cross_kv=None if cx is None else cx[str(j)],
                                    moe_stats=st, impl=impl)
                new_cc[str(j)] = lc
                if st:
                    acc = acc + st["experts_hit"]
            return (x, acc), new_cc
        xs = ((params["layers"]["stack"], layers["stack"], cross["stack"])
              if cross is not None else
              (params["layers"]["stack"], layers["stack"]))
        (x, moe_acc), new_stack = jax.lax.scan(cycle, (x, moe_acc), xs)
        new_layers["stack"] = new_stack

    for k, i in enumerate(g.tail):
        st = {} if want_moe else None
        x, lc = block_paged(params["layers"]["tail"][k], cfg, i, x,
                            layers["tail"][k], tables, lengths,
                            spec.layers[i],
                            cross_kv=None if cross is None else cross["tail"][k],
                            moe_stats=st, impl=impl)
        new_layers["tail"].append(lc)
        if st:
            moe_acc = moe_acc + st["experts_hit"]

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if not all_logits:
        x = x[:, -1:]
    logits = logits_fn(params, cfg, x)
    S_new = (tokens.shape[1]
             + (0 if patch_embeds is None else patch_embeds.shape[1]))
    new_cache = {**cache, "lengths": lengths + S_new, "layers": new_layers}
    if want_moe:
        new_cache["moe_stats"] = (
            moe_acc / max(_n_moe_layers(cfg), 1)
        ).reshape(cache["moe_stats"].shape)
    return logits, new_cache


def encode_cross_segment(params, cfg: ModelConfig, frame_embeds,
                         impl: str = "auto"):
    """Run the encoder over ONE input's frame embeddings (1, T, F) and
    return the per-layer cross-KV pytree (leaves (1, T, G, hd); scanned
    cycles carry a leading n_cycles axis) — the payload
    ``write_cross_segment`` lands in a shared segment pool."""
    enc_out = encode(params, cfg, frame_embeds, impl)
    return _init_cross(params, cfg, enc_out)


def write_cross_segment(cache, cross_lane, seg):
    """Scatter one encoded input's cross-KV into the paged cache's shared
    segment pools at segment index ``seg`` (written once, then immutable
    and shared by every lane whose ``cross_seg`` points at it)."""
    pool = cache["cross"]

    def put(p, n):
        return p.at[seg].set(n[0].astype(p.dtype))

    def put_stack(p, n):
        return p.at[:, seg].set(n[:, 0].astype(p.dtype))

    new = {"prefix": [jax.tree.map(put, p, n)
                      for p, n in zip(pool["prefix"], cross_lane["prefix"])],
           "tail": [jax.tree.map(put, p, n)
                    for p, n in zip(pool["tail"], cross_lane["tail"])],
           "stack": None if pool["stack"] is None else
           jax.tree.map(put_stack, pool["stack"], cross_lane["stack"])}
    return {**cache, "cross": new}


# ------------------------------------------------------------ tree step

def init_tree_nodes(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Empty node-KV carry pytree (mirrors the cache's layer structure with
    0 node rows per attention/MLA layer); ``tree_step`` appends each fed
    level's K/V so deeper levels can attend their ancestors without the
    cache ever holding uncommitted rows."""
    g = layer_grouping(cfg)

    def mk(i):
        kind = cfg.block_kind(i)
        if kind in ("attn", "local"):
            return init_tree_nodes_attn(cfg, batch, dtype)
        if kind == "mla":
            return init_tree_nodes_mla(cfg, batch, dtype)
        raise ValueError(f"tree speculation requires attn/mla stacks, got {kind}")

    nodes = {"prefix": [mk(i) for i in g.prefix],
             "tail": [mk(i) for i in g.tail],
             "stack": None}
    if g.n_cycles:
        one = {str(j): mk(g.scan_start + j) for j in range(g.period)}
        nodes["stack"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (g.n_cycles,) + a.shape), one)
    return nodes


def tree_step(params, cfg: ModelConfig, tokens, cache, spec: CacheSpec,
              depths, node_mask, nodes, *, impl: str = "auto"):
    """Forward Tc tree nodes against the cache WITHOUT advancing it.

    tokens (B, Tc) node tokens; depths (Tc,) int32 position offsets from
    the cache pointer (node position = pointer + depth; siblings share
    one); node_mask (Tc, Tp+Tc) ancestor visibility over [carried nodes,
    current nodes]; nodes = the carry from ``init_tree_nodes`` / a previous
    level.  Cache rows are visible iff committed (dense: stored position
    < pointer; paged: row < lengths[b]).  Works on dense AND paged caches
    (one shared block path, dispatched on ``spec.paged``).

    Returns (logits (B, Tc, V), new_nodes with Tp+Tc rows).  The caller
    commits the accepted path afterwards with ``commit_tree_path``.
    """
    g = layer_grouping(cfg)
    x = embed_tokens(params, cfg, tokens)
    x = constrain(x, ("pod", "data"), None, None)
    depths = jnp.asarray(depths, jnp.int32)
    node_mask = jnp.asarray(node_mask, bool)
    if spec.paged:
        kw = dict(tables=cache["tables"], lengths=cache["lengths"],
                  depths=depths)
    else:
        kw = dict(pos0=cache["pos"], depths=depths)

    layers = cache["layers"]
    new_nodes = {"prefix": [], "tail": [], "stack": None}

    for k, i in enumerate(g.prefix):
        x, nn = block_tree(params["layers"]["prefix"][k], cfg, i, x,
                           layers["prefix"][k], nodes["prefix"][k], node_mask,
                           spec.layers[i], impl=impl, **kw)
        new_nodes["prefix"].append(nn)

    if g.n_cycles:
        def cycle(x, xs):
            cp, cc, pn = xs
            nns = {}
            for j in range(g.period):
                idx = g.scan_start + j
                x, nn = block_tree(cp[str(j)], cfg, idx, x, cc[str(j)],
                                   pn[str(j)], node_mask, spec.layers[idx],
                                   impl=impl, **kw)
                nns[str(j)] = nn
            return x, nns
        x, new_stack = jax.lax.scan(
            cycle, x, (params["layers"]["stack"], layers["stack"],
                       nodes["stack"]))
        new_nodes["stack"] = new_stack

    for k, i in enumerate(g.tail):
        x, nn = block_tree(params["layers"]["tail"][k], cfg, i, x,
                           layers["tail"][k], nodes["tail"][k], node_mask,
                           spec.layers[i], impl=impl, **kw)
        new_nodes["tail"].append(nn)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, cfg, x), new_nodes


def commit_tree_path(cfg: ModelConfig, cache, spec: CacheSpec, nodes, path,
                     n_commit):
    """Scatter ONLY the accepted path into the cache.

    path (P,) int32 node-row indices into the carry (padded arbitrarily
    past ``n_commit``); n_commit the number of real rows.  Dense: P rows
    land at the pointer, padding rows carry stored position -1 (never
    visible) — the caller then advances the pointer by n_commit.  Paged:
    rows land at each stream's current length via ``paged_write`` — the
    caller truncates lengths to ``+ n_commit`` and rows past that are dead
    under the ``p < length`` rule.  Either way rollback stays the existing
    O(1) pointer / length truncation.
    """
    g = layer_grouping(cfg)
    path = jnp.asarray(path, jnp.int32)
    n_commit = jnp.asarray(n_commit, jnp.int32)

    def commit_layer(i, lc, nn):
        kind = cfg.block_kind(i)
        if spec.paged:
            if kind in ("attn", "local"):
                return commit_tree_rows_paged_attn(
                    lc, nn, path, cache["tables"], cache["lengths"])
            if kind == "mla":
                return commit_tree_rows_paged_mla(
                    lc, nn, path, cache["tables"], cache["lengths"])
        else:
            if kind in ("attn", "local"):
                return commit_tree_rows_attn(lc, nn, path, n_commit,
                                             cache["pos"])
            if kind == "mla":
                return commit_tree_rows_mla(lc, nn, path, n_commit,
                                            cache["pos"])
        raise ValueError(kind)

    layers = cache["layers"]
    new_layers = {
        "prefix": [commit_layer(i, layers["prefix"][k], nodes["prefix"][k])
                   for k, i in enumerate(g.prefix)],
        "tail": [commit_layer(i, layers["tail"][k], nodes["tail"][k])
                 for k, i in enumerate(g.tail)],
        "stack": None}
    if g.n_cycles:
        def cyc(cc, nn):
            return {str(j): commit_layer(g.scan_start + j, cc[str(j)],
                                         nn[str(j)])
                    for j in range(g.period)}
        new_layers["stack"] = jax.vmap(cyc)(layers["stack"], nodes["stack"])
    return {**cache, "layers": new_layers}


# ------------------------------------------------------------ confidence API

def prefill(params, cfg, tokens, cache, spec, **kw):
    return step(params, cfg, tokens, cache, spec, all_logits=False, **kw)


def decode_step(params, cfg, token, cache, spec, **kw):
    assert token.shape[1] == 1
    return step(params, cfg, token, cache, spec, all_logits=False, **kw)


def verify_chunk(params, cfg, tokens, cache, spec, **kw):
    return step(params, cfg, tokens, cache, spec, all_logits=True, **kw)
