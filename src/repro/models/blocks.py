"""Per-layer block: pre-norm mixer + pre-norm FFN/MoE with residuals."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attn_cached, attn_paged, attn_train, attn_tree,
                        attn_tree_paged, cross_attn, encode_cross_kv,
                        init_attention)
from .common import activation_fn, dense_init, rms_norm
from .mla import (init_mla, mla_cached, mla_paged, mla_train, mla_tree,
                  mla_tree_paged)
from .moe import init_moe, moe_ffn
from .quant import qmatmul
from .rglru import init_rglru, rglru_mixer
from .sharding import constrain
from .ssm import init_ssm, ssm_mixer


def init_ffn(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
         "w_out": dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype)}
    if cfg.activation in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def ffn_apply(params, cfg, x):
    act = activation_fn(cfg.activation)
    h = qmatmul(x, params["w_in"])
    if "w_gate" in params:
        h = act(h) * qmatmul(x, params["w_gate"])
    else:
        h = act(h)
    h = constrain(h, ("pod", "data"), None, "model")
    return qmatmul(h, params["w_out"])


def init_block(key, cfg, layer_idx: int, *, cross: bool = False,
               dtype=jnp.float32):
    kind = cfg.block_kind(layer_idx)
    ks = jax.random.split(key, 4)
    p = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if kind in ("attn", "local"):
        p["mixer"] = init_attention(ks[0], cfg, dtype=dtype)
    elif kind == "mla":
        p["mixer"] = init_mla(ks[0], cfg, dtype=dtype)
    elif kind == "mamba2":
        p["mixer"] = init_ssm(ks[0], cfg, dtype=dtype)
    elif kind == "rglru":
        p["mixer"] = init_rglru(ks[0], cfg, dtype=dtype)
    else:
        raise ValueError(kind)
    has_ffn = kind != "mamba2"
    if has_ffn:
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        if cfg.is_moe_layer(layer_idx):
            p["ffn"] = init_moe(ks[1], cfg, dtype=dtype)
        else:
            p["ffn"] = init_ffn(ks[1], cfg, dtype=dtype)
    if cross:
        p["cross_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = init_attention(ks[2], cfg, cross=True, dtype=dtype)
    return p


def block_train(params, cfg, layer_idx: int, x, positions, *, enc_out=None,
                impl: str = "auto"):
    """Full-sequence pass (no cache). Returns (x, aux)."""
    kind = cfg.block_kind(layer_idx)
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    aux = {}
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        h = attn_train(params["mixer"], cfg, h, positions, window=window, impl=impl)
    elif kind == "mla":
        h = mla_train(params["mixer"], cfg, h, positions, impl=impl)
    elif kind == "mamba2":
        h, _ = ssm_mixer(params["mixer"], cfg, h)
    elif kind == "rglru":
        h, _ = rglru_mixer(params["mixer"], cfg, h)
    x = x + h
    if "cross" in params and enc_out is not None:
        h = rms_norm(x, params["cross_norm"], cfg.rms_eps)
        x = x + cross_attn(params["cross"], cfg, h, enc_out)
    if "ffn" in params:
        h = rms_norm(x, params["norm2"], cfg.rms_eps)
        if cfg.is_moe_layer(layer_idx):
            h, aux = moe_ffn(params["ffn"], cfg, h)
        else:
            h = ffn_apply(params["ffn"], cfg, h)
        x = x + h
    return x, aux


def block_paged(params, cfg, layer_idx: int, x, layer_cache, tables, lengths,
                spec, *, cross_kv=None, moe_stats=None, impl: str = "auto"):
    """Paged cached step: attention kinds go through the block-table pools,
    recurrent kinds keep their per-stream state (batch-native already).
    ``cross_kv`` is a per-lane {"k","v"} dict gathered from the shared
    encoder segment pools; ``moe_stats`` (a dict the caller owns)
    accumulates the routed layers' expert-activation counts.
    Returns (x, new_layer_cache)."""
    kind = cfg.block_kind(layer_idx)
    decode = x.shape[1] == 1
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    if kind in ("attn", "local"):
        h, layer_cache = attn_paged(params["mixer"], cfg, h, layer_cache,
                                    tables, lengths, window=spec.window,
                                    impl=impl)
    elif kind == "mla":
        h, layer_cache = mla_paged(params["mixer"], cfg, h, layer_cache,
                                   tables, lengths, impl=impl)
    elif kind == "mamba2":
        h, layer_cache = ssm_mixer(params["mixer"], cfg, h, layer_cache, decode=decode)
    elif kind == "rglru":
        h, layer_cache = rglru_mixer(params["mixer"], cfg, h, layer_cache, decode=decode)
    x = x + h
    if "cross" in params and cross_kv is not None:
        h = rms_norm(x, params["cross_norm"], cfg.rms_eps)
        x = x + cross_attn(params["cross"], cfg, h, cross_kv)
    if "ffn" in params:
        h = rms_norm(x, params["norm2"], cfg.rms_eps)
        if cfg.is_moe_layer(layer_idx):
            h, aux = moe_ffn(params["ffn"], cfg, h, capacity_factor=2.0)
            _fold_moe_stats(moe_stats, aux)
        else:
            h = ffn_apply(params["ffn"], cfg, h)
        x = x + h
    return x, layer_cache


def block_tree(params, cfg, layer_idx: int, x, layer_cache, layer_nodes,
               node_mask, spec, *, pos0=None, depths=None, tables=None,
               lengths=None, impl: str = "auto"):
    """Tree-node step: attention/MLA attend over cache + carried node KV
    under the ancestor mask and do NOT write the cache; recurrent kinds
    cannot serve trees (state integrates sequentially — there is no
    per-branch state to fork) and are rejected at engine init.
    Dense when ``pos0`` is given (node positions = pos0 + depths), paged
    when (tables, lengths) are.  Returns (x, new_layer_nodes)."""
    kind = cfg.block_kind(layer_idx)
    paged = tables is not None
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    if kind in ("attn", "local"):
        if paged:
            h, layer_nodes = attn_tree_paged(
                params["mixer"], cfg, h, layer_cache, tables, lengths, depths,
                layer_nodes, node_mask, window=spec.window, impl=impl)
        else:
            h, layer_nodes = attn_tree(
                params["mixer"], cfg, h, pos0 + depths, layer_cache,
                layer_nodes, node_mask, pos0, window=spec.window, impl=impl)
    elif kind == "mla":
        if paged:
            h, layer_nodes = mla_tree_paged(
                params["mixer"], cfg, h, layer_cache, tables, lengths, depths,
                layer_nodes, node_mask, impl=impl)
        else:
            h, layer_nodes = mla_tree(
                params["mixer"], cfg, h, pos0 + depths, layer_cache,
                layer_nodes, node_mask, pos0, impl=impl)
    else:
        raise ValueError(f"tree speculation requires attn/mla stacks, "
                         f"got {kind}")
    x = x + h
    if "ffn" in params:
        h = rms_norm(x, params["norm2"], cfg.rms_eps)
        if cfg.is_moe_layer(layer_idx):
            h, _ = moe_ffn(params["ffn"], cfg, h, capacity_factor=2.0)
        else:
            h = ffn_apply(params["ffn"], cfg, h)
        x = x + h
    return x, layer_nodes


def _fold_moe_stats(moe_stats, aux):
    """Accumulate one routed layer's expert-activation count into the
    caller-owned ``moe_stats`` dict (callers inside ``lax.scan`` fold the
    dict into their carry — a module-level accumulator would leak tracers)."""
    if moe_stats is None:
        return
    hit = aux["moe_experts_hit"]
    moe_stats["experts_hit"] = moe_stats.get("experts_hit", 0.0) + hit
    moe_stats["layers"] = moe_stats.get("layers", 0) + 1


def block_cached(params, cfg, layer_idx: int, x, pos0, layer_cache, spec,
                 *, cross_kv=None, moe_stats=None, impl: str = "auto"):
    """Cached step (prefill chunk or decode). Returns (x, new_layer_cache)."""
    kind = cfg.block_kind(layer_idx)
    decode = x.shape[1] == 1
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    if kind in ("attn", "local"):
        h, layer_cache = attn_cached(params["mixer"], cfg, h, pos0, layer_cache,
                                     window=spec.window, ring=spec.ring, impl=impl)
    elif kind == "mla":
        h, layer_cache = mla_cached(params["mixer"], cfg, h, pos0, layer_cache,
                                    ring=spec.ring, impl=impl)
    elif kind == "mamba2":
        h, layer_cache = ssm_mixer(params["mixer"], cfg, h, layer_cache, decode=decode)
    elif kind == "rglru":
        h, layer_cache = rglru_mixer(params["mixer"], cfg, h, layer_cache, decode=decode)
    x = x + h
    if "cross" in params and cross_kv is not None:
        h = rms_norm(x, params["cross_norm"], cfg.rms_eps)
        x = x + cross_attn(params["cross"], cfg, h, cross_kv)
    if "ffn" in params:
        h = rms_norm(x, params["norm2"], cfg.rms_eps)
        if cfg.is_moe_layer(layer_idx):
            h, aux = moe_ffn(params["ffn"], cfg, h, capacity_factor=2.0)
            _fold_moe_stats(moe_stats, aux)
        else:
            h = ffn_apply(params["ffn"], cfg, h)
        x = x + h
    return x, layer_cache
