"""Shared building blocks: norms, rotary embeddings, activations, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    # python float (weak type) so bf16 params stay bf16
    std = float(scale / np.sqrt(max(fan_in, 1)))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return truncated_normal_init(key, (d_in, d_out), 1.0, dtype)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d_model), dtype) * 0.02


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def activation_fn(name: str):
    if name == "swiglu" or name == "silu":
        return jax.nn.silu
    if name == "geglu" or name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta))
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, D/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_with_logits(logits, labels, mask=None):
    """Mean CE over valid positions. logits (..., V) fp32-safe."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
