"""Synthetic multi-domain corpus + promptsets (DESIGN.md §6).

The paper evaluates on SpecBench / MT-Bench / HumanEval with Llama-scale
models.  On CPU we reproduce the *claims* with tiny models trained on a
synthetic language whose domains mirror the paper's key structure:

  code        low-entropy, highly deterministic grammar  (HumanEval analog)
  math        exact arithmetic lines (learnable by a larger model)
  prose       Zipfian word-Markov text, high entropy     (MT-Bench analog)
  cipher      deterministic word-substitution "translation"
  list        enumerations with predictable separators   (extraction/rag)

SpecBench categories are mixtures over these base generators, so coding
prompts really are lower-entropy than non-coding ones (paper Fig. 2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from .tokenizer import ByteTokenizer

_WORDS = None


def _vocab(rng: np.random.Generator, n: int = 280) -> List[str]:
    letters = "abcdefghijklmnopqrstuvwxyz"
    words = set()
    while len(words) < n:
        L = int(rng.integers(2, 8))
        words.add("".join(rng.choice(list(letters), L)))
    return sorted(words)


class DomainGenerators:
    """Deterministic (seeded) text generators per base domain."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.words = _vocab(self.rng)
        n = len(self.words)
        # order-1 word Markov chain, sparse rows -> moderate entropy
        probs = self.rng.dirichlet(np.full(24, 0.4), size=n)
        cols = np.stack([self.rng.choice(n, 24, replace=False) for _ in range(n)])
        self.markov = (cols, probs)
        # deterministic substitution "translation" table
        perm = self.rng.permutation(n)
        self.cipher = {self.words[i]: self.words[perm[i]] + "e" for i in range(n)}

    # -- base domains -------------------------------------------------
    def code(self, rng, n_lines: int = 8) -> str:
        vs = [f"x{i}" for i in range(6)]
        ops = ["+", "-", "*"]
        out = []
        for _ in range(n_lines):
            a, b, c = rng.choice(vs), rng.choice(vs), rng.choice(vs)
            if rng.random() < 0.3:
                out.append(f"def f_{rng.integers(10)}({a}, {b}):")
                out.append(f"    return {a} {rng.choice(ops)} {b}")
            else:
                out.append(f"{c} = {a} {rng.choice(ops)} {b};")
        return "\n".join(out) + "\n"

    def math(self, rng, n_lines: int = 6) -> str:
        out = []
        for _ in range(n_lines):
            a, b = int(rng.integers(0, 50)), int(rng.integers(0, 50))
            out.append(f"{a} + {b} = {a + b}")
        return "\n".join(out) + "\n"

    def prose(self, rng, n_words: int = 40) -> str:
        cols, probs = self.markov
        n = len(self.words)
        w = int(rng.integers(n))
        toks = []
        for i in range(n_words):
            toks.append(self.words[w])
            if rng.random() < 0.08:
                toks[-1] += "."
            w = int(rng.choice(cols[w], p=probs[w]))
        return " ".join(toks) + ".\n"

    def cipher_pairs(self, rng, n_words: int = 12) -> str:
        cols, probs = self.markov
        n = len(self.words)
        w = int(rng.integers(n))
        src = []
        for _ in range(n_words):
            src.append(self.words[w])
            w = int(rng.choice(cols[w], p=probs[w]))
        tgt = [self.cipher[x] for x in src]
        return "EN: " + " ".join(src) + " | FR: " + " ".join(tgt) + "\n"

    def listing(self, rng, n_items: int = 8) -> str:
        out = [f"- item {i}: {self.words[int(rng.integers(len(self.words)))]}"
               for i in range(n_items)]
        return "\n".join(out) + "\n"


# SpecBench category -> mixture over base domains
SPECBENCH_MIX: Dict[str, Dict[str, float]] = {
    "coding":          {"code": 0.9, "prose": 0.1},
    "extraction":      {"listing": 0.7, "prose": 0.3},
    "humanities":      {"prose": 1.0},
    "math":            {"math": 0.9, "prose": 0.1},
    "math_reasoning":  {"math": 0.6, "prose": 0.4},
    "qa":              {"prose": 0.8, "listing": 0.2},
    "rag":             {"listing": 0.5, "prose": 0.5},
    "reasoning":       {"prose": 0.7, "math": 0.3},
    "roleplay":        {"prose": 1.0},
    "stem":            {"math": 0.4, "prose": 0.6},
    "summarization":   {"prose": 0.7, "listing": 0.3},
    "translation":     {"cipher": 0.9, "prose": 0.1},
    "writing":         {"prose": 1.0},
}

DATASET_MIX: Dict[str, Dict[str, float]] = {
    # MT-Bench: broad non-coding chat; HumanEval: pure code
    "mt_bench":  {"prose": 0.6, "math": 0.15, "listing": 0.15, "cipher": 0.1},
    "humaneval": {"code": 1.0},
    "alpaca":    {"prose": 0.5, "code": 0.2, "math": 0.15, "listing": 0.15},
}


class SyntheticCorpus:
    def __init__(self, seed: int = 0):
        self.gens = DomainGenerators(seed)
        self.tok = ByteTokenizer()

    def _sample_domain(self, rng, mix: Dict[str, float]) -> str:
        names = list(mix)
        p = np.array([mix[k] for k in names], np.float64)
        name = names[int(rng.choice(len(names), p=p / p.sum()))]
        return getattr(self.gens, {"code": "code", "math": "math",
                                   "prose": "prose", "cipher": "cipher_pairs",
                                   "listing": "listing"}[name])(rng)

    def document(self, rng, mix: Dict[str, float], min_chars: int = 400) -> str:
        parts = []
        total = 0
        while total < min_chars:
            t = self._sample_domain(rng, mix)
            parts.append(t)
            total += len(t)
        return "".join(parts)

    def token_stream(self, mix: Dict[str, float], seed: int = 0) -> Iterator[int]:
        rng = np.random.default_rng(seed)
        while True:
            doc = self.document(rng, mix)
            yield from self.tok.encode(doc, bos=True, eos=True)

    def training_batches(self, *, seq_len: int, batch_size: int,
                         mix: Dict[str, float] = None, seed: int = 0
                         ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yields (tokens, labels) of shape (B, S) — next-token LM setup."""
        mix = mix or DATASET_MIX["alpaca"]
        streams = [self.token_stream(mix, seed * 1000 + b)
                   for b in range(batch_size)]
        buffers = [[] for _ in range(batch_size)]
        while True:
            x = np.zeros((batch_size, seq_len), np.int32)
            y = np.zeros((batch_size, seq_len), np.int32)
            for b in range(batch_size):
                while len(buffers[b]) < seq_len + 1:
                    buffers[b].append(next(streams[b]))
                chunk = buffers[b][:seq_len + 1]
                buffers[b] = buffers[b][seq_len:]
                x[b] = chunk[:-1]
                y[b] = chunk[1:]
            yield x, y

    # -- prompt sets ---------------------------------------------------
    def prompts(self, dataset: str, n: int, seed: int = 100,
                prompt_chars: int = 80) -> List[Tuple[str, List[int]]]:
        """Returns [(category, token_ids)] for a named dataset."""
        out = []
        if dataset == "specbench":
            cats = list(SPECBENCH_MIX)
            per = max(1, n // len(cats))
            for c in cats:
                rng = np.random.default_rng(seed + hash(c) % 10000)
                for _ in range(per):
                    doc = self.document(rng, SPECBENCH_MIX[c], prompt_chars)
                    out.append((c, self.tok.encode(doc[:prompt_chars])))
            return out
        mix = DATASET_MIX[dataset]
        rng = np.random.default_rng(seed)
        for _ in range(n):
            doc = self.document(rng, mix, prompt_chars)
            out.append((dataset, self.tok.encode(doc[:prompt_chars])))
        return out
