"""Byte-level tokenizer with special tokens (no external vocab files)."""
from __future__ import annotations

from typing import List


class ByteTokenizer:
    BOS = 256
    EOS = 257
    PAD = 258

    def __init__(self):
        self.vocab_size = 259

    def encode(self, text: str, *, bos: bool = True, eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8", errors="replace"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: List[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")
