"""Pytree checkpointing: npz payload + json tree structure. No deps."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, metadata: dict = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for p, leaf in flat:
        k = _path_str(p)
        keys.append(k)
        arrays[k] = np.asarray(leaf)
    np.savez_compressed(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump({"keys": keys, "treedef": str(treedef),
                   "metadata": metadata or {}}, f)


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    payload = np.load(path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        k = _path_str(p)
        arr = payload[k]
        assert arr.shape == tuple(np.shape(leaf)), (k, arr.shape, np.shape(leaf))
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def checkpoint_exists(path: str) -> bool:
    return os.path.exists(path + ".npz") and os.path.exists(path + ".json")
