"""AdamW with cosine schedule and global-norm clipping (no optax dep)."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 2000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init_opt_state(params) -> OptState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(z, params), jax.tree.map(z, params))


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
