"""Jitted train step + host training loop."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import forward_hidden
from .losses import chunked_ce_loss
from .optimizer import OptConfig, OptState, adamw_update, init_opt_state


def make_train_step(cfg, opt_cfg: OptConfig, *, impl: str = "auto",
                    remat: bool = True, ce_chunk: int = 512,
                    compute_dtype=None, microbatches: int = 1,
                    donate: bool = True) -> Callable:
    """Returns jitted (params, opt_state, batch) -> (params, opt_state, metrics).

    batch: {"tokens": (B,S) int32, "labels": (B,S) int32,
            optional "patch_embeds" / "frame_embeds" / "mask"}.
    compute_dtype: bf16 mixed-precision forward (params stay f32 masters).
    microbatches: grad-accumulation over B/microbatches slices (scan) — cuts
    the activation/MoE working set at the cost of re-gathering FSDP-sharded
    weights per microbatch (§Perf iteration knob).
    """

    def loss_fn(params, batch):
        if compute_dtype is not None:
            params = jax.tree.map(
                lambda p: p.astype(compute_dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
        extras = {k: batch[k] for k in ("patch_embeds", "frame_embeds")
                  if k in batch}
        hidden, aux = forward_hidden(params, cfg, batch["tokens"],
                                     impl=impl, remat=remat, **extras)
        if cfg.vision is not None and "patch_embeds" in batch:
            hidden = hidden[:, batch["patch_embeds"].shape[1]:]
        ce = chunked_ce_loss(params, cfg, hidden, batch["labels"],
                             mask=batch.get("mask"), chunk=ce_chunk)
        return ce + aux, (ce, aux)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            (_, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            def slice_mb(x):
                B = x.shape[0]
                assert B % microbatches == 0, (B, microbatches)
                return x.reshape(microbatches, B // microbatches, *x.shape[1:])
            mbs = jax.tree.map(slice_mb, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                g_acc, ce_acc, aux_acc = carry
                (_, (ce, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_acc, g)
                return (g_acc, ce_acc + ce, aux_acc + aux), None

            (grads, ce, aux), _ = jax.lax.scan(
                acc, (zero, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            ce, aux = ce / microbatches, aux / microbatches
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": ce, "aux_loss": aux, **om}
        return params, opt_state, metrics

    if not donate:
        return train_step            # raw fn (dry-run wraps it with shardings)
    return jax.jit(train_step, donate_argnums=(0, 1))


def train(cfg, params, batches: Iterator, opt_cfg: OptConfig, *,
          steps: int, log_every: int = 50, impl: str = "auto",
          remat: bool = True, callback=None) -> Dict:
    step_fn = make_train_step(cfg, opt_cfg, impl=impl, remat=remat)
    opt_state = init_opt_state(params)
    history = []
    t0 = time.perf_counter()
    for s in range(steps):
        x, y = next(batches)
        batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if s % log_every == 0 or s == steps - 1:
            m = {k: float(v) for k, v in m.items()}
            m["step"] = s
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(m)
    return {"params": params, "opt_state": opt_state, "history": history}
