"""Sequence-chunked cross-entropy: never materializes (B, S, V) logits.

For the big-vocab assigned architectures (vocab up to 256k), full-sequence
logits at train_4k would be ~0.5 TB; we scan over sequence chunks and
compute logits + CE per chunk (the logits stay (B, chunk, V), sharded
vocab-over-model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy_with_logits
from repro.models.transformer import logits_fn


def chunked_ce_loss(params, cfg, hidden, labels, mask=None, chunk: int = 512):
    """hidden (B,S,d), labels (B,S) -> mean CE over valid positions."""
    B, S, _ = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        m = jnp.pad(mask if mask is not None else jnp.ones((B, S), bool),
                    ((0, 0), (0, pad)))
    else:
        m = mask if mask is not None else jnp.ones((B, S), bool)
    n = hidden.shape[1] // chunk
    hs = hidden.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = m.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h, l, mm = xs
        logits = logits_fn(params, cfg, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mm.astype(jnp.float32)
        return (tot + nll.sum(), cnt + mm.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
