"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) pair.

The four assigned input shapes:

  train_4k       seq 4,096    global_batch 256   -> train_step
  prefill_32k    seq 32,768   global_batch 32    -> prefill_step
  decode_32k     seq 32,768   global_batch 128   -> serve_step (1 new token)
  long_500k      seq 524,288  global_batch 1     -> serve_step (ring cache)

VLM: the patch stub occupies the first ``num_patches`` positions, so the
token stream is shortened to keep the total sequence at the assigned length.
Audio (enc-dec): ``seq`` counts decoder positions; the encoder consumes the
stub's ``frontend_len`` frames.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelConfig
from repro.models import transformer as T

__all__ = ["SHAPES", "PairSpec", "pair_spec", "input_specs",
           "abstract_params", "abstract_cache"]

SHAPES = {
    "train_4k": dict(seq_len=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, batch=1, kind="decode"),
}


@dataclass(frozen=True)
class PairSpec:
    arch: str
    shape: str
    kind: str                 # train | prefill | decode
    seq_len: int
    batch: int


def pair_spec(arch: str, shape: str) -> PairSpec:
    s = SHAPES[shape]
    return PairSpec(arch, shape, s["kind"], s["seq_len"], s["batch"])


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins (weak-type-correct, shardable, no allocation)."""
    s = SHAPES[shape]
    B, S = s["batch"], s["seq_len"]
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if s["kind"] == "train":
        S_tok = S
        if cfg.vision is not None:
            S_tok = S - cfg.vision.num_patches
            out["patch_embeds"] = _sds((B, cfg.vision.num_patches,
                                        cfg.vision.vit_dim), jnp.bfloat16)
        if cfg.is_encdec:
            out["frame_embeds"] = _sds((B, cfg.encdec.frontend_len,
                                        cfg.encdec.frontend_dim), jnp.bfloat16)
        out["tokens"] = _sds((B, S_tok), jnp.int32)
        out["labels"] = _sds((B, S_tok), jnp.int32)
        return out
    if s["kind"] == "prefill":
        S_tok = S
        if cfg.vision is not None:
            S_tok = S - cfg.vision.num_patches
            out["patch_embeds"] = _sds((B, cfg.vision.num_patches,
                                        cfg.vision.vit_dim), jnp.bfloat16)
        if cfg.is_encdec:
            out["frame_embeds"] = _sds((B, cfg.encdec.frontend_len,
                                        cfg.encdec.frontend_dim), jnp.bfloat16)
        out["tokens"] = _sds((B, S_tok), jnp.int32)
        return out
    # decode: ONE new token against a seq_len cache
    out["tokens"] = _sds((B, 1), jnp.int32)
    return out


def abstract_params(cfg: ModelConfig, dtype) -> dict:
    return jax.eval_shape(
        lambda k: T.init_params(cfg, k, dtype=dtype), jax.random.PRNGKey(0))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    from repro.models.cache import build_cache_spec
    spec = build_cache_spec(cfg, max_len)          # static metadata
    cache = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len, dtype)[0])
    return cache, spec
