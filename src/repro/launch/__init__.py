"""Launch layer: meshes, sharding rules, abstract input specs, dry-runs.

The device-placement vocabulary for the whole stack (docs/sharding.md):
``mesh`` builds the production / forced-host-device meshes, ``shardings``
assigns PartitionSpecs to parameter, cache (dense slot-stacked, paged
pool, int8-scale) and batch pytrees by path, and ``specs`` provides
ShapeDtypeStruct stand-ins for the assigned (arch x shape) pairs so
placement can be decided without allocating.  ``dryrun`` is deliberately
NOT imported here: importing it mutates ``XLA_FLAGS`` (512 forced host
devices) and must only ever happen in a dedicated interpreter — run it as
``python -m repro.launch.dryrun``.
"""
from repro.launch.mesh import (HOST_DEVICE_FLAG, forced_host_env,
                               make_host_mesh, make_production_mesh)
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    cache_spec, paged_cache_shardings,
                                    paged_cache_spec, param_spec,
                                    params_shardings, replicated,
                                    slot_cache_shardings, slot_cache_spec,
                                    tree_shardings)
from repro.launch.specs import (SHAPES, PairSpec, abstract_cache,
                                abstract_params, input_specs, pair_spec)

__all__ = [
    # meshes
    "HOST_DEVICE_FLAG", "forced_host_env", "make_host_mesh",
    "make_production_mesh",
    # sharding rules
    "batch_shardings", "cache_shardings", "cache_spec",
    "paged_cache_shardings", "paged_cache_spec", "param_spec",
    "params_shardings", "replicated", "slot_cache_shardings",
    "slot_cache_spec", "tree_shardings",
    # abstract input specs
    "SHAPES", "PairSpec", "abstract_cache", "abstract_params",
    "input_specs", "pair_spec",
]
