"""Parameter / cache / batch PartitionSpec assignment by pytree path.

Logical scheme (DESIGN.md §5, docs/sharding.md):
  * tensor-parallel axis "model": attention heads, FFN hidden, MoE experts,
    vocab dim of the embedding.
  * FSDP axis ("pod","data"): the other large weight dim (ZeRO-style); for
    single-pod meshes "pod" resolves away, for batch=1 shapes everything
    non-divisible is dropped by ``resolve_spec``.
  * batch axis ("pod","data") on activations and KV caches; the engines'
    slot-stacked caches shard their leading SLOT axis over it
    (``slot_cache_spec``) and paged pools shard heads over "model" with
    per-stream tables/lengths on the batch axis (``paged_cache_spec``).

Stacked (scan-over-layers) parameters get a leading replicated cycle dim.
Int8-quantized caches carry ``*_scale`` siblings that shard exactly like
their payload rows; tree-speculation node buffers reuse the attention
cache rules (their leaves mirror the cache layout).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.cache import POOL_LEAF_KEYS
from repro.models.sharding import resolve_spec

FSDP = ("pod", "data")
BATCH = ("pod", "data")

__all__ = [
    "FSDP", "BATCH", "param_spec", "params_shardings", "cache_spec",
    "cache_shardings", "slot_cache_spec", "slot_cache_shardings",
    "paged_cache_spec", "paged_cache_shardings", "batch_shardings",
    "tree_shardings", "replicated", "lane_shardings",
    "fused_tick_shardings",
]

# (regex over "/"-joined path, spec WITHOUT the stacked-cycle dim)
_PARAM_RULES: Tuple[Tuple[str, tuple], ...] = (
    (r"embed$",                     ("model", FSDP)),
    (r"lm_head$",                   (FSDP, "model")),
    (r"(final_norm|norm1|norm2|cross_norm|q_norm|k_norm|kv_norm|out_norm)$", (None,)),
    # attention
    (r"mixer/w[qkv]$",              (FSDP, "model")),
    (r"cross/w[qkv]$",              (FSDP, "model")),
    (r"mixer/wo$",                  ("model", FSDP)),
    (r"cross/wo$",                  ("model", FSDP)),
    (r"mixer/b[qkv]$",              ("model",)),
    # MLA
    (r"mixer/w_q$",                 (FSDP, "model")),
    (r"mixer/w_dq$",                (FSDP, None)),
    (r"mixer/w_uq$",                (None, "model")),
    (r"mixer/w_dkv$",               (FSDP, None)),
    (r"mixer/w_uk$",                (None, "model")),
    (r"mixer/w_uv$",                (None, "model")),
    # dense FFN
    (r"ffn/w_(in|gate)$",           (FSDP, "model")),
    (r"ffn/w_out$",                 ("model", FSDP)),
    # MoE
    (r"ffn/router$",                (None, "model")),
    (r"ffn/experts/w_(in|gate)$",   ("model", None, FSDP)),
    (r"ffn/experts/w_out$",         ("model", FSDP, None)),
    (r"ffn/shared/w_(in|gate)$",    (FSDP, "model")),
    (r"ffn/shared/w_out$",          ("model", FSDP)),
    # Mamba2 SSD
    (r"mixer/w_in$",                (FSDP, "model")),
    (r"mixer/conv_w$",              (None, "model")),
    (r"mixer/conv_b$",              ("model",)),
    (r"mixer/(A_log|D|dt_bias)$",   ("model",)),
    (r"mixer/w_out$",               ("model", FSDP)),
    # RG-LRU
    (r"mixer/w_[xy]$",              (FSDP, "model")),
    (r"mixer/w_[ri]$",              (None, "model")),
    (r"mixer/b_[ri]$",              ("model",)),
    (r"mixer/lam$",                 ("model",)),
    # frontends
    (r"vis_proj/w1$",               (None, "model")),
    (r"vis_proj/w2$",               ("model", None)),
    (r"enc_proj$",                  (None, None)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_spec(path_str: str, shape) -> tuple:
    # int8-quantized weights (models/quant.py): the "qw" payload keeps its
    # parent weight's spec; the per-output-channel "scale" (parent shape
    # minus the contracted d_in axis) keeps the parent's d_out sharding
    if path_str.endswith("/qw"):
        return param_spec(path_str[:-len("/qw")], shape)
    if path_str.endswith("/scale"):
        parent = param_spec(path_str[:-len("/scale")],
                            tuple(shape[:-1]) + (1, shape[-1]))
        return tuple(parent[:-2]) + (parent[-1],)
    stacked = "/stack/" in path_str or path_str.endswith("/stack")
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_str):
            if stacked:
                spec = (None,) + tuple(spec)
            return tuple(spec)[:len(shape)] + (None,) * (len(shape) - len(spec) - (1 if stacked else 0))
    return (None,) * len(shape)


def tree_shardings(mesh: Mesh, tree: Any, spec_fn) -> Any:
    """Build a NamedSharding pytree for ``tree`` via spec_fn(path, shape)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        spec = spec_fn(ps, shape)
        out.append(NamedSharding(mesh, resolve_spec(mesh, spec, shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def params_shardings(mesh: Mesh, params_shape: Any, mode: str = "train") -> Any:
    """mode="train": ZeRO-3-ish — the non-"model" weight dim shards over
    ("pod","data") (params+grads+moments must fit). mode="serve": weights
    stay resident, sharded over "model" only — decode would otherwise
    all-gather every FSDP shard each layer (§Perf decode iteration 3)."""
    if mode == "serve":
        def spec_fn(path, shape):
            spec = param_spec(path, shape)
            return tuple(None if s == FSDP else s for s in spec)
        return tree_shardings(mesh, params_shape, spec_fn)
    return tree_shardings(mesh, params_shape, param_spec)


def cache_spec(path_str: str, shape) -> tuple:
    """KV/state cache sharding: batch over ("pod","data"); for attention
    caches prefer sharding KV heads over "model", else the sequence dim;
    recurrent state shards its channel/head dim over "model".  Int8 caches'
    ``*_scale`` leaves shard like their payload minus the head_dim axis.
    Tree node buffers ({"k","v"} (B, Tn, G, D) carries) hit the same rules
    as the cache rows they mirror."""
    stacked = "/stack/" in path_str
    lead = (None,) if stacked else ()
    if re.search(r"/(k|v)$", path_str):
        b, L, G, D = shape[-4:]
        if G % 16 == 0:
            return lead + (BATCH, None, "model", None)
        return lead + (BATCH, "model", None, None)
    if re.search(r"/(k|v)_scale$", path_str):
        b, L, G = shape[-3:]
        if G % 16 == 0:
            return lead + (BATCH, None, "model")
        return lead + (BATCH, "model", None)
    if re.search(r"/ckv$", path_str) or re.search(r"/krope$", path_str):
        return lead + (BATCH, "model", None)
    if re.search(r"/(ckv|krope)_scale$", path_str):
        return lead + (BATCH, "model")
    if re.search(r"/pos$", path_str):
        return lead + (None,) * (len(shape) - len(lead))
    if re.search(r"/conv$", path_str):
        return lead + (BATCH, None, "model")
    if re.search(r"/ssm$", path_str):
        return lead + (BATCH, "model", None, None)
    if re.search(r"/rec$", path_str):
        return lead + (BATCH, "model")
    if re.search(r"cross/.*(k|v)$", path_str):
        return lead + (BATCH, None, "model", None)
    return lead + (BATCH,) + (None,) * (len(shape) - len(lead) - 1)


def cache_shardings(mesh: Mesh, cache_shape: Any) -> Any:
    return tree_shardings(mesh, cache_shape, cache_spec)


def slot_cache_spec(path_str: str, shape) -> tuple:
    """Slot-stacked dense caches (``BatchedSpecEngine``): B per-stream B=1
    caches stacked on a leading SLOT axis.  The slot axis is the serving
    batch — shard it over ("pod","data") — and the inner dims follow the
    single-stream ``cache_spec`` rules (the inner batch dim is 1, so its
    batch axes resolve away and only "model" head sharding survives)."""
    return (BATCH,) + tuple(cache_spec(path_str, shape[1:]))


def slot_cache_shardings(mesh: Mesh, cache_shape: Any) -> Any:
    return tree_shardings(mesh, cache_shape, slot_cache_spec)


def paged_cache_spec(path_str: str, shape) -> tuple:
    """Paged caches (``PagedSpecEngine``): the global block pools carry NO
    stream axis — any stream's table may point at any physical block, so
    the pool's block axis must stay whole per shard.  K/V pools (and their
    int8 scale siblings) shard KV heads over "model"; MLA latent pools are
    contracted over their latent dim inside absorbed attention and stay
    replicated.  Per-stream leaves — block tables, lengths, recurrent
    state — shard over the ("pod","data") batch axes, which is what keeps
    paged gather/rollback per-shard: a lane's table row lives with the
    lane."""
    leaf = path_str.rsplit("/", 1)[-1]
    if leaf in POOL_LEAF_KEYS:
        lead = (None,) if "/stack/" in path_str else ()
        if leaf in ("k", "v"):
            return lead + (None, None, "model", None)
        if leaf in ("k_scale", "v_scale"):
            return lead + (None, None, "model")
        return (None,) * len(shape)            # MLA latent pools replicated
    if leaf in ("lengths", "tables"):
        return (BATCH,) + (None,) * (len(shape) - 1)
    return cache_spec(path_str, shape)         # per-stream recurrent state


def paged_cache_shardings(mesh: Mesh, cache_shape: Any) -> Any:
    return tree_shardings(mesh, cache_shape, paged_cache_spec)


def batch_shardings(mesh: Mesh, batch_shape: Any) -> Any:
    def spec_fn(path, shape):
        return (BATCH,) + (None,) * (len(shape) - 1)
    return tree_shardings(mesh, batch_shape, spec_fn)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def lane_shardings(mesh: Mesh, *shapes):
    """NamedShardings pinning the leading STREAM-LANE axis of flat (B, ...)
    per-lane serving operands — tokens, arm rows, PRNG keys, active masks,
    and the RAGGED-LENGTH vectors the fused tick and length-aware kernels
    take — to the ("pod","data") batch axes.  Indivisible axes drop per
    ``resolve_spec``, so B=1 / odd-B shapes degrade to replicated."""
    out = tuple(NamedSharding(mesh, resolve_spec(mesh, (BATCH,), s))
                for s in shapes)
    return out[0] if len(out) == 1 else out


def fused_tick_shardings(mesh: Mesh, *, batch_size: int, gamma_max: int,
                         n_prompt_tokens: int, signal_dim: int,
                         dparams_sh, tparams_sh, dcache_sh, tcache_sh):
    """(in_shardings, out_sharding_fields) for the fused serving tick
    (``core/spec_decode.fused_session_tick`` argument order).

    Per-lane operands — in/last tokens, arm matrix, draft/verify PRNG
    keys, active mask, and the three ragged (B,) length/keep vectors —
    shard their lane axis over ("pod","data"); the AdaEDL threshold
    replicates; params and caches keep the resident pytree shardings the
    engine placed them with.  The outcome-buffer fields come back lane-
    sharded so the host's deferred read pulls each lane from its shard."""
    B, g = batch_size, gamma_max

    def lane(shape):
        return lane_shardings(mesh, shape)

    ins = (dparams_sh, tparams_sh, dcache_sh, tcache_sh,
           lane((B, n_prompt_tokens)),            # in_tokens
           lane((B, 1)),                          # last_tokens
           lane((B, g)),                          # arm_mat
           replicated(mesh),                      # lam
           lane((B, 2)), lane((B, 2)),            # drngs, vrngs
           lane((B,)),                            # active
           lane((B,)), lane((B,)), lane((B,)))    # lengths, dkeep, tkeep
    outs = dict(n_drafted=lane((B,)), n_accepted=lane((B,)),
                out_tokens=lane((B, g + 1)), entropies=lane((B, g)),
                signals=lane((B, g, signal_dim)),
                dcache=dcache_sh, tcache=tcache_sh)
    return ins, outs
