"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers, compiles, fits, and report its roofline terms.

MUST be run as a module/script, never imported by tests or library code:
importing this module sets ``XLA_FLAGS`` to force 512 host devices, which
only takes effect if jax has not initialized yet — and would silently
leave a test process at 1 device (or, worse, poison a later jax init in
the same process) if imported casually.  The env assignment sits below
this docstring but ABOVE the first ``import jax``, which is what makes
the trick work while keeping this text the module's real ``__doc__``
(docs/sharding.md#dryrun).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape decode_32k [--multi-pod]
  python -m repro.launch.dryrun --all            # every pair, both meshes
"""
import os

# Force 512 virtual host devices BEFORE jax (imported below) initializes.
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.roofline import build_roofline
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    params_shardings, replicated)
from repro.launch.specs import SHAPES, abstract_cache, abstract_params, input_specs
from repro.models import transformer as T
from repro.models.sharding import use_mesh
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import make_train_step

__all__ = ["lower_pair", "main"]

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def lower_pair(arch: str, shape: str, *, multi_pod: bool = False,
               compile_: bool = True, verbose: bool = True,
               unroll: bool = False, cfg_overrides: dict = None,
               train_microbatches: int = 1, donate_cache: bool = False,
               cache_int8: bool = False, argmax_out: bool = False,
               serve_resident: bool = False) -> dict:
    cfg = get_config(arch)
    if unroll:   # accurate cost_analysis for the roofline (scan counts once)
        cfg = cfg.replace(scan_layers=False)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    sh = SHAPES[shape]
    kind, B, S = sh["kind"], sh["batch"], sh["seq_len"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    t0 = time.perf_counter()

    with use_mesh(mesh):
        ins = input_specs(cfg, shape)
        if kind == "train":
            params = abstract_params(cfg, jnp.float32)
            opt = jax.eval_shape(init_opt_state, params)
            psh = params_shardings(mesh, params)
            # opt state mirrors params: reuse param shardings for mu/nu
            from repro.training.optimizer import OptState
            osh = OptState(replicated(mesh),
                           params_shardings(mesh, opt.mu),
                           params_shardings(mesh, opt.nu))
            bsh = batch_shardings(mesh, ins)
            train_fn = make_train_step(cfg, OptConfig(),
                                       compute_dtype=jnp.bfloat16,
                                       microbatches=train_microbatches,
                                       donate=False)   # raw fn
            fn = jax.jit(train_fn, in_shardings=(psh, osh, bsh))
            lowered = fn.lower(params, opt, ins)
        else:
            params = abstract_params(cfg, jnp.bfloat16)
            psh = params_shardings(mesh, params,
                                   mode="serve" if serve_resident else "train")
            if kind == "prefill":
                def prefill_fn(p, batch):
                    cache, spec = T.init_cache(cfg, B, S + 8, jnp.bfloat16)
                    logits, cache = T.step(p, cfg, batch["tokens"], cache,
                                           spec, **{k: v for k, v in batch.items()
                                                    if k not in ("tokens",)})
                    return logits, cache
                bsh = batch_shardings(mesh, ins)
                fn = jax.jit(prefill_fn, in_shardings=(psh, bsh))
                lowered = fn.lower(params, ins)
            else:  # decode: one token against a seq_len cache
                cache_dtype = jnp.int8 if cache_int8 else jnp.bfloat16
                cache, spec = abstract_cache(cfg, B, S, cache_dtype)
                csh = cache_shardings(mesh, cache)
                tsh = batch_shardings(mesh, {"tokens": ins["tokens"]})["tokens"]

                def decode_fn(p, tok, c):
                    logits, c = T.step(p, cfg, tok, c, spec)
                    if argmax_out:
                        # serving returns the sampled token, not the logits:
                        # distributed argmax over the vocab-sharded logits
                        # avoids the (B, V) all-gather entirely
                        return jnp.argmax(logits, axis=-1).astype(jnp.int32), c
                    return logits, c
                # donate the cache: in-place slot update instead of a full
                # copy-on-write of the KV buffers (§Perf decode iteration)
                fn = jax.jit(decode_fn, in_shardings=(psh, tsh, csh),
                             donate_argnums=(2,) if donate_cache else ())
                lowered = fn.lower(params, ins["tokens"], cache)

        t_lower = time.perf_counter() - t0
        result = {"arch": arch, "shape": shape, "mesh": mesh_name,
                  "chips": chips, "kind": kind, "lower_s": t_lower,
                  "status": "lowered"}
        if compile_:
            compiled = lowered.compile()
            t_comp = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            rl = build_roofline(arch, shape, mesh_name, chips, cost, hlo,
                                cfg, kind, B, S)
            result.update({
                "status": "compiled", "compile_s": t_comp,
                "memory": _mem_dict(mem), "roofline": rl.to_dict(),
            })
            if verbose:
                print(f"[{arch} x {shape} x {mesh_name}] COMPILED "
                      f"lower={t_lower:.1f}s compile={t_comp:.1f}s")
                print("  memory_analysis:", result["memory"])
                print("  roofline:", json.dumps(rl.to_dict(), indent=2))
    return result


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll scan-over-layers for exact cost analysis")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON result here")
    args = ap.parse_args()

    os.makedirs(args.out and os.path.dirname(args.out) or ARTIFACT_DIR,
                exist_ok=True)
    results = []
    if args.all:
        pairs = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                 for mp in (False, True)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape, args.multi_pod)]
    rc = 0
    for arch, shape, mp in pairs:
        try:
            r = lower_pair(arch, shape, multi_pod=mp,
                           compile_=not args.lower_only, unroll=args.unroll)
        except Exception as e:
            traceback.print_exc()
            r = {"arch": arch, "shape": shape,
                 "mesh": "pod2x16x16" if mp else "pod16x16",
                 "status": "failed", "error": f"{type(e).__name__}: {e}"}
            rc = 1
        results.append(r)
    out_path = args.out or os.path.join(
        ARTIFACT_DIR, f"{pairs[0][0]}_{pairs[0][1]}_"
        f"{'multi' if pairs[0][2] else 'single'}.json")
    with open(out_path, "w") as f:
        json.dump(results if args.all else results[0], f, indent=2)
    print(f"wrote {out_path}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
