"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh over whatever local devices exist (sharding tests)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
