"""Mesh construction: production TPU v5e pods and forced-host-device test
meshes (docs/sharding.md).

Every constructor is a FUNCTION, not a module-level constant: importing
this module never touches jax device state (required so smoke tests see
1 CPU device).  Multi-device CPU runs must force the device count through
``XLA_FLAGS`` BEFORE jax initializes — ``forced_host_env`` builds the
subprocess environment tests and benches share for that.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

__all__ = [
    "make_production_mesh", "make_host_mesh", "forced_host_env",
    "HOST_DEVICE_FLAG", "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW",
]

# the XLA flag that splits the host CPU into N virtual devices; it must be
# in the environment before jax initializes (see launch/dryrun.py)
HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def make_production_mesh(*, multi_pod: bool = False):
    """The deployment meshes: (16, 16) ("data","model") single pod, or
    (2, 16, 16) ("pod","data","model") across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small ("data","model") mesh over whatever local devices exist
    (sharding tests / forced-host-device runs).  Axis sizes are clamped to
    the available device count, so the same call works on 1 real CPU
    device and on ``--xla_force_host_platform_device_count=8``."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"))


def forced_host_env(n_devices: int, base: Optional[dict] = None) -> dict:
    """Subprocess environment forcing ``n_devices`` virtual CPU devices.

    The flag only takes effect at jax init, so multi-device CPU tests and
    benches spawn a fresh interpreter with this env (never set it in an
    already-initialized process).  Existing XLA_FLAGS content is preserved.
    """
    env = dict(os.environ if base is None else base)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(HOST_DEVICE_FLAG)]
    flags.append(f"{HOST_DEVICE_FLAG}={n_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["JAX_PLATFORMS"] = "cpu"
    return env


# Hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
