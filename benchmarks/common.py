"""Shared benchmark infrastructure: trained model pairs + evaluation loop.

Metrics match the paper: m (mean accepted length per drafting session),
% (acceptance rate), s (speedup over Static-6 vanilla speculative decoding).
Speedup uses the analytic cost model (active-params per forward token) —
CPU wall-clock is not TPU wall-clock (DESIGN.md §6) — wall-clock is also
recorded for reference.
"""
from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import PAIR_COST_RATIO, PAPER_PAIRS, paper_pair
from repro.core import (EngineSpec, FixedArm, ModelBundle, StaticGamma,
                        make_controller, make_engine)
from repro.core.controller import Controller
from repro.data.synthetic import SyntheticCorpus
from repro.models import transformer as T
from repro.training.checkpoint import (checkpoint_exists, load_checkpoint,
                                       save_checkpoint)
from repro.training.optimizer import OptConfig
from repro.training.train_loop import train

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
GAMMA_MAX = 16        # CPU proxy for the paper's 128 "unbounded" cap
STATIC_GAMMA = 6


def get_corpus() -> SyntheticCorpus:
    return SyntheticCorpus(seed=0)


def trained_pair(name: str, *, steps: int = 200, seq_len: int = 96,
                 batch: int = 8) -> tuple:
    """Train (once, cached) the draft/target analog pair ``name``."""
    dcfg, tcfg = paper_pair(name)
    os.makedirs(os.path.join(ART, "models"), exist_ok=True)
    corpus = get_corpus()
    bundles = []
    for cfg, seed in ((dcfg, 0), (tcfg, 1)):
        path = os.path.join(ART, "models", f"{cfg.name}")
        template = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                  jax.random.PRNGKey(seed))
        if checkpoint_exists(path):
            params = load_checkpoint(path, jax.tree.map(
                lambda s: np.zeros(s.shape, s.dtype), template))
            params = jax.tree.map(jax.numpy.asarray, params)
        else:
            t0 = time.perf_counter()
            params = T.init_params(cfg, jax.random.PRNGKey(seed))
            out = train(cfg, params,
                        corpus.training_batches(seq_len=seq_len,
                                                batch_size=batch, seed=seed),
                        OptConfig(lr=3e-3, warmup_steps=30, total_steps=steps),
                        steps=steps, log_every=max(steps // 3, 1))
            params = out["params"]
            save_checkpoint(path, params,
                            {"loss": out["history"][-1]["loss"],
                             "train_s": time.perf_counter() - t0})
        bundles.append(ModelBundle(params, cfg))
    # analog models give the acceptance dynamics; the REAL pair's FLOP ratio
    # gives the cost model (see PAIR_COST_RATIO)
    bundles[1].cost_per_token = 1.0
    bundles[0].cost_per_token = PAIR_COST_RATIO[name]
    return bundles[0], bundles[1]


# ---------------------------------------------------------------------
# Paper protocol (Sec. 4.2): baseline heuristics get a THRESHOLD GRID
# SEARCH on the Llama-1B/8B analog over SpecBench, fixed for all other
# pairs/datasets.  TapOut's arm pool is tuning-free: thresholds come from a
# scale-free signal-quantile calibration (no performance feedback) — the
# Table-1 constants assume LLM-scale logit distributions, and our analog
# pairs are char-level (DESIGN.md §6).

# Quantiles chosen so each rule fires on ~the worst 10-15% of tokens
# (the paper's Table-1 constants imply a similar firing rate at LLM scale,
# giving oracle-like draft lengths of ~6; a median threshold would stop
# every other token). Directionality: MC/margin stop on LOW signal values,
# SVIP/SVIP-diff on HIGH ones.
CAL_QUANTILES = {  # signal -> (trace column, quantile)
    "max_confidence": ("top1", 0.15),
    "svip": ("sqrt_entropy", 0.85),
    "svip_difference": ("sqrt_entropy_diff", 0.90),
    "logit_margin": ("margin", 0.15),
}

BASELINE_GRIDS = {
    "max_confidence": [0.3, 0.5, 0.7, 0.9],
    "svip": [0.4, 0.8, 1.2, 1.6],
    "svip_difference": [0.1, 0.3, 0.6, 1.0],
    "logit_margin": [0.1, 0.3, 0.5, 0.7],
}


def _collect_calibration_traces(draft, target, n_prompts=4, max_new=48):
    corpus = get_corpus()
    eng = make_engine(draft, target, StaticGamma(gamma=8),
                      EngineSpec(backend="single", max_len=512))
    eng.collect_traces = True
    traces = []
    for _, ids in corpus.prompts("alpaca", n_prompts, seed=101):
        r = eng.generate(ids[:48], max_new)
        traces.extend(r.traces)
    return traces


def calibrated_thresholds(pair_name: str) -> Dict[str, float]:
    """Quantile calibration of the arm pool for this pair (cached)."""
    os.makedirs(os.path.join(ART, "bench"), exist_ok=True)
    path = os.path.join(ART, "bench", f"calibration_{pair_name}.json")
    if os.path.exists(path):
        return json.load(open(path))
    draft, target = trained_pair(pair_name)
    traces = _collect_calibration_traces(draft, target)
    sig = np.concatenate([t["signals"][:t["n_drafted"]] for t in traces])
    # columns: entropy, sqrt_entropy, top1, top2, margin, pos/32
    cols = {"entropy": sig[:, 0], "sqrt_entropy": sig[:, 1],
            "top1": sig[:, 2], "margin": sig[:, 4],
            "sqrt_entropy_diff": np.abs(np.diff(sig[:, 1]))}
    th = {arm: float(np.quantile(cols[col], q))
          for arm, (col, q) in CAL_QUANTILES.items()}
    with open(path, "w") as f:
        json.dump(th, f, indent=2)
    return th


def calibrated_pool(pair_name: str):
    from repro.core.arms import pool_from_thresholds
    return pool_from_thresholds(calibrated_thresholds(pair_name))


def tuned_baseline_thresholds() -> Dict[str, float]:
    """The paper's baseline tuning: grid search each heuristic's threshold on
    the Llama-1B/8B analog x SpecBench; fix for all pairs/datasets (cached)."""
    path = os.path.join(ART, "bench", "baseline_grid.json")
    if os.path.exists(path):
        return json.load(open(path))
    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()
    prompts = [ids[:48] for _, ids in corpus.prompts("specbench", 13, seed=103)]
    best = {}
    for arm, grid in BASELINE_GRIDS.items():
        scores = {}
        for h in grid:
            ctrl = FixedArm(GAMMA_MAX, arm, threshold=h)
            r = evaluate_method(draft, target, ctrl, prompts, max_new=48)
            scores[h] = r.cost_per_token
        best[arm] = min(scores, key=scores.get)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(best, f, indent=2)
    return best


def make_method(mname: str, pair_name: str, gamma_max: int, seed: int):
    if mname == "static6":
        return StaticGamma(gamma=STATIC_GAMMA, seed=seed)
    if mname == "adaedl":
        return make_controller("fixed_adaedl", gamma_max, seed)
    if mname in ("svip", "max_confidence", "svip_difference", "logit_margin"):
        th = tuned_baseline_thresholds()[mname]
        return FixedArm(gamma_max, mname, threshold=round(float(th), 4),
                        seed=seed)
    pool = calibrated_pool(pair_name)
    kinds = {"tapout_seq_ts": "tapout_seq_ts",
             "tapout_seq_ucb1": "tapout_seq_ucb1",
             "tapout_seq_ucb_tuned": "tapout_seq_ucb_tuned",
             "tapout_token_ts": "tapout_token_ts",
             "tapout_token_ucb1": "tapout_token_ucb1"}
    return make_controller(kinds[mname], gamma_max, seed, pool=pool)


METHODS = ["static6", "adaedl", "svip", "max_confidence", "tapout_seq_ts",
           "tapout_seq_ucb1", "tapout_token_ts", "tapout_token_ucb1"]


@dataclass
class MethodResult:
    method: str
    m: float            # mean accepted per session
    accept_rate: float
    cost_per_token: float
    wall_per_token: float
    speedup: float = 0.0   # filled vs static6
    extra: dict = field(default_factory=dict)


def evaluate_method(draft: ModelBundle, target: ModelBundle,
                    controller: Controller, prompts: List[List[int]], *,
                    max_new: int = 64, max_len: int = 1024, seed: int = 0,
                    engine_kwargs: Optional[Dict] = None) -> MethodResult:
    """Drain ``prompts`` through a single-stream engine and aggregate the
    paper metrics.  ``engine_kwargs`` become ``EngineSpec`` fields — the
    quantization axes (``kv_dtype="int8"``, ``quant_draft=True``) ride
    through here so every bench compares precisions under one harness; a
    quantized draft's cheaper ``cost_per_token``
    (``core.rewards.precision_cost_factor``) flows into
    ``cost_per_token`` below via the engine's modeled session cost."""
    eng = make_engine(draft, target, controller,
                      EngineSpec(backend="single", max_len=max_len, seed=seed,
                                 **(engine_kwargs or {})))
    tot_acc = tot_draft = tot_sessions = tot_new = 0
    cost = wall = 0.0
    for ids in prompts:
        r = eng.generate(ids, max_new)
        tot_acc += r.total_accepted
        tot_draft += r.total_drafted
        tot_sessions += len(r.sessions)
        tot_new += r.new_tokens
        cost += r.modeled_cost
        wall += r.wall_time_s
    return MethodResult(
        controller.name,
        m=tot_acc / max(tot_sessions, 1),
        accept_rate=tot_acc / max(tot_draft, 1),
        cost_per_token=cost / max(tot_new, 1),
        wall_per_token=wall / max(tot_new, 1),
        extra={"controller": controller},
    )


def run_method_suite(pair_name: str, prompts: List[List[int]],
                     methods: Optional[List[str]] = None, *,
                     max_new: int = 64, seed: int = 0,
                     gamma_max: int = GAMMA_MAX) -> Dict[str, MethodResult]:
    draft, target = trained_pair(pair_name)
    methods = methods or list(METHODS)
    out: Dict[str, MethodResult] = {}
    for mname in methods:
        ctrl = make_method(mname, pair_name, gamma_max, seed)
        out[mname] = evaluate_method(draft, target, ctrl, prompts,
                                     max_new=max_new, seed=seed)
        out[mname].method = mname
    base = out.get("static6")
    if base:
        for r in out.values():
            r.speedup = base.cost_per_token / max(r.cost_per_token, 1e-12)
    return out


def save_json(name: str, payload) -> str:
    os.makedirs(os.path.join(ART, "bench"), exist_ok=True)
    p = os.path.join(ART, "bench", f"{name}.json")
    with open(p, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return p


BENCH_SERVING_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json"))


def record_serving_bench(bench: str, summary: dict) -> str:
    """Append one serving-bench run's summary to the repo-root
    ``BENCH_serving.json`` so the perf trajectory is recorded ACROSS PRs
    (the file is committed; CI fails the lint lane if it is gitignored and
    the bench-smoke job if a run did not write it).  Entries are appended,
    never rewritten — the git history of this file IS the trajectory."""
    doc = {"runs": []}
    if os.path.exists(BENCH_SERVING_PATH):
        try:
            with open(BENCH_SERVING_PATH) as f:
                doc = json.load(f)
        except (ValueError, OSError):
            doc = {"runs": []}
    doc.setdefault("runs", []).append({
        "bench": bench,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "summary": summary,
    })
    with open(BENCH_SERVING_PATH, "w") as f:
        json.dump(doc, f, indent=2, default=float)
    return BENCH_SERVING_PATH


def fmt_table(rows: List[dict], cols: List[str]) -> str:
    widths = [max(len(c), *(len(f"{r.get(c, '')}") for r in rows)) for c in cols]
    lines = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    for r in rows:
        lines.append("  ".join(f"{r.get(c, '')}".ljust(w)
                               for c, w in zip(cols, widths)))
    return "\n".join(lines)
