"""Figs. 5 & 6: interpretability — arm-value progression of Seq-UCB1 and the
correspondence between final arm values and standalone per-arm speedups."""
from __future__ import annotations

import numpy as np

from .common import (GAMMA_MAX, calibrated_pool, calibrated_thresholds,
                     evaluate_method, get_corpus, save_json, trained_pair)
from repro.core import (EngineSpec, StaticGamma, TapOutSequence,
                        make_controller, make_engine)

ARMS = ["max_confidence", "svip", "adaedl", "svip_difference", "logit_margin"]


def run(quick: bool = False) -> dict:
    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()
    out = {}
    for dataset in ("mt_bench", "humaneval"):
        prompts = [ids[:48] for _, ids in
                   corpus.prompts(dataset, 3 if quick else 6, seed=31)]
        pool = calibrated_pool("llama-1b-8b")
        ctrl = TapOutSequence(GAMMA_MAX, "ucb1", "blend", pool=pool)
        eng = make_engine(draft, target, ctrl,
                          EngineSpec(backend="single", max_len=512))
        progression = []
        for ids in prompts:
            eng.generate(ids, 40 if quick else 72)
            progression.append([float(v) for v in ctrl.arm_values])
        # standalone per-arm speedups (Fig 6 comparison)
        base = evaluate_method(draft, target, StaticGamma(6), prompts,
                               max_new=40 if quick else 64)
        standalone = {}
        th = calibrated_thresholds("llama-1b-8b")
        for arm in ARMS:
            kw = {"threshold": round(float(th[arm]), 4)} if arm in th else {}
            r = evaluate_method(draft, target,
                                make_controller(f"fixed_{arm}", GAMMA_MAX, **kw),
                                prompts, max_new=40 if quick else 64)
            standalone[arm] = base.cost_per_token / max(r.cost_per_token, 1e-12)
        final = {a: float(v) for a, v in zip(ARMS, ctrl.arm_values)}
        # rank correlation between arm values and standalone speedups
        va = np.array([final[a] for a in ARMS])
        vs = np.array([standalone[a] for a in ARMS])
        ra, rs = np.argsort(np.argsort(va)), np.argsort(np.argsort(vs))
        spearman = float(1 - 6 * np.sum((ra - rs) ** 2) /
                         (len(ARMS) * (len(ARMS) ** 2 - 1)))
        out[dataset] = {"arm_value_progression": progression,
                        "final_arm_values": final,
                        "standalone_speedups": standalone,
                        "spearman_values_vs_speedup": spearman,
                        "value_spread": float(va.max() - va.min())}
    save_json("fig5_6_arm_values", out)
    return out
