"""Heterogeneous drafter pool: fixed-drafter baselines vs the meta-bandit.

Three drafters serve the same target (docs/drafters.md):

* ``kv``    — a perturbed copy of the target (the ``bench_tree``
              correlated-pair idiom: mid-range acceptance, no training),
              modeled at the nominal big-draft cost ratio with a KV state
              LINEAR in context length;
* ``eagle`` — an EAGLE-style head distilled against the target's hidden
              states (``core.drafters.train_eagle_head``; labels are the
              target's own argmax — the drafting objective), head-only
              compute cost, one layer of linear KV state;
* ``ssd``   — a Mamba2/SSD draft distilled the same way via the standard
              ``training/`` loop, O(1) per-stream recurrent state.

The modeled per-drafted-token cost is ``c_base + state_bytes(L) /
MEM_UNIT`` (``core.rewards.drafter_state_bytes``): compute plus the
memory traffic of the drafter's decode state at the stream's CURRENT
length, in units of one target forward token.  That makes the best
drafter REGIME-DEPENDENT — at short contexts the near-free trained head
wins, at long contexts its (and the kv draft's) linear KV state loses to
the O(1) SSD draft — and the
meta-bandit (cost-adjusted reward over the crossed (drafter x stop-rule)
pool) has to find each regime's winner online.  Per-tick accounting is
deterministic for a fixed seed, so all four claims gate EVERY mode,
``--smoke`` included:

* ``claim_meta_ge_worst_fixed``      — per regime, meta-bandit modeled
  tokens/s >= the worst fixed drafter's;
* ``claim_meta_within_tol_of_best``  — per regime, meta >= (1 - TOL) x
  the best fixed drafter.  TOL pays the exploration tax: the bandit must
  keep sampling every (drafter x stop-rule) arm over a ~100-tick horizon,
  and in the long regime the losing arms it samples are expensive.  The
  bench crosses a 3-stop-rule subset of the default pool with the 3
  drafters (9 arms) so that horizon can amortize the sweep — the full
  5-rule cross stays the ``default_drafter_pool`` default;
* ``claim_best_fixed_differs_by_regime`` — the argmax fixed drafter is
  different in the short vs long regime (the pool is not redundant);
* ``claim_ssd_state_o1``             — SSD per-stream draft-state bytes
  are CONSTANT in sequence length while the kv drafter's grow linearly.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

TOL = 0.35


def _distill_batches(target, *, seq_len: int, batch: int, seed: int):
    """(tokens, labels) batches where labels are the TARGET's argmax next
    token on random prefixes — the draft-the-target objective, no corpus
    needed."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import transformer as T

    @jax.jit
    def argmax_labels(params, toks):
        h, _ = T.forward_hidden(params, target.cfg, toks, remat=False)
        return jnp.argmax(T.logits_fn(params, target.cfg, h), axis=-1)

    rng = np.random.default_rng(seed)
    V = target.cfg.vocab_size
    while True:
        x = rng.integers(1, V, size=(batch, seq_len)).astype(np.int32)
        y = np.asarray(argmax_labels(target.params, jnp.asarray(x)))
        yield x, y.astype(np.int32)


def _build_pool(cfg: dict):
    """Target + the three drafters, with modeled compute costs."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.bench_serving_batch import _tiny_pair
    from repro.core import (Drafter, DrafterPool, ModelBundle, eagle_bundle,
                            ssd_draft_bundle, train_eagle_head)
    from repro.models import transformer as T
    from repro.training.optimizer import OptConfig

    _, target = _tiny_pair(n_layers_t=2, d_model_t=64)
    target.cost_per_token = 1.0

    # kv: perturbed target copy (bench_tree's correlated-pair idiom) at
    # the nominal big-draft compute ratio
    leaves, treedef = jax.tree.flatten(target.params)
    keys = jax.random.split(jax.random.PRNGKey(42), len(leaves))
    noisy = [l + cfg["sigma"] * jnp.std(l) * jax.random.normal(k, l.shape,
                                                               l.dtype)
             if l.ndim > 0 else l for l, k in zip(leaves, keys)]
    kvb = ModelBundle(jax.tree.unflatten(treedef, noisy),
                      target.cfg.replace(name="drf_kv"),
                      cost_per_token=cfg["kv_cost"])

    # eagle: distilled head, head-only compute cost
    steps = cfg["train_steps"]
    out = train_eagle_head(
        target, _distill_batches(target, seq_len=48, batch=4, seed=5),
        steps=steps, opt_cfg=OptConfig(lr=3e-3, warmup_steps=min(5, steps),
                                       total_steps=steps))
    eb = eagle_bundle(target, out["head"], out["head_cfg"])
    tgt_params = float(target.cfg.active_param_count())
    eb.cost_per_token = eb.cost_per_token / tgt_params
    print(f"  eagle head distilled: loss "
          f"{out['history'][0]['loss']:.3f} -> "
          f"{out['history'][-1]['loss']:.3f}", file=sys.stderr)

    # ssd: distilled Mamba2 draft via the standard training loop
    from repro.training.train_loop import train
    sb = ssd_draft_bundle(target.cfg, seed=9)
    tr = train(sb.cfg, sb.params,
               _distill_batches(target, seq_len=48, batch=4, seed=6),
               OptConfig(lr=3e-3, warmup_steps=min(5, steps),
                         total_steps=steps),
               steps=steps, log_every=max(steps // 2, 1))
    sb = ModelBundle(tr["params"], sb.cfg,
                     cost_per_token=sb.cfg.active_param_count() / tgt_params)
    print(f"  ssd draft distilled: loss "
          f"{tr['history'][0]['loss']:.3f} -> "
          f"{tr['history'][-1]['loss']:.3f}", file=sys.stderr)

    pool = DrafterPool([Drafter("kv", kvb, "kv"),
                        Drafter("eagle", eb, "eagle"),
                        Drafter("ssd", sb, "ssd")])
    return pool, target


def _cost_at(pool, mem_unit: float):
    """Per-drafted-token modeled cost at context length L (target = 1.0)."""
    def cost(name: str, L: int) -> float:
        base = pool.bundle(name).cost_per_token
        return base + pool.state_bytes(name, int(L)) / mem_unit
    return cost


def _run(pool, target, shapes, cfg, prompts, cost_at, label: str) -> dict:
    """Serve ``prompts`` through the drafter-pool engine under ``shapes``
    and account modeled cost per tick at each stream's current length."""
    import numpy as np
    from repro.core import EngineSpec, make_engine
    from repro.core.controller import TapOutTreeSequence

    # UCB-Tuned: the variance term matters here — per-arm cost-adjusted
    # rewards are near-deterministic, so UCB1's sqrt(2 ln t / n) bonus
    # would keep pulls near-uniform over a CI-scale horizon while
    # UCB-Tuned's variance-capped bonus separates the drafters quickly
    ctrl = TapOutTreeSequence(cfg["gamma_max"], "ucb_tuned", "cost",
                              shapes=shapes, seed=0)
    eng = make_engine(pool.bundle(pool.default), target, ctrl,
                      EngineSpec(drafters=pool, batch_size=cfg["batch_size"],
                                 max_len=cfg["max_len"]))
    queue = [list(p) for p in prompts]
    left, active = len(queue), {}
    for s in range(cfg["batch_size"]):
        if queue:
            p = queue.pop(0)
            eng.open_stream(s, p)
            active[s] = len(p)
    tokens, cost = 0, 0.0
    for _ in range(cfg["max_ticks"]):
        if not active:
            break
        n_hist = len(ctrl.history)
        eng.session_step_batch()
        if len(ctrl.history) > n_hist:
            row = ctrl.history[-1]
            L = float(np.mean([len(eng.slots[s]["seq"]) for s in active]))
            committed = row["n_accepted"] + row["batch"]
            tokens += committed
            cost += (row["n_drafted"] * cost_at(row["drafter"], L)
                     + (row["n_drafted"] + row["batch"]) * 1.0)
        for s in list(active):
            st = eng.slots[s]
            if st["done"] or st["res"].new_tokens >= cfg["max_new"]:
                eng.close_stream(s)
                del active[s]
                left -= 1
                if queue:
                    p = queue.pop(0)
                    eng.open_stream(s, p)
                    active[s] = len(p)
    assert left == 0, f"{label}: {left} streams unfinished"
    tps = tokens / max(cost, 1e-9)
    return {"tokens": tokens, "modeled_cost": round(cost, 3),
            "tok_per_cost": round(tps, 5),
            "drafter_pulls": ctrl.drafter_pulls,
            "engine": eng.describe()}


def _prompts(lo: int, hi: int, n: int, seed: int):
    import numpy as np
    rng = np.random.default_rng(seed)
    return [rng.integers(1, 60, size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


def run(quick: bool = False, smoke: bool = False) -> dict:
    from benchmarks.common import fmt_table, record_serving_bench, save_json
    from repro.core.arms import (chain_shape, default_drafter_pool,
                                 default_pool)

    if smoke or quick:
        cfg = dict(sigma=0.35, kv_cost=0.25, train_steps=25,
                   gamma_max=4, batch_size=2, max_len=256, max_new=24,
                   n_prompts=8, mem_equal_len=24, max_ticks=800)
    else:
        cfg = dict(sigma=0.35, kv_cost=0.25, train_steps=60,
                   gamma_max=4, batch_size=2, max_len=256, max_new=32,
                   n_prompts=12, mem_equal_len=24, max_ticks=1500)

    pool, target = _build_pool(cfg)
    mem_unit = float(pool.state_bytes("kv", cfg["mem_equal_len"]))
    cost_at = _cost_at(pool, mem_unit)
    regimes = {
        "short": _prompts(6, 14, cfg["n_prompts"], seed=3),
        "long": _prompts(180, 220, cfg["n_prompts"], seed=4),
    }

    results, best, worst = {}, {}, {}
    for regime, prompts in regimes.items():
        # regime-specific arm costs: the controller's cost-adjusted reward
        # sees the SAME modeled cost the metric charges, evaluated at the
        # regime's typical context length
        L_typ = int(sum(len(p) for p in prompts) / len(prompts)
                    + cfg["max_new"] // 2)
        costs = tuple((d.name, cost_at(d.name, L_typ)) for d in pool)
        # 3-stop-rule x 3-drafter cross (see module docstring)
        keep = {chain_shape(a).name for a in default_pool()[:3]}
        shapes = [s for s in default_drafter_pool(cfg["gamma_max"], costs)
                  if s.name.rsplit("@", 1)[0] in keep]
        res = {}
        for d in pool.names:
            res[f"fixed_{d}"] = _run(
                pool, target, [s for s in shapes if s.drafter == d], cfg,
                prompts, cost_at, f"{regime}/fixed_{d}")
        res["meta"] = _run(pool, target, shapes, cfg, prompts, cost_at,
                           f"{regime}/meta")
        fixed = {d: res[f"fixed_{d}"]["tok_per_cost"] for d in pool.names}
        best[regime] = max(fixed, key=fixed.get)
        worst[regime] = min(fixed, key=fixed.get)
        results[regime] = res
        rows = [{"run": k, "tok/cost": v["tok_per_cost"],
                 "tokens": v["tokens"], "pulls": v["drafter_pulls"]}
                for k, v in res.items()]
        print(f"  [{regime}] L_typ={L_typ} best={best[regime]}\n"
              + fmt_table(rows, ["run", "tok/cost", "tokens", "pulls"]),
              file=sys.stderr)

    state_lens = (64, 256, 1024, 4096)
    state_bytes = {d: {L: pool.state_bytes(d, L) for L in state_lens}
                   for d in pool.names}
    ssd_o1 = all(state_bytes["ssd"][L] == state_bytes["ssd"][state_lens[0]]
                 for L in state_lens)
    kv_linear = all(
        state_bytes["kv"][b] * a == state_bytes["kv"][a] * b
        for a, b in zip(state_lens, state_lens[1:]))

    def meta_ok(regime, bound):
        m = results[regime]["meta"]["tok_per_cost"]
        f = results[regime][f"fixed_{bound[regime]}"]["tok_per_cost"]
        return m >= (1.0 - TOL) * f if bound is best else m >= f

    claims = {
        "claim_meta_ge_worst_fixed": bool(
            all(meta_ok(r, worst) for r in regimes)),
        "claim_meta_within_tol_of_best": bool(
            all(meta_ok(r, best) for r in regimes)),
        "claim_best_fixed_differs_by_regime": bool(
            best["short"] != best["long"]),
        "claim_ssd_state_o1": bool(ssd_o1 and kv_linear),
    }
    summary = {
        "config": cfg, "tolerance": TOL,
        "drafters": pool.describe(cfg["max_len"]),
        "mem_unit_bytes": mem_unit,
        "best_fixed": best, "worst_fixed": worst,
        "tok_per_cost": {r: {k: v["tok_per_cost"] for k, v in res.items()}
                         for r, res in results.items()},
        "meta_drafter_pulls": {r: results[r]["meta"]["drafter_pulls"]
                               for r in results},
        "state_bytes_per_stream": state_bytes,
        **claims,
        "engine": {r: results[r]["meta"]["engine"] for r in results},
    }
    suffix = "_smoke" if smoke else ""
    save_json(f"drafters{suffix}", {"summary": summary, "results": {
        r: {k: {kk: vv for kk, vv in v.items() if kk != "engine"}
            for k, v in res.items()} for r, res in results.items()}})
    record_serving_bench(f"drafters{suffix}", summary)
    return summary


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI config")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    summary = run(quick=args.quick, smoke=args.smoke)
    ok = True
    for k in ("claim_meta_ge_worst_fixed", "claim_meta_within_tol_of_best",
              "claim_best_fixed_differs_by_regime", "claim_ssd_state_o1"):
        print(f"{k}={summary[k]}")
        ok = ok and summary[k]
    # all four claims are modeled-cost arithmetic over deterministic
    # greedy serving runs, so they gate EVERY mode, --smoke included
    sys.exit(0 if ok else 1)
