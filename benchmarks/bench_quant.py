"""Quantized inference bench: bf16 vs int8-KV vs int8-draft arms.

Three single-stream arms drain the SAME prompt set under the same stop
rule, reporting accepted-per-verify and the modeled cost-per-token
(``core.rewards``):

  * ``bf16_chain``  — the baseline chain arm, full-precision everything;
  * ``int8_kv``     — both models' KV caches stored int8 (per-row scales);
  * ``int8_draft``  — draft weights quantized once, modeled draft cost
                      scaled by ``precision_cost_factor("int8")``.

Headline claim (``claim_quant_cheaper_per_token``): the int8-draft arm's
modeled cost-per-token beats the bf16 chain arm — quantization shrinks the
draft/target cost ratio ``c`` that bounds TapOut's speedup, so the same
acceptance buys cheaper tokens.

The MEMORY-CONSTRAINED SERVING row drains a multi-stream workload through
two paged servers with the SAME ``pool_tokens`` budget: the int8-KV pool
must come in at well under half the bytes (int8 payload + f32 per-row
scales vs fp32 pools), i.e. ~2x the effective KV capacity per byte —
``claim_int8_kv_shrinks_pool``.  Output parity of the int8-KV server vs
the bf16 server is recorded alongside (``int8_kv_output_parity``).

``--smoke`` runs a seconds-scale config for CI, writes
``artifacts/bench/quant_spec_smoke.json`` and appends a summary row to the
repo-root ``BENCH_serving.json`` (the committed perf trajectory).
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _serve_paged(draft, target, prompts, *, max_new: int, gamma_max: int,
                 max_len: int, pool_tokens: int, kv_dtype=None) -> dict:
    """One deterministic paged drain collecting per-request OUTPUTS.

    Deliberately not ``bench_serving_batch._serve``: that harness exists
    for TIMING (warmup drain, best-of-repeats, online-bandit controller),
    all of which is wrong for a byte-footprint + output-parity comparison
    — this one drains once with a fixed stop rule and keeps the tokens.
    """
    from repro.core import EngineSpec, make_controller
    from repro.serving.engine import SpecServer
    srv = SpecServer(draft, target,
                     make_controller("fixed_svip", gamma_max=gamma_max,
                                     seed=0),
                     spec=EngineSpec(backend="paged", batch_size=4,
                                     max_len=max_len, block_size=16,
                                     pool_tokens=pool_tokens,
                                     kv_dtype=kv_dtype))
    for p in prompts:
        srv.submit(p, max_new)
    srv.run_until_drained(max_ticks=2000)
    stats = srv.throughput_stats()
    stats["outputs"] = {r.request_id: list(r.result.tokens)
                       for r in srv.responses}
    return stats


def run(quick: bool = False, smoke: bool = False) -> dict:
    from benchmarks.bench_serving_batch import _tiny_pair, _workload
    from benchmarks.common import (evaluate_method, record_serving_bench,
                                   save_json)
    from repro.core import make_controller
    from repro.core.rewards import precision_cost_factor

    if smoke or quick:
        cfg = dict(n_prompts=3, max_new=16, gamma_max=4, max_len=128)
        draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                                   n_layers_d=1, d_model_d=32)
    else:
        cfg = dict(n_prompts=8, max_new=48, gamma_max=6, max_len=256)
        draft, target = _tiny_pair()

    prompts = _workload(cfg["n_prompts"], seed=2)

    # ---- single-stream precision arms under one stop rule
    arms = {
        "bf16_chain": {},
        "int8_kv": {"kv_dtype": "int8"},
        "int8_draft": {"quant_draft": True},
    }
    results = {}
    for name, ekw in arms.items():
        ctrl = make_controller("fixed_svip", gamma_max=cfg["gamma_max"],
                               seed=0)
        r = evaluate_method(draft, target, ctrl, prompts,
                            max_new=cfg["max_new"], max_len=cfg["max_len"],
                            engine_kwargs=ekw)
        results[name] = {"m": r.m, "accept_rate": r.accept_rate,
                         "cost_per_token": r.cost_per_token}
        print(f"  {name}: m={r.m:.2f} accept={r.accept_rate:.2f} "
              f"cost/token={r.cost_per_token:.3e}", file=sys.stderr)

    claim_cheaper = bool(results["int8_draft"]["cost_per_token"]
                         < results["bf16_chain"]["cost_per_token"])

    # ---- memory-constrained serving: same pool_tokens, ~2x capacity/byte
    serve_prompts = _workload(max(cfg["n_prompts"], 6), seed=3)
    pool_tokens = 4 * cfg["max_len"]
    srv_kw = dict(max_new=cfg["max_new"], gamma_max=cfg["gamma_max"],
                  max_len=cfg["max_len"], pool_tokens=pool_tokens)
    fp = _serve_paged(draft, target, serve_prompts, **srv_kw)
    q8 = _serve_paged(draft, target, serve_prompts, kv_dtype="int8",
                      **srv_kw)
    parity = fp["outputs"] == q8["outputs"]
    claim_pool = bool(q8["cache_pool_bytes"] < 0.5 * fp["cache_pool_bytes"])
    print(f"  paged pool bytes: fp={fp['cache_pool_bytes']} "
          f"int8={q8['cache_pool_bytes']} parity={parity}", file=sys.stderr)

    payload = {
        "config": cfg,
        "arms": results,
        "precision_cost_factor_int8": precision_cost_factor("int8"),
        "claim_quant_cheaper_per_token": claim_cheaper,
        "paged_pool_bytes": {"fp": fp["cache_pool_bytes"],
                             "int8": q8["cache_pool_bytes"]},
        "int8_kv_output_parity": bool(parity),
        "claim_int8_kv_shrinks_pool": claim_pool,
    }
    suffix = "_smoke" if smoke else ""
    save_json(f"quant_spec{suffix}", payload)
    record_serving_bench(f"quant_spec{suffix}", {
        "arms": results,
        "claim_quant_cheaper_per_token": claim_cheaper,
        "claim_int8_kv_shrinks_pool": claim_pool,
        "int8_kv_output_parity": bool(parity),
        "paged_pool_bytes": payload["paged_pool_bytes"],
    })
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI config")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    payload = run(quick=args.quick, smoke=args.smoke)
    ok = payload["claim_quant_cheaper_per_token"]
    ok_pool = payload["claim_int8_kv_shrinks_pool"]
    print(f"claim_quant_cheaper_per_token={ok}")
    print(f"claim_int8_kv_shrinks_pool={ok_pool}")
    sys.exit(0 if (ok and ok_pool) else 1)
