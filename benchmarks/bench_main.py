"""Table 3: all methods x model-pair analogs x {MT-Bench, HumanEval}.

Key paper claim (C3): TapOut Seq-UCB1 delivers top-2 speedup while being
tuning-free, across model families and datasets."""
from __future__ import annotations

from .common import (METHODS, get_corpus, run_method_suite, save_json)

PAIRS = ["llama-1b-70b", "llama-1b-8b", "olmo2-1b-32b", "gemma-270m-27b"]


def run(quick: bool = False) -> dict:
    corpus = get_corpus()
    pairs = PAIRS[:2] if quick else PAIRS
    table = {}
    for pair in pairs:
        for dataset in ("mt_bench", "humaneval"):
            prompts = [ids[:48] for _, ids in
                       corpus.prompts(dataset, 3 if quick else 5, seed=17)]
            res = run_method_suite(pair, prompts,
                                   max_new=40 if quick else 72)
            table[f"{pair}|{dataset}"] = {
                k: {"m": v.m, "accept_rate": v.accept_rate,
                    "speedup": v.speedup} for k, v in res.items()}
    # claim: seq-UCB1 speedup is top-2 among methods per (pair, dataset)
    top2 = 0
    for key, row in table.items():
        speeds = sorted((v["speedup"] for v in row.values()), reverse=True)
        thresh = speeds[1] if len(speeds) > 1 else speeds[0]
        if row["tapout_seq_ucb1"]["speedup"] >= thresh - 0.03:
            top2 += 1
    out = {"table": table, "claim_sequcb1_top2_frac": top2 / len(table)}
    save_json("table3_main", out)
    return out
