"""Table 5 (Appendix A.3): SpecBench across the four model-pair analogs."""
from __future__ import annotations

from .common import get_corpus, run_method_suite, save_json

PAIRS = ["llama-1b-70b", "llama-1b-8b", "olmo2-1b-32b", "gemma-270m-27b"]


def run(quick: bool = False) -> dict:
    corpus = get_corpus()
    pairs = PAIRS[1:2] if quick else PAIRS
    prompts = [ids[:48] for _, ids in
               corpus.prompts("specbench", 13 if quick else 26, seed=19)]
    table = {}
    for pair in pairs:
        res = run_method_suite(pair, prompts, max_new=40 if quick else 64)
        table[pair] = {k: {"m": v.m, "accept_rate": v.accept_rate,
                           "speedup": v.speedup} for k, v in res.items()}
    top2 = 0
    for pair, row in table.items():
        speeds = sorted((v["speedup"] for v in row.values()), reverse=True)
        thresh = speeds[1] if len(speeds) > 1 else speeds[0]
        if row["tapout_seq_ucb1"]["speedup"] >= thresh - 0.03:
            top2 += 1
    out = {"table": table, "claim_sequcb1_top2_frac": top2 / len(table)}
    save_json("table5_specbench", out)
    return out
