"""Prefix-sharing serving: pool bytes, peak concurrency, and prefill
skipped on a cache hit (docs/prefix_sharing.md).

N streams admitted with a common block-aligned system prompt should be
~free relative to PR 2-style fully private reservation, along three
measured-and-GATED axes:

* ``claim_shared_region_blocks_1_over_n`` — the physical blocks backing
  the shared prefix region across all N concurrent streams are <=
  ``(1/N + eps)`` of what private reservation allocates for that region
  (exactly ``F`` distinct blocks vs ``N*F``; a copy-on-write of the one
  draft frontier block is the only allowed slack).  Counted from the
  allocator's tables — deterministic, gates every mode including
  ``--smoke``.
* ``claim_shared_admits_more`` — at a FIXED pool size the prefix-sharing
  server reaches STRICTLY higher peak concurrency than the private
  server on the same shared-prompt workload, because adopters reserve
  only their non-shared suffix.  Deterministic admission arithmetic,
  gates every mode.
* ``claim_prefill_skipped_ge_shared_fraction`` — on a cache hit the
  engine's prefill-compute counters show at least the shared fraction of
  the prefill region was skipped (the compute part of the TTFT win;
  deterministic, gates every mode).  ``claim_ttft_hit_faster`` asserts
  the wall-clock counterpart — admission-to-first-token on a hit beats
  the cold admission of the same prompt — and also gates every mode: at
  >=80% of prefill skipped the gap is far outside timer noise once both
  code paths are warm.

Appends a ``prefix_sharing`` summary row to BENCH_serving.json (the
committed perf trajectory) and writes
``artifacts/bench/prefix_sharing[_smoke].json``.
"""
from __future__ import annotations

import os
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_serving_batch import _tiny_pair

BLOCK = 16


def _prompts(n: int, prefix_blocks: int, seed: int = 0) -> List[List[int]]:
    """n prompts sharing a block-aligned prefix + a distinct short tail."""
    import numpy as np
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, 60, size=prefix_blocks * BLOCK).tolist()
    return [prefix + rng.integers(1, 60, size=7).tolist() for _ in range(n)]


def _mk_engine(draft, target, *, prefix_cache, pool_tokens, batch_size=4,
               gamma_max=4, max_len=256, seed=0):
    from repro.core import EngineSpec, make_controller, make_engine
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=gamma_max, seed=seed)
    return make_engine(draft, target, ctrl, EngineSpec(
        backend="paged", batch_size=batch_size, max_len=max_len,
        block_size=BLOCK, pool_tokens=pool_tokens,
        prefix_cache=prefix_cache, seed=seed))


def _shared_region_blocks(eng, n_streams: int, region_blocks: int) -> int:
    """Distinct physical blocks backing the first ``region_blocks`` logical
    blocks of every live stream, summed over the draft+target pools."""
    total = 0
    for alloc in (eng.dalloc, eng.talloc):
        phys = {b for s in range(n_streams)
                for b in alloc.owned[s][:region_blocks]}
        total += len(phys)
    return total


def _region_bytes(eng, n_blocks: int) -> int:
    """Bytes of ``n_blocks`` pool blocks across both models' cache leaves."""
    per_block = eng.pool_stats()["cache_pool_bytes"] // (
        eng.dspec.num_blocks + eng.tspec.num_blocks)
    return 2 * n_blocks * per_block


def _concurrency_run(draft, target, prompts, *, prefix_cache, pool_tokens,
                     max_new, gamma_max):
    from repro.core import EngineSpec, make_controller
    from repro.serving.engine import SpecServer
    ctrl = make_controller("tapout_seq_ucb1", gamma_max=gamma_max, seed=0)
    srv = SpecServer(draft, target, ctrl, spec=EngineSpec(
        backend="paged", batch_size=4, max_len=256, block_size=BLOCK,
        pool_tokens=pool_tokens, prefix_cache=prefix_cache))
    for p in prompts:
        srv.submit(p, max_new)
    t0 = time.perf_counter()
    srv.run_until_drained(max_ticks=2000)
    wall = time.perf_counter() - t0
    stats = srv.throughput_stats()
    stats["wall_s"] = wall
    stats["tokens_per_s"] = stats["total_new_tokens"] / max(wall, 1e-9)
    assert len(srv.responses) == len(prompts), "workload failed to drain"
    return stats


def _ttft(eng, prompt, slot) -> float:
    """Wall seconds from admission to the first emitted token."""
    t0 = time.perf_counter()
    eng.open_stream(slot, list(prompt), reserve_tokens=len(prompt) + 20)
    eng.session_step_batch()
    return time.perf_counter() - t0


def run(quick: bool = False, smoke: bool = False) -> dict:
    from benchmarks.common import record_serving_bench, save_json

    n_streams = 4
    prefix_blocks = 2 if smoke else 4
    max_new = 6 if smoke else (12 if quick else 24)
    gamma_max = 4
    draft, target = _tiny_pair(n_layers_t=2, d_model_t=64,
                               n_layers_d=1, d_model_d=32)
    prompts = _prompts(n_streams, prefix_blocks)
    P = len(prompts[0])
    reserve = P + max_new + gamma_max + 2
    need_blocks = -(-reserve // BLOCK)
    shared_tokens = prefix_blocks * BLOCK

    # ---- pool-bytes row: N concurrent streams, same block-aligned prefix.
    # Private reservation backs the prefix region with N*F blocks per pool;
    # sharing backs it with F (+ at most the one COW'd draft frontier
    # block, which this layout never needs: the suffix keeps the write
    # frontier past the adopted run).
    rows = {}
    for mode, pc in (("private", False), ("shared", True)):
        eng = _mk_engine(draft, target, prefix_cache=pc,
                         pool_tokens=16 * need_blocks * BLOCK,
                         gamma_max=gamma_max)
        for s, p in enumerate(prompts):
            eng.open_stream(s, list(p), reserve_tokens=reserve)
        blocks = _shared_region_blocks(eng, n_streams, prefix_blocks)
        ps = eng.pool_stats()
        rows[mode] = {
            "prefix_region_blocks": blocks,
            "prefix_region_bytes": _region_bytes(eng, blocks),
            "blocks_in_use": ps["blocks_in_use"],
            "prefill_tokens_computed": ps["prefill_tokens_computed"],
            "prefill_tokens_skipped": ps["prefill_tokens_skipped"],
            "cow_copies": ps["cow_copies"],
        }
        for s in range(n_streams):
            eng.close_stream(s)
    ratio = rows["shared"]["prefix_region_blocks"] / max(
        rows["private"]["prefix_region_blocks"], 1)
    eps = 1.0 / (n_streams * prefix_blocks)        # one COW block of slack
    claim_blocks = bool(ratio <= 1.0 / n_streams + eps)
    print(f"  shared-region blocks: {rows['shared']['prefix_region_blocks']}"
          f" vs private {rows['private']['prefix_region_blocks']}"
          f"  ratio={ratio:.3f} (target <= {1.0 / n_streams + eps:.3f})",
          file=sys.stderr)

    # ---- fixed-pool concurrency row: the pool fits ONE private
    # reservation plus change, so the private server serializes; adopters
    # only reserve their suffix, so the sharing server overlaps streams.
    pool_blocks = need_blocks + 2 * max(need_blocks - prefix_blocks, 1)
    many = _prompts(8, prefix_blocks, seed=1)
    conc = {}
    for mode, pc in (("private", False), ("shared", True)):
        conc[mode] = _concurrency_run(
            draft, target, many, prefix_cache=pc,
            pool_tokens=pool_blocks * BLOCK, max_new=max_new,
            gamma_max=gamma_max)
        print(f"  {mode}: peak_concurrency={conc[mode]['peak_concurrency']}"
              f"  backpressure={conc[mode]['backpressure_events']}"
              f"  {conc[mode]['tokens_per_s']:.1f} tok/s", file=sys.stderr)
    claim_conc = bool(conc["shared"]["peak_concurrency"]
                      > conc["private"]["peak_concurrency"])

    # ---- TTFT row: same prompt cold (miss) and warm (hit) on one engine
    # whose jitted shapes are already compiled; the hit skips the shared
    # prefix's prefill compute entirely.
    eng = _mk_engine(draft, target, prefix_cache=True,
                     pool_tokens=16 * need_blocks * BLOCK,
                     gamma_max=gamma_max)
    _ttft(eng, prompts[0], 0)                      # warmup: compile + seed
    eng.close_stream(0)
    eng.prefix_cache.evict(10 ** 6)                # forget everything
    base = eng.pool_stats()
    ttft_miss = _ttft(eng, prompts[1], 0)          # cold: full prefill
    ttft_hit = _ttft(eng, prompts[2], 1)           # hit: suffix-only prefill
    ps = eng.pool_stats()
    skipped = ps["prefill_tokens_skipped"] - base["prefill_tokens_skipped"]
    computed = ps["prefill_tokens_computed"] - base["prefill_tokens_computed"]
    hit_prefill_region = P - 1
    frac_skipped = skipped / hit_prefill_region
    shared_frac = shared_tokens / hit_prefill_region
    claim_prefill = bool(frac_skipped >= shared_frac - 1e-9)
    claim_ttft = bool(ttft_hit < ttft_miss)
    print(f"  ttft: miss={ttft_miss * 1e3:.1f}ms hit={ttft_hit * 1e3:.1f}ms"
          f"  prefill skipped {skipped}/{hit_prefill_region}"
          f" (shared fraction {shared_frac:.2f})", file=sys.stderr)

    payload = {
        "config": {"n_streams": n_streams, "prefix_blocks": prefix_blocks,
                   "block_size": BLOCK, "prompt_len": P,
                   "max_new": max_new, "gamma_max": gamma_max,
                   "pool_blocks_fixed": pool_blocks},
        "region": rows,
        "region_block_ratio": ratio,
        "concurrency": conc,
        "ttft_miss_s": ttft_miss,
        "ttft_hit_s": ttft_hit,
        "prefill_skipped_fraction_on_hit": frac_skipped,
        "prefill_tokens_computed_on_miss": computed,
        "claim_shared_region_blocks_1_over_n": claim_blocks,
        "claim_shared_admits_more": claim_conc,
        "claim_prefill_skipped_ge_shared_fraction": claim_prefill,
        "claim_ttft_hit_faster": claim_ttft,
    }
    suffix = "_smoke" if smoke else ""
    save_json(f"prefix_sharing{suffix}", payload)
    record_serving_bench(f"prefix_sharing{suffix}", {
        "engine": eng.describe(),
        "region_block_ratio": ratio,
        "peak_concurrency_shared": conc["shared"]["peak_concurrency"],
        "peak_concurrency_private": conc["private"]["peak_concurrency"],
        "ttft_miss_s": ttft_miss,
        "ttft_hit_s": ttft_hit,
        "prefill_skipped_fraction_on_hit": frac_skipped,
        "claim_shared_region_blocks_1_over_n": claim_blocks,
        "claim_shared_admits_more": claim_conc,
        "claim_prefill_skipped_ge_shared_fraction": claim_prefill,
        "claim_ttft_hit_faster": claim_ttft,
    })
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale config for CI; claims still gate")
    args = ap.parse_args()
    payload = run(quick=args.quick, smoke=args.smoke)
    ok = all(payload[k] for k in payload if k.startswith("claim_"))
    for k in sorted(payload):
        if k.startswith("claim_"):
            print(f"{k}={payload[k]}")
    sys.exit(0 if ok else 1)
