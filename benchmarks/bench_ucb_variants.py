"""Fig. 4: UCB1 vs UCB-Tuned (blend reward) across SpecBench categories.
Paper: UCB1 wins everywhere because r_blend has low variance."""
from __future__ import annotations

from collections import defaultdict

from .common import (GAMMA_MAX, calibrated_pool, evaluate_method, get_corpus,
                     save_json, trained_pair)
from repro.core import StaticGamma, TapOutSequence


def run(quick: bool = False) -> dict:
    draft, target = trained_pair("llama-1b-8b")
    corpus = get_corpus()
    prompts_by_cat = defaultdict(list)
    for cat, ids in corpus.prompts("specbench", 13 if quick else 26, seed=13):
        prompts_by_cat[cat].append(ids[:48])
    per_cat = {}
    for cat, prompts in sorted(prompts_by_cat.items()):
        base = evaluate_method(draft, target, StaticGamma(6), prompts,
                               max_new=40 if quick else 64)
        row = {}
        for bandit in ("ucb1", "ucb_tuned"):
            ctrl = TapOutSequence(GAMMA_MAX, bandit, "blend",
                                  pool=calibrated_pool("llama-1b-8b"))
            r = evaluate_method(draft, target, ctrl, prompts,
                                max_new=40 if quick else 64)
            row[bandit] = base.cost_per_token / max(r.cost_per_token, 1e-12)
        per_cat[cat] = row
    wins = sum(per_cat[c]["ucb1"] >= per_cat[c]["ucb_tuned"] - 0.02
               for c in per_cat)
    # pooled primary claim (one online bandit across the whole promptset)
    all_prompts = [p for c in sorted(prompts_by_cat)
                   for p in prompts_by_cat[c]]
    base = evaluate_method(draft, target, StaticGamma(6), all_prompts,
                           max_new=40 if quick else 64)
    pooled = {}
    for bandit in ("ucb1", "ucb_tuned"):
        ctrl = TapOutSequence(GAMMA_MAX, bandit, "blend",
                              pool=calibrated_pool("llama-1b-8b"))
        r = evaluate_method(draft, target, ctrl, all_prompts,
                            max_new=40 if quick else 64)
        pooled[bandit] = base.cost_per_token / max(r.cost_per_token, 1e-12)
    out = {"per_category_speedup": per_cat, "pooled_speedup": pooled,
           "claim_ucb1_geq_ucbtuned":
               bool(pooled["ucb1"] >= pooled["ucb_tuned"] - 0.01),
           "claim_ucb1_geq_ucbtuned_frac": wins / len(per_cat)}
    save_json("fig4_ucb_variants", out)
    return out
