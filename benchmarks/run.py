"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the headline metric
or claim check of each benchmark) and writes full JSON payloads under
artifacts/bench/.

  fig2_entropy        Fig. 2   entropy by position, coding vs non-coding
  table2_reward       Table 2  r_simple vs r_blend (+ Fig. 3 lengths)
  fig4_ucb_variants   Fig. 4   UCB1 vs UCB-Tuned
  table3_main         Table 3  methods x pairs x {MT-Bench, HumanEval}
  table4_specdecpp    Table 4  trained SpecDec++ vs bandits
  table5_specbench    Table 5  SpecBench across pairs
  a2_more_arms        App. A.2 small vs multi-threshold arm pool
  serving_batch       —        batched serving tokens/s + latency vs B
  tree_spec           —        tree-vs-chain accepted/verify + shape bandit
  quant_spec          —        bf16 vs int8-KV vs int8-draft arms + pool bytes
  prefix_sharing      —        shared-prefix pool blocks / concurrency / TTFT
  slo_serving         —        open-loop goodput under p95 SLO, FIFO vs SLO
  drafters            —        heterogeneous drafter pool: fixed vs meta-bandit
  moe_encoder         —        MoE routed-cost + shared encoder-segment pool
  kernels_micro       —        kernel/XLA-path microbench
  roofline            §Roofline collation from the dry-run artifacts

Serving-path benches (serving_batch, tree_spec, quant_spec,
prefix_sharing, slo_serving, drafters, moe_encoder) additionally append their
summaries to the repo-root BENCH_serving.json (committed — the perf
trajectory across
PRs); ``scripts/check_bench_schema.py`` validates every appended row.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced prompt counts / pairs")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from . import (bench_arm_values, bench_drafters, bench_entropy,
                   bench_kernels, bench_main, bench_moe_encoder,
                   bench_more_arms, bench_prefix_sharing, bench_quant,
                   bench_reward, bench_serving_batch, bench_specbench,
                   bench_specdecpp, bench_tree, bench_ucb_variants,
                   roofline_table)

    def derived_fmt(d):
        keys = [k for k in d if k.startswith("claim_")]
        if keys:
            return ";".join(f"{k}={d[k]}" for k in keys)
        return ""

    benches = {
        "fig2_entropy": (bench_entropy.run, derived_fmt),
        "table2_reward": (bench_reward.run, derived_fmt),
        "fig4_ucb_variants": (bench_ucb_variants.run, derived_fmt),
        "table3_main": (bench_main.run, derived_fmt),
        "table4_specdecpp": (bench_specdecpp.run, derived_fmt),
        "table5_specbench": (bench_specbench.run, derived_fmt),
        "a2_more_arms": (bench_more_arms.run, derived_fmt),
        "serving_batch": (bench_serving_batch.run, derived_fmt),
        "tree_spec": (bench_tree.run, derived_fmt),
        "quant_spec": (bench_quant.run, derived_fmt),
        "prefix_sharing": (bench_prefix_sharing.run, derived_fmt),
        "drafters": (bench_drafters.run, derived_fmt),
        "moe_encoder": (bench_moe_encoder.run, derived_fmt),
        "fig5_6_arm_values": (bench_arm_values.run, lambda d: ";".join(
            f"{k}_spearman={d[k]['spearman_values_vs_speedup']:.2f}"
            for k in d)),
        "kernels_micro": (bench_kernels.run, lambda d: ";".join(
            f"{k}={v:.1f}" for k, v in d.items() if k.endswith("_us"))),
        "roofline": (roofline_table.run, lambda d:
                     f"compiled={d['n_compiled_scanned']}/{d['n_total_scanned']}"),
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    rc = 0
    for name, (fn, fmt) in benches.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            payload = fn(quick=args.quick)
            us = (time.perf_counter() - t0) * 1e6
            print(f"{name},{us:.0f},{fmt(payload)}", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"{name},-1,ERROR:{type(e).__name__}", flush=True)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
